//! Simulated-testbed clock (DESIGN.md §8).
//!
//! This container has **one CPU core**, so the paper's p = 2…16 thread
//! sweeps cannot produce real concurrency. Engines therefore run their
//! real code paths while *accounting* virtual time the way a p-core
//! shared-memory machine would spend it:
//!
//! ```text
//! T_iter(p) = max_w(compute_w)            // workers run concurrently
//!           + t_barrier(p)                 // two barrier phases
//!           + t_merge(p)                   // leader folds p partials
//! T_run(p)  = Σ_iters T_iter(p)
//! ```
//!
//! `compute_w` is *measured* (the real per-shard work, identical
//! instructions a real thread would execute). The sync terms come from
//! [`SyncModel`], calibrated by [`calibrate`] with microbenchmarks of
//! the actual merge/lock operations on this machine. Both raw 1-core
//! wall-clock and virtual-clock numbers are recorded for every
//! experiment (EXPERIMENTS.md).

use std::time::Instant;

use crate::kmeans::step::PartialStats;

/// Calibrated synchronization-cost model for the virtual testbed.
#[derive(Debug, Clone)]
pub struct SyncModel {
    /// Seconds for the leader to fold one worker's PartialStats
    /// (measured per (k, d) at calibration).
    pub t_merge_one: f64,
    /// Seconds per barrier crossing per worker (cache-line ping-pong +
    /// futex wake; measured with real `std::sync::Barrier` pairs).
    pub t_barrier_per_worker: f64,
    /// Extra serialization cost per worker when merging under a single
    /// mutex (the paper's `critical` directive): lock handoff latency.
    pub t_critical_handoff: f64,
}

impl SyncModel {
    /// Leader-merge iteration overhead for `p` workers.
    pub fn leader_overhead(&self, p: usize) -> f64 {
        2.0 * self.t_barrier_per_worker * p as f64 + self.t_merge_one * p as f64
    }

    /// Critical-section iteration overhead for `p` workers: merges are
    /// serialized through one lock, each paying handoff + merge.
    pub fn critical_overhead(&self, p: usize) -> f64 {
        2.0 * self.t_barrier_per_worker * p as f64
            + (self.t_merge_one + self.t_critical_handoff) * p as f64
    }
}

/// Measure the sync primitives on this machine for a given (k, d).
pub fn calibrate(k: usize, d: usize) -> SyncModel {
    // merge cost: fold PartialStats repeatedly
    let mut a = PartialStats::zeros(k, d);
    let mut b = PartialStats::zeros(k, d);
    for i in 0..k * d {
        b.sums[i] = i as f64;
    }
    for c in 0..k {
        b.counts[c] = c as u64;
    }
    let reps = 20_000;
    let t0 = Instant::now();
    for _ in 0..reps {
        a.merge(&b);
        std::hint::black_box(&a);
    }
    let t_merge_one = t0.elapsed().as_secs_f64() / reps as f64;

    // barrier cost: ping-pong a 2-party barrier (measures wake latency)
    let barrier = std::sync::Barrier::new(2);
    let rounds = 2_000;
    let t_barrier = std::thread::scope(|s| {
        let h = s.spawn(|| {
            for _ in 0..rounds {
                barrier.wait();
            }
        });
        let t0 = Instant::now();
        for _ in 0..rounds {
            barrier.wait();
        }
        let dt = t0.elapsed().as_secs_f64() / rounds as f64;
        h.join().unwrap();
        dt
    });

    // lock handoff: uncontended mutex lock/unlock (contended handoff is
    // strictly worse; this is the optimistic floor, noted in DESIGN.md)
    let m = std::sync::Mutex::new(0u64);
    let t0 = Instant::now();
    for _ in 0..reps {
        *m.lock().unwrap() += 1;
    }
    let t_critical_handoff = t0.elapsed().as_secs_f64() / reps as f64 + t_barrier * 0.1;

    SyncModel {
        t_merge_one,
        t_barrier_per_worker: t_barrier,
        t_critical_handoff,
    }
}

/// Virtual-clock accumulator for one engine run.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    /// Per-iteration max worker compute (seconds).
    pub iter_compute: Vec<f64>,
    /// Per-iteration sync overhead (seconds).
    pub iter_sync: Vec<f64>,
}

impl VirtualClock {
    pub fn push_iteration(&mut self, worker_busy: &[f64], sync: f64) {
        let max = worker_busy.iter().copied().fold(0.0, f64::max);
        self.iter_compute.push(max);
        self.iter_sync.push(sync);
    }

    /// Total virtual wall-clock.
    pub fn total(&self) -> f64 {
        self.iter_compute.iter().sum::<f64>() + self.iter_sync.iter().sum::<f64>()
    }

    pub fn iterations(&self) -> usize {
        self.iter_compute.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_positive_and_sane() {
        let m = calibrate(8, 3);
        assert!(m.t_merge_one > 0.0 && m.t_merge_one < 1e-3, "{m:?}");
        assert!(m.t_barrier_per_worker > 0.0 && m.t_barrier_per_worker < 1e-2, "{m:?}");
        assert!(m.t_critical_handoff > 0.0, "{m:?}");
    }

    #[test]
    fn overhead_monotone_in_p() {
        let m = SyncModel {
            t_merge_one: 1e-6,
            t_barrier_per_worker: 2e-6,
            t_critical_handoff: 5e-7,
        };
        let mut last = 0.0;
        for p in [1, 2, 4, 8, 16] {
            let o = m.leader_overhead(p);
            assert!(o > last);
            last = o;
            // critical always costs at least leader
            assert!(m.critical_overhead(p) >= o);
        }
    }

    #[test]
    fn virtual_clock_takes_max_over_workers() {
        let mut vc = VirtualClock::default();
        vc.push_iteration(&[0.1, 0.5, 0.2], 0.01);
        vc.push_iteration(&[0.3, 0.3], 0.01);
        assert!((vc.total() - (0.5 + 0.3 + 0.02)).abs() < 1e-12);
        assert_eq!(vc.iterations(), 2);
    }
}
