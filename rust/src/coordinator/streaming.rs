//! Out-of-core streaming engine — the "extremely large datasets"
//! extension the paper's conclusion motivates.
//!
//! The dataset never resides in memory: each Lloyd iteration streams
//! chunk-sized blocks from the binary dataset file (`data::io` format)
//! through the `stats_partial` executable, keeping only
//! O(chunk + K·d) host memory. Backpressure is inherent (synchronous
//! chunk pipeline); a double-buffered reader overlaps disk IO with
//! device compute via a prefetch thread.
//!
//! Unlike the in-memory engines, X cannot stay device-resident across
//! iterations (it would defeat the memory bound), so every iteration
//! re-uploads each chunk — exactly the regime where the paper's GPU
//! streaming comparison lives. The A1 chunk ablation applies directly.
//!
//! The pure-rust counterpart (no AOT runtime, sharded workers, any
//! [`crate::data::DataSource`]) is [`crate::kmeans::streaming`]; both
//! share the `.pkd` header probe in [`crate::data::io`].

use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Instant;

use crate::config::RunConfig;
use crate::coordinator::driver::EngineRun;
use crate::coordinator::plan::chunk_calls;
use crate::data::io::probe_binary;
use crate::error::{Error, Result};
use crate::kmeans::KmeansResult;
use crate::rng::Pcg64;
use crate::runtime::manifest::ExecKind;
use crate::runtime::{Runtime, TensorArg};

/// Header info of a binary dataset file (without loading the payload).
#[derive(Debug, Clone)]
pub struct FileInfo {
    pub path: PathBuf,
    pub dim: usize,
    pub n: usize,
    payload_offset: u64,
}

/// Probe a `.pkd` file's header (validating facade over
/// [`crate::data::io::probe_binary`]).
pub fn probe(path: &Path) -> Result<FileInfo> {
    let h = probe_binary(path)?;
    Ok(FileInfo {
        path: path.to_path_buf(),
        dim: h.dim,
        n: h.n,
        payload_offset: h.payload_offset,
    })
}

/// One prefetched block: rows `[lo, hi)` padded to `chunk`.
struct Block {
    call_idx: usize,
    data: Vec<f32>,
}

/// Spawn the prefetch thread: reads blocks in call order, sends them
/// over a bounded channel (capacity 2 = double buffering).
fn spawn_reader(
    info: &FileInfo,
    calls: Vec<crate::coordinator::plan::ChunkCall>,
) -> Result<mpsc::Receiver<std::result::Result<Block, String>>> {
    let (tx, rx) = mpsc::sync_channel(2);
    let info = info.clone();
    std::thread::spawn(move || {
        let run = || -> Result<()> {
            let f = std::fs::File::open(&info.path)?;
            let mut r = BufReader::with_capacity(1 << 20, f);
            for (ci, call) in calls.iter().enumerate() {
                let d = info.dim;
                let mut data = vec![0.0f32; call.chunk * d];
                let byte_lo = info.payload_offset + (call.lo * d * 4) as u64;
                r.seek(SeekFrom::Start(byte_lo))?;
                let valid_bytes = call.n_valid() * d * 4;
                let mut buf = vec![0u8; valid_bytes];
                r.read_exact(&mut buf)?;
                for (i, c) in buf.chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                if tx
                    .send(Ok(Block { call_idx: ci, data }))
                    .is_err()
                {
                    break; // consumer gone (error path); stop quietly
                }
            }
            Ok(())
        };
        if let Err(e) = run() {
            let _ = tx.send(Err(e.to_string()));
        }
    });
    Ok(rx)
}

/// Run streaming Lloyd over a binary dataset file.
///
/// `cfg.seed` drives reservoir-style initialization: K initial
/// centroids are sampled from the file with a single bounded-memory
/// pass (reservoir sampling), matching the paper's random-point init
/// without loading the dataset.
pub fn run_file(path: &Path, cfg: &RunConfig) -> Result<EngineRun> {
    let mut rt = Runtime::new_or_native(&cfg.artifacts_dir)?;
    run_file_with(&mut rt, path, cfg)
}

/// Run against a caller-owned runtime.
pub fn run_file_with(rt: &mut Runtime, path: &Path, cfg: &RunConfig) -> Result<EngineRun> {
    cfg.validate()?;
    cfg.pin_kernel()?;
    let info = probe(path)?;
    let (n, d) = (info.n, info.dim);
    let k = cfg.k;
    if n == 0 {
        return Err(Error::Shape("empty dataset file".into()));
    }

    // ---- setup ----------------------------------------------------------
    let t_setup = Instant::now();
    let sizes = crate::coordinator::shared::resolve_chunk_sizes(
        rt,
        ExecKind::StatsPartial,
        d,
        k,
        cfg.chunk,
    )?;
    let mut specs = std::collections::HashMap::new();
    let mut assign_specs = std::collections::HashMap::new();
    for &s in &sizes {
        let spec = rt.find(ExecKind::StatsPartial, d, k, s)?;
        rt.prepare(&spec)?;
        specs.insert(s, spec);
        let aspec = rt.find(ExecKind::Assign, d, k, s)?;
        rt.prepare(&aspec)?;
        assign_specs.insert(s, aspec);
    }
    let spec_fin = rt.find(ExecKind::Finalize, d, k, 0)?;
    rt.prepare(&spec_fin)?;
    let calls = chunk_calls(0, n, &sizes);

    // reservoir-sample K initial centroids in one pass
    let mut centroids = reservoir_init(&info, k, cfg.seed)?;
    let setup_secs = t_setup.elapsed().as_secs_f64();

    // ---- iteration loop ---------------------------------------------------
    let t_loop = Instant::now();
    let mut history = Vec::new();
    let mut converged = false;
    let mut iterations = 0usize;
    let mut exec_calls = 0usize;
    let mut sse = f64::NAN;
    let mut peak_block_bytes = 0usize;

    for _ in 0..cfg.max_iters {
        let rx = spawn_reader(&info, calls.clone())?;
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0.0f64; k];
        let mut iter_sse = 0.0f64;
        for block in rx {
            let block = block.map_err(Error::Worker)?;
            let call = &calls[block.call_idx];
            peak_block_bytes = peak_block_bytes.max(block.data.len() * 4);
            let outs = rt.execute(
                &specs[&call.chunk],
                &[
                    TensorArg::F32(&block.data),
                    TensorArg::F32(&centroids),
                    TensorArg::I32(&[call.n_valid() as i32]),
                ],
            )?;
            exec_calls += 1;
            for (a, &b) in sums.iter_mut().zip(outs[0].as_f32()) {
                *a += b as f64;
            }
            for (a, &b) in counts.iter_mut().zip(outs[1].as_f32()) {
                *a += b as f64;
            }
            iter_sse += outs[2].as_f32()[0] as f64;
        }
        let sums_f32: Vec<f32> = sums.iter().map(|&v| v as f32).collect();
        let counts_f32: Vec<f32> = counts.iter().map(|&v| v as f32).collect();
        let outs = rt.execute(
            &spec_fin,
            &[
                TensorArg::F32(&sums_f32),
                TensorArg::F32(&counts_f32),
                TensorArg::F32(&centroids),
            ],
        )?;
        exec_calls += 1;
        centroids = outs[0].as_f32().to_vec();
        let shift = outs[1].as_f32()[0] as f64;
        sse = iter_sse;
        iterations += 1;
        history.push((sse, shift));
        if shift < cfg.tol {
            converged = true;
            break;
        }
    }

    // final assignment pass (streamed once more)
    let mut assign = vec![-1i32; n];
    {
        let rx = spawn_reader(&info, calls.clone())?;
        for block in rx {
            let block = block.map_err(Error::Worker)?;
            let call = &calls[block.call_idx];
            let outs = rt.execute(
                &assign_specs[&call.chunk],
                &[
                    TensorArg::F32(&block.data),
                    TensorArg::F32(&centroids),
                    TensorArg::I32(&[call.n_valid() as i32]),
                ],
            )?;
            exec_calls += 1;
            let a = outs[0].as_i32();
            assign[call.lo..call.hi].copy_from_slice(&a[..call.n_valid()]);
        }
    }
    let wall_secs = t_loop.elapsed().as_secs_f64();

    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    Ok(EngineRun {
        result: KmeansResult {
            centroids,
            assign,
            k,
            dim: d,
            iterations,
            sse,
            shift,
            converged,
            history,
            empty_events: Vec::new(),
            pruning: None,
        },
        setup_secs,
        wall_secs,
        virtual_clock: None,
        exec_calls,
    })
}

/// Single-pass reservoir sampling of K distinct rows from the file.
fn reservoir_init(info: &FileInfo, k: usize, seed: u64) -> Result<Vec<f32>> {
    if k > info.n {
        return Err(Error::Config(format!("k {} > n {}", k, info.n)));
    }
    let d = info.dim;
    let f = std::fs::File::open(&info.path)?;
    let mut r = BufReader::with_capacity(1 << 20, f);
    r.seek(SeekFrom::Start(info.payload_offset))?;
    let mut rng = Pcg64::new(seed, 0x5e5e);
    let mut reservoir = vec![0.0f32; k * d];
    let mut row = vec![0u8; d * 4];
    for i in 0..info.n {
        r.read_exact(&mut row)?;
        let slot = if i < k {
            Some(i)
        } else {
            let j = rng.next_below((i + 1) as u64) as usize;
            (j < k).then_some(j)
        };
        if let Some(s) = slot {
            for (jj, c) in row.chunks_exact(4).enumerate() {
                reservoir[s * d + jj] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
    }
    Ok(reservoir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{io, MixtureSpec};
    use crate::kmeans::{self, KmeansConfig};

    fn artifacts_ready() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("parakm_streaming_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn cfg(k: usize) -> RunConfig {
        RunConfig {
            k,
            seed: 42,
            artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts"),
            ..Default::default()
        }
    }

    #[test]
    fn probe_reads_header() {
        let ds = MixtureSpec::paper_3d(4).generate(1234, 7);
        let p = tmp("probe.pkd");
        io::write_binary(&p, &ds).unwrap();
        let info = probe(&p).unwrap();
        assert_eq!(info.dim, 3);
        assert_eq!(info.n, 1234);
    }

    #[test]
    fn probe_rejects_garbage() {
        let p = tmp("garbage.pkd");
        std::fs::write(&p, b"not a dataset").unwrap();
        assert!(probe(&p).is_err());
    }

    #[test]
    fn reservoir_init_samples_real_rows() {
        let ds = MixtureSpec::paper_2d(4).generate(500, 3);
        let p = tmp("reservoir.pkd");
        io::write_binary(&p, &ds).unwrap();
        let info = probe(&p).unwrap();
        let mu = reservoir_init(&info, 8, 11).unwrap();
        assert_eq!(mu.len(), 16);
        for c in 0..8 {
            let cent = &mu[c * 2..(c + 1) * 2];
            assert!(
                (0..ds.len()).any(|i| ds.point(i) == cent),
                "centroid {c} not a dataset row"
            );
        }
    }

    /// Streaming from disk must produce the same clustering as the
    /// in-memory offload engine (same algorithm, bounded memory).
    #[test]
    fn matches_in_memory_engines() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(30_001, 5);
        let p = tmp("stream30k.pkd");
        io::write_binary(&p, &ds).unwrap();
        let run = run_file(&p, &cfg(4)).unwrap();
        assert!(run.result.converged);

        // reference: serial from the reservoir init (same seed => the
        // same K rows are chosen, so the runs are directly comparable)
        let info = probe(&p).unwrap();
        let mu0 = reservoir_init(&info, 4, 42).unwrap();
        let kc = KmeansConfig::new(4).with_seed(42);
        let reference = kmeans::serial::run_from(&ds, &kc, &mu0);
        assert_eq!(run.result.iterations, reference.iterations);
        let ari = crate::metrics::adjusted_rand_index(&run.result.assign, &reference.assign);
        assert!(ari > 0.9999, "ari {ari}");
    }

    #[test]
    fn missing_file_is_clean_error() {
        let missing = tmp("does_not_exist.pkd");
        let _ = std::fs::remove_file(&missing);
        assert!(run_file(&missing, &cfg(4)).is_err());
    }

    #[test]
    fn truncated_payload_surfaces_as_error() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(9000, 5);
        let p = tmp("trunc.pkd");
        io::write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        // header still says n=9000, payload is short: must error, not hang
        assert!(run_file(&p, &cfg(4)).is_err());
    }
}
