//! Shard/chunk planning: how `n` rows map onto workers and onto
//! fixed-size executable calls.
//!
//! AOT artifacts are shape-specialized, one per streaming chunk size
//! (DESIGN.md §2). A shard is covered greedily with the largest
//! available chunk that fits, so big shards amortize launch overhead
//! over big calls while the padding waste of the tail is bounded by
//! the *smallest* available chunk size. The final call pads up to the
//! smallest chunk ≥ the remainder and masks via `n_valid`.

/// One executable invocation: rows `[lo, hi)`, executed by the
/// artifact specialized to `chunk` (`hi - lo <= chunk`; the gap is
/// padding masked by `n_valid`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkCall {
    pub lo: usize,
    pub hi: usize,
    pub chunk: usize,
}

impl ChunkCall {
    pub fn n_valid(&self) -> usize {
        self.hi - self.lo
    }

    pub fn padding(&self) -> usize {
        self.chunk - self.n_valid()
    }
}

/// Greedy multi-size chunking of rows `[lo, hi)`.
///
/// `sizes` is the available artifact chunk sizes (any order, deduped
/// internally). Invariants (tested): calls are contiguous, cover the
/// range exactly, only the final call may pad, and its padding is less
/// than the smallest size.
pub fn chunk_calls(lo: usize, hi: usize, sizes: &[usize]) -> Vec<ChunkCall> {
    assert!(!sizes.is_empty(), "no chunk sizes");
    let mut sorted: Vec<usize> = sizes.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    assert!(sorted[0] > 0, "zero chunk size");

    let mut out = Vec::new();
    let mut cur = lo;
    while cur < hi {
        let remaining = hi - cur;
        // largest size fully covered by the remaining rows …
        let fit = sorted.iter().rev().find(|&&s| s <= remaining);
        let chunk = match fit {
            Some(&s) => s,
            // … or the smallest size ≥ remainder (padded tail)
            None => *sorted.iter().find(|&&s| s >= remaining).unwrap(),
        };
        let end = (cur + chunk).min(hi);
        out.push(ChunkCall { lo: cur, hi: end, chunk });
        cur = end;
    }
    out
}

/// Convenience: single-size chunking (A1 ablation pins one size).
pub fn chunk_calls_single(lo: usize, hi: usize, chunk: usize) -> Vec<ChunkCall> {
    chunk_calls(lo, hi, &[chunk])
}

/// Full plan for `p` workers over `n` rows.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub n: usize,
    pub p: usize,
    /// (shard_range, chunk calls) per worker.
    pub shards: Vec<((usize, usize), Vec<ChunkCall>)>,
}

impl ShardPlan {
    pub fn new(n: usize, p: usize, sizes: &[usize]) -> ShardPlan {
        let ranges = crate::data::dataset::shard_ranges(n, p);
        let shards = ranges
            .iter()
            .map(|&(lo, hi)| ((lo, hi), chunk_calls(lo, hi, sizes)))
            .collect();
        ShardPlan { n, p, shards }
    }

    /// Total executable calls per iteration.
    pub fn total_calls(&self) -> usize {
        self.shards.iter().map(|(_, c)| c.len()).sum()
    }

    /// Fraction of transferred rows that are padding (perf telemetry).
    pub fn padding_fraction(&self) -> f64 {
        let padded: usize = self
            .shards
            .iter()
            .flat_map(|(_, calls)| calls.iter())
            .map(ChunkCall::padding)
            .sum();
        let total: usize = self
            .shards
            .iter()
            .flat_map(|(_, calls)| calls.iter())
            .map(|c| c.chunk)
            .sum();
        if total == 0 {
            0.0
        } else {
            padded as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn single_size_covers_range() {
        let calls = chunk_calls_single(10, 250, 100);
        assert_eq!(
            calls,
            vec![
                ChunkCall { lo: 10, hi: 110, chunk: 100 },
                ChunkCall { lo: 110, hi: 210, chunk: 100 },
                ChunkCall { lo: 210, hi: 250, chunk: 100 },
            ]
        );
        assert_eq!(calls[2].n_valid(), 40);
        assert_eq!(calls[2].padding(), 60);
    }

    #[test]
    fn multi_size_prefers_large_then_small_tail() {
        let calls = chunk_calls(0, 70_000, &[4096, 65536]);
        assert_eq!(calls[0], ChunkCall { lo: 0, hi: 65536, chunk: 65536 });
        assert_eq!(calls[1], ChunkCall { lo: 65536, hi: 69632, chunk: 4096 });
        // tail: 368 rows in one padded 4096 call
        assert_eq!(calls[2], ChunkCall { lo: 69632, hi: 70_000, chunk: 4096 });
        assert_eq!(calls[2].padding(), 4096 - 368);
    }

    #[test]
    fn tiny_range_single_padded_small_call() {
        let calls = chunk_calls(5, 25, &[4096, 65536]);
        assert_eq!(calls, vec![ChunkCall { lo: 5, hi: 25, chunk: 4096 }]);
    }

    #[test]
    fn empty_range_no_calls() {
        assert!(chunk_calls(5, 5, &[100]).is_empty());
    }

    #[test]
    fn exact_multiple_no_padding() {
        let plan = ShardPlan::new(200, 2, &[100]);
        assert_eq!(plan.total_calls(), 2);
        assert_eq!(plan.padding_fraction(), 0.0);
    }

    #[test]
    fn plan_properties() {
        prop::check("shard plan covers all rows exactly once", 64, |g| {
            let n = g.usize_in(0, 5000);
            let p = g.usize_in(1, 17);
            let mut sizes = vec![g.usize_in(1, 100), g.usize_in(100, 700)];
            if g.bool() {
                sizes.truncate(1);
            }
            let plan = ShardPlan::new(n, p, &sizes);
            let smallest = *sizes.iter().min().unwrap();
            prop::ensure(plan.shards.len() == p, "wrong worker count")?;
            let mut covered = 0usize;
            let mut expected_next = 0usize;
            for ((lo, hi), calls) in &plan.shards {
                prop::ensure(*lo == expected_next, "shards not contiguous")?;
                expected_next = *hi;
                let mut cur = *lo;
                for (i, c) in calls.iter().enumerate() {
                    prop::ensure(c.lo == cur, "chunks not contiguous")?;
                    prop::ensure(c.n_valid() > 0, "empty chunk call")?;
                    prop::ensure(c.n_valid() <= c.chunk, "oversized chunk")?;
                    prop::ensure(sizes.contains(&c.chunk), "unknown chunk size")?;
                    if i + 1 < calls.len() {
                        prop::ensure(c.padding() == 0, "padding before the tail")?;
                    } else {
                        prop::ensure(
                            c.padding() < smallest,
                            format!("tail padding {} >= smallest {}", c.padding(), smallest),
                        )?;
                    }
                    cur = c.hi;
                    covered += c.n_valid();
                }
                prop::ensure(cur == *hi, "chunks don't cover shard")?;
            }
            prop::ensure(expected_next == n, "shards don't cover dataset")?;
            prop::ensure(covered == n, "row count mismatch")
        });
    }

    #[test]
    fn padding_fraction_bounds() {
        prop::check("padding fraction in [0,1)", 32, |g| {
            let n = g.usize_in(1, 3000);
            let p = g.usize_in(1, 8);
            let sizes = [g.usize_in(1, 500)];
            let f = ShardPlan::new(n, p, &sizes).padding_fraction();
            prop::ensure((0.0..1.0).contains(&f), format!("padding {f}"))
        });
    }
}
