//! Shared engine-run telemetry: what every AOT engine reports back to
//! the eval harness and benches.

use crate::coordinator::simtime::VirtualClock;
use crate::kmeans::KmeansResult;

/// Result + timing telemetry of one engine run.
#[derive(Debug, Clone)]
pub struct EngineRun {
    /// The clustering itself (same shape every engine returns).
    pub result: KmeansResult,
    /// One-time setup: client creation + artifact compilation + data
    /// upload. Reported separately — the paper times the algorithm, and
    /// AOT compilation is a build-time analog.
    pub setup_secs: f64,
    /// Real measured wall-clock of the iteration loop on this container.
    pub wall_secs: f64,
    /// Virtual testbed clock (DESIGN.md §8); `None` for engines that
    /// report only real time (e.g. offload with device parallelism 1).
    pub virtual_clock: Option<VirtualClock>,
    /// Executable calls made (telemetry for the A1 chunk ablation).
    pub exec_calls: usize,
}

impl EngineRun {
    /// The time used in paper-table comparisons: virtual testbed total
    /// when simulated, otherwise real wall-clock.
    pub fn table_secs(&self) -> f64 {
        self.virtual_clock
            .as_ref()
            .map(VirtualClock::total)
            .unwrap_or(self.wall_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_result() -> KmeansResult {
        KmeansResult {
            centroids: vec![0.0; 4],
            assign: vec![0, 1],
            k: 2,
            dim: 2,
            iterations: 1,
            sse: 0.0,
            shift: 0.0,
            converged: true,
            history: vec![],
            empty_events: vec![],
            pruning: None,
        }
    }

    #[test]
    fn table_secs_prefers_virtual() {
        let mut vc = VirtualClock::default();
        vc.push_iteration(&[0.5], 0.1);
        let run = EngineRun {
            result: dummy_result(),
            setup_secs: 9.0,
            wall_secs: 2.0,
            virtual_clock: Some(vc),
            exec_calls: 3,
        };
        assert!((run.table_secs() - 0.6).abs() < 1e-12);
        let raw = EngineRun { virtual_clock: None, ..run };
        assert_eq!(raw.table_secs(), 2.0);
    }
}
