//! The paper's coordination contribution, over the AOT runtime.
//!
//! Two engines share one compiled compute core (DESIGN.md §2):
//!
//! - [`shared`] — the OpenMP model: the dataset is sharded across `p`
//!   workers; each worker streams its shard through the
//!   `assign_partial` executable and produces local statistics; the
//!   leader merges them (barrier + critical-section analog) and
//!   finalizes the centroids.
//! - [`offload`] — the OpenACC model: the whole dataset streams through
//!   the `fused_step` executable with device-resident accumulators;
//!   the host only shuttles centroids and checks convergence
//!   (per-iteration fork/join onto the device).
//!
//! [`streaming`] extends the offload model out of core: it pulls a
//! `.pkd` file through the same executables chunk by chunk, keeping
//! O(chunk + K·d) host memory (its pure-rust, sharded counterpart over
//! any [`crate::data::DataSource`] is [`crate::kmeans::streaming`]).
//! [`plan`] maps rows onto workers and shape-specialized executable
//! calls; [`driver`] defines the [`EngineRun`] telemetry each engine
//! returns; [`simtime`] provides the simulated-testbed clock used to
//! report multi-core numbers from this 1-core container (DESIGN.md §8).

pub mod driver;
pub mod offload;
pub mod plan;
pub mod shared;
pub mod simtime;
pub mod streaming;

pub use driver::EngineRun;
