//! Device-offload engine — the paper's OpenACC model over the AOT
//! runtime.
//!
//! Structure (paper §"Using OpenACC"):
//! - per Lloyd iteration the host forks work onto the device — here a
//!   sequence of `fused_step` executions whose accumulators
//!   (sums/counts/SSE) thread through the calls, the device-side
//!   reduction replacing OpenACC's `atomic`/`reduction` clauses;
//! - the `finalize` executable recomputes centroids on device;
//! - the host only uploads the (tiny) centroid buffer each iteration,
//!   checks E < tol, and loops — constant fork/de-fork, unlike the
//!   spawn-once shared engine.
//!
//! X chunks are uploaded once at setup (`acc data copyin` analog).
//!
//! **Device clock.** The paper's device is a GPU; this container's is
//! one XLA-CPU core. Symmetric with the shared engine's thread testbed
//! (DESIGN.md §8), the engine reports a *virtual device clock*: each
//! chunk call's measured wall time decomposes into launch overhead
//! (calibrated from the tiny `finalize` executable, which is ~pure
//! overhead) plus compute, and compute is scaled by
//! `PARAKM_DEVICE_PARALLELISM` (default 16 — a modest accelerator; 1
//! disables the model). Raw wall-clock is always recorded alongside.

use std::time::Instant;

use crate::config::RunConfig;
use crate::coordinator::driver::EngineRun;
use crate::coordinator::plan::chunk_calls;
use crate::coordinator::simtime::VirtualClock;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::init;
use crate::kmeans::KmeansResult;
use crate::runtime::manifest::ExecKind;
use crate::runtime::{Runtime, TensorArg};

/// Device-parallelism factor for the virtual device clock (see module
/// docs). Read from `PARAKM_DEVICE_PARALLELISM`; default 16; `1`
/// disables the model (raw wall-clock only).
pub fn device_parallelism() -> f64 {
    std::env::var("PARAKM_DEVICE_PARALLELISM")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .filter(|&v| v >= 1.0)
        .unwrap_or(16.0)
}

/// Run the offload engine (fresh runtime; compilation counts toward
/// setup).
pub fn run(ds: &Dataset, cfg: &RunConfig) -> Result<EngineRun> {
    let mut rt = Runtime::new_or_native(&cfg.artifacts_dir)?;
    run_with(&mut rt, ds, cfg)
}

/// Run against a caller-owned [`Runtime`] (compiled-executable reuse
/// across eval/bench sweeps — see `shared::run_with`).
pub fn run_with(rt: &mut Runtime, ds: &Dataset, cfg: &RunConfig) -> Result<EngineRun> {
    cfg.validate()?;
    cfg.pin_kernel()?;
    let d = ds.dim();
    let k = cfg.k;
    let n = ds.len();
    if n == 0 {
        return Err(Error::Shape("empty dataset".into()));
    }

    // ---- setup ----------------------------------------------------------
    let t_setup = Instant::now();
    let sizes = crate::coordinator::shared::resolve_chunk_sizes(
        rt,
        ExecKind::FusedStats,
        d,
        k,
        cfg.chunk,
    )?;
    let mut specs = std::collections::HashMap::new();
    let mut assign_specs = std::collections::HashMap::new();
    for &s in &sizes {
        let spec = rt.find(ExecKind::FusedStats, d, k, s)?;
        rt.prepare(&spec)?;
        specs.insert(s, spec);
        let aspec = rt.find(ExecKind::Assign, d, k, s)?;
        rt.prepare(&aspec)?;
        assign_specs.insert(s, aspec);
    }
    let spec_fin = rt.find(ExecKind::Finalize, d, k, 0)?;
    rt.prepare(&spec_fin)?;

    let calls = chunk_calls(0, n, &sizes);
    let mut x_bufs = Vec::with_capacity(calls.len());
    let mut nv_bufs = Vec::with_capacity(calls.len());
    for call in &calls {
        let rows = ds.rows(call.lo, call.hi);
        let buf = if call.padding() == 0 {
            rt.upload_f32(rows, &[call.chunk, d])?
        } else {
            let mut pad_buf = vec![0.0f32; call.chunk * d];
            pad_buf[..rows.len()].copy_from_slice(rows);
            rt.upload_f32(&pad_buf, &[call.chunk, d])?
        };
        x_bufs.push(buf);
        nv_bufs.push(rt.upload_i32(&[call.n_valid() as i32], &[1])?);
    }
    let mut centroids = init::initialize(ds, k, cfg.init, cfg.seed);

    // calibrate launch overhead: the finalize executable's compute is
    // negligible (k×d elements), so its call time ≈ pure PJRT dispatch
    // + output-tuple fetch
    let dev_par = device_parallelism();
    let t_launch = {
        let zs = vec![0.0f32; k * d];
        let zc = vec![0.0f32; k];
        let args = [
            TensorArg::F32(&zs),
            TensorArg::F32(&zc),
            TensorArg::F32(&centroids),
        ];
        rt.execute(&spec_fin, &args)?; // warmup
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            rt.execute(&spec_fin, &args)?;
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    let setup_secs = t_setup.elapsed().as_secs_f64();

    // ---- iteration loop --------------------------------------------------
    let t_loop = Instant::now();
    let mut assign = vec![-1i32; n];
    let mut history = Vec::new();
    let mut vclock = VirtualClock::default();
    let mut converged = false;
    let mut iterations = 0usize;
    let mut exec_calls = 0usize;
    let zero_sums = vec![0.0f32; k * d];
    let zero_counts = vec![0.0f32; k];
    let zero_sse = vec![0.0f32; 1];
    let mut sse = f64::NAN;

    for _ in 0..cfg.max_iters {
        let mu_buf = rt.upload_f32(&centroids, &[k, d])?;
        // accumulators start zeroed each iteration; they round-trip
        // host<->device between chunk calls because the tuple output
        // forces a host copy anyway (k·d + k + 1 floats — negligible)
        let mut acc_sums = zero_sums.clone();
        let mut acc_counts = zero_counts.clone();
        let mut acc_sse = zero_sse.clone();

        let mut iter_device = 0.0f64; // virtual device time this iteration
        for (ci, call) in calls.iter().enumerate() {
            let sums_b = rt.upload_f32(&acc_sums, &[k, d])?;
            let counts_b = rt.upload_f32(&acc_counts, &[k])?;
            let sse_b = rt.upload_f32(&acc_sse, &[1])?;
            let t_call = Instant::now();
            let outs = rt.execute_buffers(
                &specs[&call.chunk],
                &[&x_bufs[ci], &mu_buf, &sums_b, &counts_b, &sse_b, &nv_bufs[ci]],
            )?;
            let wall = t_call.elapsed().as_secs_f64();
            let compute = (wall - t_launch).max(0.0);
            iter_device += t_launch + compute / dev_par;
            exec_calls += 1;

            acc_sums = outs[0].as_f32().to_vec();
            acc_counts = outs[1].as_f32().to_vec();
            acc_sse = outs[2].as_f32().to_vec();
        }

        let outs = rt.execute(
            &spec_fin,
            &[
                TensorArg::F32(&acc_sums),
                TensorArg::F32(&acc_counts),
                TensorArg::F32(&centroids),
            ],
        )?;
        exec_calls += 1;
        centroids = outs[0].as_f32().to_vec();
        let shift = outs[1].as_f32()[0] as f64;
        sse = acc_sse[0] as f64;
        iterations += 1;
        history.push((sse, shift));
        // finalize call: pure launch overhead on the virtual device
        vclock.push_iteration(&[iter_device], t_launch);
        if shift < cfg.tol {
            converged = true;
            break;
        }
    }

    // final assignment pass against the converged centroids (the
    // iteration loop moves only statistics — §Perf L2-1)
    {
        let mu_buf = rt.upload_f32(&centroids, &[k, d])?;
        let mut final_device = 0.0f64;
        for (ci, call) in calls.iter().enumerate() {
            let t_call = Instant::now();
            let outs = rt.execute_buffers(
                &assign_specs[&call.chunk],
                &[&x_bufs[ci], &mu_buf, &nv_bufs[ci]],
            )?;
            let wall = t_call.elapsed().as_secs_f64();
            final_device += t_launch + (wall - t_launch).max(0.0) / dev_par;
            exec_calls += 1;
            let a = outs[0].as_i32();
            assign[call.lo..call.hi].copy_from_slice(&a[..call.n_valid()]);
        }
        vclock.push_iteration(&[final_device], 0.0);
    }
    let wall_secs = t_loop.elapsed().as_secs_f64();

    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    Ok(EngineRun {
        result: KmeansResult {
            centroids,
            assign,
            k,
            dim: d,
            iterations,
            sse,
            shift,
            converged,
            history,
            empty_events: Vec::new(),
            pruning: None,
        },
        setup_secs,
        wall_secs,
        virtual_clock: (dev_par > 1.0).then_some(vclock),
        exec_calls,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::MixtureSpec;
    use crate::kmeans::{serial, KmeansConfig};

    fn artifacts_ready() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    fn cfg(k: usize, chunk: usize) -> RunConfig {
        RunConfig {
            k,
            chunk,
            artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts"),
            ..Default::default()
        }
    }

    #[test]
    fn matches_pure_rust_serial() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(35_000, 11);
        let c = cfg(4, 16384);
        let run1 = run(&ds, &c).unwrap();
        let kc = KmeansConfig::new(4).with_seed(c.seed);
        let mu0 = crate::kmeans::init::initialize(&ds, 4, c.init, c.seed);
        let reference = serial::run_from(&ds, &kc, &mu0);
        assert_eq!(run1.result.iterations, reference.iterations);
        let ari = crate::metrics::adjusted_rand_index(&run1.result.assign, &reference.assign);
        assert!(ari > 0.9999, "ari {ari}");
    }

    /// Offload and shared engines implement the same math — identical
    /// clustering from identical init, regardless of coordination model.
    #[test]
    fn matches_shared_engine() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(25_000, 13);
        let c = cfg(4, 16384);
        let off = run(&ds, &c).unwrap();
        let sh = crate::coordinator::shared::run(&ds, &c, 4).unwrap();
        assert_eq!(off.result.assign, sh.result.assign);
        assert_eq!(off.result.iterations, sh.result.iterations);
        for (x, y) in off.result.centroids.iter().zip(&sh.result.centroids) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn device_clock_scales_compute_not_launch() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(30_000, 21);
        let r = run(&ds, &cfg(4, 0)).unwrap();
        let vc = r.virtual_clock.as_ref().expect("device clock on by default");
        // +1: the post-convergence assignment pass is accounted too
        assert_eq!(vc.iterations(), r.result.iterations + 1);
        // virtual device time must be below raw wall (compute scaled
        // down) but nonzero (launch overhead preserved)
        assert!(vc.total() > 0.0);
        assert!(vc.total() < r.wall_secs, "virtual {} !< wall {}", vc.total(), r.wall_secs);
        // disabling the model drops the clock
        std::env::set_var("PARAKM_DEVICE_PARALLELISM", "1");
        let raw = run(&ds, &cfg(4, 0)).unwrap();
        std::env::remove_var("PARAKM_DEVICE_PARALLELISM");
        assert!(raw.virtual_clock.is_none());
        assert_eq!(raw.result.assign, r.result.assign);
    }

    #[test]
    fn history_sse_monotone() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(20_000, 17);
        let r = run(&ds, &cfg(4, 16384)).unwrap();
        for w in r.result.history.windows(2) {
            assert!(w[1].0 <= w[0].0 * (1.0 + 1e-5), "sse increased {w:?}");
        }
        assert!(r.result.converged);
        assert!(r.exec_calls > 0);
    }
}
