//! Shared-memory engine — the paper's OpenMP model over the AOT
//! runtime.
//!
//! Leader/worker structure (paper §"Using OpenMP"):
//! - the dataset is sharded contiguously across `p` workers
//!   ([`crate::coordinator::plan`]);
//! - every iteration each worker streams its shard's chunks through
//!   the `stats_partial` executable and accumulates *local* stats
//!   (assignments are materialized once, after convergence, by the
//!   `assign` program — §Perf L2-1);
//! - the leader merges the locals (the `critical`/barrier step) and
//!   recomputes centroids through the `finalize` executable;
//! - iterate until E = Σ‖μ^{t+1} − μ^t‖² < tol.
//!
//! X chunks are uploaded to the device once at setup (the OpenACC
//! `data copyin` analog also used here — only centroids move per
//! iteration). On this 1-core container workers execute sequentially
//! and a [`VirtualClock`] accounts the p-way concurrency
//! (DESIGN.md §8); `worker_busy` is real measured compute per shard.

use std::time::Instant;

use crate::config::RunConfig;
use crate::coordinator::driver::EngineRun;
use crate::coordinator::plan::ShardPlan;
use crate::coordinator::simtime::{self, SyncModel, VirtualClock};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::init;
use crate::kmeans::step::PartialStats;
use crate::kmeans::KmeansResult;
use crate::runtime::manifest::ExecKind;
use crate::runtime::{Runtime, TensorArg};

/// How worker partials reach the leader (cost model for the A2
/// ablation; numerically identical either way).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergePolicy {
    Leader,
    Critical,
}

/// Run the shared-memory engine with `p` workers.
pub fn run(ds: &Dataset, cfg: &RunConfig, p: usize) -> Result<EngineRun> {
    run_opts(ds, cfg, p, MergePolicy::Leader)
}

/// Run with an explicit merge policy (fresh runtime; compilation counts
/// toward setup).
pub fn run_opts(
    ds: &Dataset,
    cfg: &RunConfig,
    p: usize,
    policy: MergePolicy,
) -> Result<EngineRun> {
    let mut rt = Runtime::new_or_native(&cfg.artifacts_dir)?;
    run_with(&mut rt, ds, cfg, p, policy)
}

/// Run against a caller-owned [`Runtime`], reusing its compiled
/// executables across runs (the eval harness and benches sweep dozens
/// of (N, p) cells — recompiling per cell would swamp the measurement).
pub fn run_with(
    rt: &mut Runtime,
    ds: &Dataset,
    cfg: &RunConfig,
    p: usize,
    policy: MergePolicy,
) -> Result<EngineRun> {
    cfg.validate()?;
    cfg.pin_kernel()?;
    let d = ds.dim();
    let k = cfg.k;
    let n = ds.len();
    if n == 0 {
        return Err(Error::Shape("empty dataset".into()));
    }
    let p = p.max(1).min(n);

    // ---- setup (reported separately; includes compilation only when
    // this runtime sees the executables for the first time) ---------------
    let t_setup = Instant::now();
    // chunk = 0 -> auto: use every available size for this (d, k) so the
    // planner can fit shards with bounded padding (plan.rs docs)
    let sizes = resolve_chunk_sizes(rt, ExecKind::StatsPartial, d, k, cfg.chunk)?;
    let mut specs = std::collections::HashMap::new();
    let mut assign_specs = std::collections::HashMap::new();
    for &s in &sizes {
        let spec = rt.find(ExecKind::StatsPartial, d, k, s)?;
        rt.prepare(&spec)?;
        specs.insert(s, spec);
        let aspec = rt.find(ExecKind::Assign, d, k, s)?;
        rt.prepare(&aspec)?;
        assign_specs.insert(s, aspec);
    }
    let spec_fin = rt.find(ExecKind::Finalize, d, k, 0)?;
    rt.prepare(&spec_fin)?;

    let plan = ShardPlan::new(n, p, &sizes);
    // upload every chunk once; tail chunks padded with zeros
    let mut x_bufs = Vec::with_capacity(plan.total_calls());
    let mut nv_bufs = Vec::with_capacity(plan.total_calls());
    for (_, calls) in &plan.shards {
        for call in calls {
            let rows = ds.rows(call.lo, call.hi);
            let buf = if call.padding() == 0 {
                rt.upload_f32(rows, &[call.chunk, d])?
            } else {
                let mut pad_buf = vec![0.0f32; call.chunk * d];
                pad_buf[..rows.len()].copy_from_slice(rows);
                rt.upload_f32(&pad_buf, &[call.chunk, d])?
            };
            x_bufs.push(buf);
            nv_bufs.push(rt.upload_i32(&[call.n_valid() as i32], &[1])?);
        }
    }
    let sync = simtime::calibrate(k, d);
    let mut centroids = init::initialize(ds, k, cfg.init, cfg.seed);
    let setup_secs = t_setup.elapsed().as_secs_f64();

    // ---- iteration loop -------------------------------------------------
    let t_loop = Instant::now();
    let mut assign = vec![-1i32; n];
    let mut history = Vec::new();
    let mut vclock = VirtualClock::default();
    let mut converged = false;
    let mut iterations = 0usize;
    let mut exec_calls = 0usize;
    let mut worker_busy = vec![0.0f64; p];
    let mut sse = f64::NAN;

    for _ in 0..cfg.max_iters {
        let mu_buf = rt.upload_f32(&centroids, &[k, d])?;
        let mut merged = PartialStats::zeros(k, d);
        let mut call_idx = 0usize;

        for (w, ((_, _), calls)) in plan.shards.iter().enumerate() {
            let t_w = Instant::now();
            let mut local = PartialStats::zeros(k, d);
            for call in calls {
                // stats-only program: the per-call fetch is a few
                // hundred bytes; assignments come from the one
                // post-convergence pass below (§Perf L2-1)
                let outs = rt.execute_buffers(
                    &specs[&call.chunk],
                    &[&x_bufs[call_idx], &mu_buf, &nv_bufs[call_idx]],
                )?;
                call_idx += 1;
                exec_calls += 1;
                let sums = outs[0].as_f32();
                let counts = outs[1].as_f32();
                for i in 0..k * d {
                    local.sums[i] += sums[i] as f64;
                }
                for c in 0..k {
                    local.counts[c] += counts[c] as u64;
                }
                local.sse += outs[2].as_f32()[0] as f64;
            }
            worker_busy[w] = t_w.elapsed().as_secs_f64();
            merged.merge(&local);
        }

        // leader: finalize through the AOT executable
        let sums_f32: Vec<f32> = merged.sums.iter().map(|&v| v as f32).collect();
        let counts_f32: Vec<f32> = merged.counts.iter().map(|&v| v as f32).collect();
        let outs = rt.execute(
            &spec_fin,
            &[
                TensorArg::F32(&sums_f32),
                TensorArg::F32(&counts_f32),
                TensorArg::F32(&centroids),
            ],
        )?;
        exec_calls += 1;
        centroids = outs[0].as_f32().to_vec();
        let shift = outs[1].as_f32()[0] as f64;
        sse = merged.sse;
        iterations += 1;
        history.push((sse, shift));

        let overhead = match policy {
            MergePolicy::Leader => sync.leader_overhead(p),
            MergePolicy::Critical => sync.critical_overhead(p),
        };
        vclock.push_iteration(&worker_busy[..p], overhead);

        if shift < cfg.tol {
            converged = true;
            break;
        }
    }

    // final assignment pass (one per run, against the converged
    // centroids) — the iteration loop moves only statistics
    let mu_buf = rt.upload_f32(&centroids, &[k, d])?;
    let mut call_idx = 0usize;
    for (w, ((_, _), calls)) in plan.shards.iter().enumerate() {
        let t_w = Instant::now();
        for call in calls {
            let outs = rt.execute_buffers(
                &assign_specs[&call.chunk],
                &[&x_bufs[call_idx], &mu_buf, &nv_bufs[call_idx]],
            )?;
            call_idx += 1;
            exec_calls += 1;
            let a = outs[0].as_i32();
            assign[call.lo..call.hi].copy_from_slice(&a[..call.n_valid()]);
        }
        worker_busy[w] = t_w.elapsed().as_secs_f64();
    }
    vclock.push_iteration(&worker_busy[..p], sync.leader_overhead(p));
    let wall_secs = t_loop.elapsed().as_secs_f64();

    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    Ok(EngineRun {
        result: KmeansResult {
            centroids,
            assign,
            k,
            dim: d,
            iterations,
            sse,
            shift,
            converged,
            history,
            empty_events: Vec::new(),
            pruning: None,
        },
        setup_secs,
        wall_secs,
        virtual_clock: Some(vclock),
        exec_calls,
    })
}

/// Expose the calibrated model (used by benches to report the overhead
/// terms alongside the tables).
pub fn calibrated_model(k: usize, d: usize) -> SyncModel {
    simtime::calibrate(k, d)
}

/// Chunk sizes the planner may use: the single configured size, or
/// (when `configured == 0`) every size the manifest provides for this
/// (kind, d, k).
pub(crate) fn resolve_chunk_sizes(
    rt: &Runtime,
    kind: ExecKind,
    d: usize,
    k: usize,
    configured: usize,
) -> crate::error::Result<Vec<usize>> {
    if configured != 0 {
        return Ok(vec![configured]);
    }
    if rt.is_native_fallback() {
        // native executor: any chunk executes; offer the standard ladder
        return Ok(crate::runtime::native::CHUNKS.to_vec());
    }
    let mut sizes: Vec<usize> = rt
        .manifest()
        .variants(kind)
        .into_iter()
        .filter(|&(vd, vk, _)| vd == d && vk == k)
        .map(|(_, _, c)| c)
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    if sizes.is_empty() {
        return Err(Error::Manifest(format!(
            "no {kind:?} artifacts for d={d} k={k}"
        )));
    }
    Ok(sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::MixtureSpec;
    use crate::kmeans::{serial, KmeansConfig};

    fn artifacts_ready() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    fn cfg(k: usize, chunk: usize) -> RunConfig {
        RunConfig {
            k,
            chunk,
            artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts"),
            ..Default::default()
        }
    }

    /// The AOT shared engine must agree with pure-rust serial Lloyd
    /// from the same init (same algorithm, different substrate).
    #[test]
    fn matches_pure_rust_serial() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // 16384-chunk artifact exists for (3, 4); n chosen to force a
        // padded tail chunk and ragged shards
        let ds = MixtureSpec::paper_3d(4).generate(40_001, 3);
        let c = cfg(4, 16384);
        let run1 = run(&ds, &c, 4).unwrap();
        let kc = KmeansConfig::new(4).with_seed(c.seed);
        let mu0 = crate::kmeans::init::initialize(&ds, 4, c.init, c.seed);
        let reference = serial::run_from(&ds, &kc, &mu0);

        assert_eq!(run1.result.iterations, reference.iterations);
        assert!(run1.result.converged);
        let ari = crate::metrics::adjusted_rand_index(&run1.result.assign, &reference.assign);
        assert!(ari > 0.9999, "ari {ari}");
        let rel = (run1.result.sse - reference.sse).abs() / reference.sse;
        assert!(rel < 1e-4, "sse rel err {rel}");
    }

    #[test]
    fn worker_count_does_not_change_clustering() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(30_000, 5);
        let c = cfg(4, 16384);
        let a = run(&ds, &c, 1).unwrap();
        let b = run(&ds, &c, 8).unwrap();
        assert_eq!(a.result.iterations, b.result.iterations);
        assert_eq!(a.result.assign, b.result.assign);
        for (x, y) in a.result.centroids.iter().zip(&b.result.centroids) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn virtual_clock_populated_and_monotone_overhead() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_3d(4).generate(20_000, 7);
        let c = cfg(4, 16384);
        let r1 = run(&ds, &c, 2).unwrap();
        let vc = r1.virtual_clock.as_ref().unwrap();
        // +1: the post-convergence assignment pass is accounted too
        assert_eq!(vc.iterations(), r1.result.iterations + 1);
        assert!(vc.total() > 0.0);
        // critical policy must cost at least leader policy in sync time
        // (calibration is re-measured per run on a noisy 1-core box, so
        // allow generous slack; the exact inequality is unit-tested on
        // the model itself in simtime::tests)
        let r2 = run_opts(&ds, &c, 2, MergePolicy::Critical).unwrap();
        let s1: f64 = vc.iter_sync.iter().sum();
        let s2: f64 = r2.virtual_clock.as_ref().unwrap().iter_sync.iter().sum();
        assert!(s2 >= s1 * 0.3, "critical {s2} vs leader {s1}");
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ds = MixtureSpec::paper_2d(4).generate(100, 1);
        let mut c = cfg(7, 65536); // k=7 has no artifact
        c.max_iters = 1;
        match run(&ds, &c, 2) {
            Err(Error::Manifest(msg)) => assert!(msg.contains("k=7"), "{msg}"),
            other => panic!("expected manifest error, got {other:?}"),
        }
    }
}
