//! Log-bucketed per-request latency histogram for the serve path.
//!
//! One `u64` counter per power-of-two nanosecond bucket: a request that
//! took `ns` nanoseconds lands in bucket `⌈log2(ns+1)⌉` (bucket 0 holds
//! exactly 0 ns, bucket 1 holds 1 ns, bucket b holds `[2^(b-1), 2^b)`),
//! capped at bucket 63. Recording is a subtraction, a `leading_zeros`
//! and an increment — cheap enough to sit on every request in both
//! serve loops — and the fixed 64×8-byte footprint means the histogram
//! can live under the stats mutex without allocation.
//!
//! Quantiles are read back by cumulative count. A quantile is reported
//! as the arithmetic midpoint of the bucket it falls in, so p50/p90/p99
//! carry the usual log-bucket resolution (±~25%): good enough to spot
//! a shed tier engaging or a batch-delay regression, not a calibrated
//! microbenchmark — `benches/serving_load.rs` measures exact per-
//! request wall times when precision matters.

use std::time::Duration;

/// Number of power-of-two buckets (covers 0 ns ..= u64::MAX ns).
pub const BUCKETS: usize = 64;

/// Fixed-footprint log2-nanosecond latency histogram.
#[derive(Debug, Clone)]
pub struct LatencyHisto {
    counts: [u64; BUCKETS],
    total: u64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        LatencyHisto { counts: [0; BUCKETS], total: 0 }
    }
}

/// The quantile digest surfaced in `{"stats"}` responses and the CLI
/// summary (microseconds, bucket-midpoint resolution).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
}

fn bucket_of(ns: u64) -> usize {
    ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Arithmetic midpoint of a bucket, in nanoseconds.
fn bucket_mid_ns(bucket: usize) -> f64 {
    if bucket == 0 {
        return 0.0;
    }
    let lo = 2f64.powi(bucket as i32 - 1);
    let hi = 2f64.powi(bucket as i32);
    (lo + hi) / 2.0
}

impl LatencyHisto {
    /// Record one request's wall time.
    pub fn record(&mut self, elapsed: Duration) {
        let ns = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        self.counts[bucket_of(ns)] += 1;
        self.total += 1;
    }

    /// Total number of recorded requests.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// The `q`-quantile (`0 < q <= 1`) in nanoseconds, at bucket
    /// resolution; 0.0 when nothing has been recorded.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_mid_ns(b);
            }
        }
        bucket_mid_ns(BUCKETS - 1)
    }

    /// p50/p90/p99 digest in microseconds.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.total,
            p50_us: self.quantile_ns(0.50) / 1_000.0,
            p90_us: self.quantile_ns(0.90) / 1_000.0,
            p99_us: self.quantile_ns(0.99) / 1_000.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2_ns() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHisto::default();
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
    }

    #[test]
    fn quantiles_are_ordered_and_bucket_resolved() {
        let mut h = LatencyHisto::default();
        // 90 fast requests (~1 µs), 9 medium (~100 µs), 1 slow (~10 ms)
        for _ in 0..90 {
            h.record(Duration::from_micros(1));
        }
        for _ in 0..9 {
            h.record(Duration::from_micros(100));
        }
        h.record(Duration::from_millis(10));
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!(s.p50_us <= s.p90_us && s.p90_us <= s.p99_us);
        // p50 sits in the ~1 µs bucket, p99 in the ~100 µs bucket
        // (log-bucket midpoints, so compare within a factor of 2)
        assert!(s.p50_us >= 0.5 && s.p50_us <= 2.0, "p50={}", s.p50_us);
        assert!(s.p99_us >= 64.0 && s.p99_us <= 256.0, "p99={}", s.p99_us);
    }

    #[test]
    fn single_sample_dominates_every_quantile() {
        let mut h = LatencyHisto::default();
        h.record(Duration::from_nanos(500));
        let s = h.summary();
        assert_eq!(s.count, 1);
        assert_eq!(s.p50_us, s.p99_us);
        assert!(s.p50_us > 0.0);
    }
}
