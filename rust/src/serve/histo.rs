//! Log-bucketed per-request latency histogram for the serve path.
//!
//! A thin serve-flavored wrapper over the generalized log₂ histogram
//! in [`crate::util::trace::Log2Histo`] (one `u64` counter per
//! power-of-two nanosecond bucket; bucket 0 holds exactly 0 ns, bucket
//! `b` holds `[2^(b-1), 2^b)`, bucket 63 saturates as the explicit
//! overflow bucket). Recording is a subtraction, a `leading_zeros` and
//! an increment — cheap enough to sit on every request in both serve
//! loops — and the fixed 64×8-byte footprint means the histogram can
//! live under the stats mutex without allocation.
//!
//! Quantiles interpolate linearly within a bucket by rank position, so
//! sub-µs latency distributions resolve instead of collapsing to a
//! bucket constant (the pre-interpolation midpoint rule reported the
//! same value for p50 and p99 whenever both ranks shared a bucket).
//! Still log-bucket resolution (±~25%), not a calibrated
//! microbenchmark — `benches/serving_load.rs` measures exact per-
//! request wall times when precision matters.

use std::time::Duration;

use crate::util::trace::Log2Histo;

/// Number of power-of-two buckets (covers 0 ns ..= u64::MAX ns).
pub const BUCKETS: usize = crate::util::trace::HISTO_BUCKETS;

/// Fixed-footprint log2-nanosecond latency histogram.
#[derive(Debug, Clone, Default)]
pub struct LatencyHisto {
    inner: Log2Histo,
}

/// The quantile digest surfaced in `{"stats"}` responses and the CLI
/// summary (microseconds, interpolated log-bucket resolution).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
}

impl LatencyHisto {
    /// Record one request's wall time.
    pub fn record(&mut self, elapsed: Duration) {
        let ns = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        self.inner.record(ns);
    }

    /// Total number of recorded requests.
    pub fn count(&self) -> u64 {
        self.inner.count()
    }

    /// The `q`-quantile (`0 < q <= 1`) in nanoseconds, interpolated
    /// within its bucket; 0.0 when nothing has been recorded.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        self.inner.quantile_ns(q)
    }

    /// p50/p90/p99 digest in microseconds.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count(),
            p50_us: self.quantile_ns(0.50) / 1_000.0,
            p90_us: self.quantile_ns(0.90) / 1_000.0,
            p99_us: self.quantile_ns(0.99) / 1_000.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::trace::OVERFLOW_BUCKET;

    fn bucket_of(ns: u64) -> usize {
        Log2Histo::bucket_of(ns)
    }

    #[test]
    fn buckets_are_log2_ns() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHisto::default();
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
    }

    #[test]
    fn quantiles_are_ordered_and_bucket_resolved() {
        let mut h = LatencyHisto::default();
        // 90 fast requests (~1 µs), 9 medium (~100 µs), 1 slow (~10 ms)
        for _ in 0..90 {
            h.record(Duration::from_micros(1));
        }
        for _ in 0..9 {
            h.record(Duration::from_micros(100));
        }
        h.record(Duration::from_millis(10));
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!(s.p50_us <= s.p90_us && s.p90_us <= s.p99_us);
        // p50 sits in the ~1 µs bucket, p99 in the ~100 µs bucket
        // (log buckets, so compare within a factor of 2)
        assert!(s.p50_us >= 0.5 && s.p50_us <= 2.0, "p50={}", s.p50_us);
        assert!(s.p99_us >= 64.0 && s.p99_us <= 256.0, "p99={}", s.p99_us);
    }

    #[test]
    fn single_sample_dominates_every_quantile() {
        let mut h = LatencyHisto::default();
        h.record(Duration::from_nanos(500));
        let s = h.summary();
        assert_eq!(s.count, 1);
        assert_eq!(s.p50_us, s.p99_us);
        assert!(s.p50_us > 0.0);
    }

    #[test]
    fn sub_microsecond_quantiles_interpolate_not_collapse() {
        // the satellite fix: 100 samples spread across one bucket
        // [512, 1024) used to report p50 == p99 == the bucket midpoint;
        // rank interpolation must separate them
        let mut h = LatencyHisto::default();
        for i in 0..100u64 {
            h.record(Duration::from_nanos(600 + i));
        }
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 < p99, "p50 {p50} must interpolate below p99 {p99}");
        assert!((512.0..1024.0).contains(&p50), "{p50}");
        assert!((512.0..1024.0).contains(&p99), "{p99}");
    }

    #[test]
    fn overflow_saturates_to_the_last_bucket_bound() {
        let mut h = LatencyHisto::default();
        h.record(Duration::from_secs(u64::MAX / 2_000_000_000));
        let s = h.summary();
        assert_eq!(s.count, 1);
        assert_eq!(bucket_of(u64::MAX), OVERFLOW_BUCKET);
        // the overflow bucket reports its lower bound (2^62 ns), a
        // stated saturation rather than a fabricated midpoint
        assert_eq!(h.quantile_ns(0.5), (1u64 << 62) as f64);
        assert_eq!(h.quantile_ns(0.99), (1u64 << 62) as f64);
    }
}
