//! Serving mode: a clustering inference service over the AOT runtime.
//!
//! After a model is trained (centroids fixed), `parakm serve` exposes
//! nearest-centroid assignment as a network service — the
//! production-facing face of the paper's system (cluster-membership
//! lookup is how segmentation/anomaly pipelines consume K-Means).
//!
//! Architecture (single-node analog of a vLLM-style router):
//!
//! ```text
//! TCP clients ── line-JSON ──► front end ──────────► bounded queue
//!                  │                                  │ (backpressure
//!   --serve-loop poll   : one poll(2) reactor thread  │  + shed tiers)
//!   --serve-loop threads: thread per connection       │
//!                            ┌────────────────────────▼───────────┐
//!                            │ batcher: drain up to `max_batch`   │
//!                            │ or wait `max_delay` — then one     │
//!                            │ padded AOT `assign` call           │
//!                            └────────────────────────┬───────────┘
//!                         responses routed back per request
//!                         (reply channel / completion + waker)
//! ```
//!
//! The front end is pluggable ([`ServeLoop`]): the default on unix is
//! the event-driven [`poll`] reactor — one thread, nonblocking sockets,
//! per-connection buffers, requests parsed by the SIMD tape scanner
//! ([`scan`]) — with the thread-per-connection loop kept as the
//! portable fallback and the cross-check baseline (both loops answer
//! byte-identically; CI diffs them). The batcher owns the
//! [`crate::runtime::Runtime`] and lives on one dedicated thread (the
//! PJRT-era contract — a real PJRT client is not `Send`; the native
//! executor keeps the same single-owner shape). No tokio in the
//! offline image (DESIGN.md §8, "Offline-image constraints"): the
//! reactor is hand-rolled over `poll(2)` + `std`.
//!
//! Observability: any connection may send `{"stats": true}` and gets
//! the live [`ServeStats`] counters — batcher mirror, shed/saturation/
//! oversize rejections and the log-bucketed latency digest
//! ([`histo::LatencyHisto`]) — back as one JSON line ([`stats_line`]),
//! answered inline so the probe stays responsive whatever the batcher
//! is doing. Models trained elsewhere load via `parakm serve --model
//! model.pkm` ([`crate::data::io::read_model`]) instead of retraining
//! at startup. DESIGN.md §13 covers the event loop, the tape-scanner
//! equivalence contract and the shed tiers.

pub mod batcher;
pub mod histo;
#[cfg(unix)]
pub mod poll;
pub mod protocol;
pub mod reply;
pub mod scan;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, BatcherStats, ModelSlot};
pub use histo::{LatencyHisto, LatencySummary};
pub use protocol::{
    health_line, reload_line, stats_line, ClientRequest, Request, Response, ServeStats,
    ERR_LINE_TOO_LONG, ERR_NOT_UTF8, ERR_RELOAD, ERR_RETRY, ERR_SATURATED, ERR_SHED_HEAVY,
    ERR_SHED_LOAD,
};
pub use reply::{Completion, ReplySink, Waker};
pub use scan::{parse_tape, parse_tape_tier, scan_tape, structural_offsets, Tape};
pub use server::{
    reload_model, serve, Lifecycle, ServeConfig, ServeLoop, ServeShared, ServerHandle, ShedConfig,
};
