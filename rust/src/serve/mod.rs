//! Serving mode: a clustering inference service over the AOT runtime.
//!
//! After a model is trained (centroids fixed), `parakm serve` exposes
//! nearest-centroid assignment as a network service — the
//! production-facing face of the paper's system (cluster-membership
//! lookup is how segmentation/anomaly pipelines consume K-Means).
//!
//! Architecture (single-node analog of a vLLM-style router):
//!
//! ```text
//! TCP clients ── line-JSON ──► acceptor threads ─► bounded queue
//!                                                   │ (backpressure)
//!                            ┌──────────────────────▼─────────────┐
//!                            │ batcher: drain up to `max_batch`   │
//!                            │ or wait `max_delay` — then one     │
//!                            │ padded AOT `assign` call           │
//!                            └──────────────────────┬─────────────┘
//!                              responses routed back per request
//! ```
//!
//! The batcher owns the [`crate::runtime::Runtime`] and lives on one
//! dedicated thread (the PJRT-era contract — a real PJRT client is not
//! `Send`; the native executor keeps the same single-owner shape).
//! Acceptors communicate via `mpsc`. No tokio in the offline image
//! (DESIGN.md §8, "Offline-image constraints"): blocking IO + threads,
//! which is also the right shape for a CPU backend.
//!
//! Observability: any connection may send `{"stats": true}` and gets
//! the live [`BatcherStats`] counters plus the acceptor's saturation-
//! rejection count back as one JSON line ([`stats_line`]) — answered
//! from the connection thread against a shared mirror, so the probe
//! stays responsive whatever the batcher is doing. Models trained
//! elsewhere load via `parakm serve --model model.pkm`
//! ([`crate::data::io::read_model`]) instead of retraining at startup.

pub mod batcher;
pub mod protocol;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, BatcherStats};
pub use protocol::{stats_line, ClientRequest, Request, Response, ERR_SATURATED};
pub use server::{serve, ServeConfig};
