//! Line-delimited JSON protocol for the assignment service.
//!
//! Request  : `{"id": 7, "points": [[x,y,z], ...]}`
//! Response : `{"id": 7, "clusters": [0, 2, ...], "distances": [..]}`
//! Error    : `{"id": 7, "error": "..."}`
//! Stats    : `{"stats": true}` → `{"stats": {"requests": .., ...}}`
//!
//! One JSON document per line; a connection may pipeline any number of
//! requests. The stats request returns a [`ServeStats`] snapshot —
//! batcher counters, acceptor/shed rejection counters and the latency
//! histogram digest ([`stats_line`]) — answered outside the batcher,
//! so it works even while the batcher is busy.
//!
//! Two parsing front ends share one extraction ([`ClientRequest::from_json`]):
//! [`ClientRequest::parse`] goes through the legacy byte-wise
//! [`crate::util::json`] parser (the threads loop / reference path),
//! and [`ClientRequest::parse_tape`] through the SIMD tape scanner in
//! [`crate::serve::scan`] (the poll loop). The two are answer-
//! equivalent on every input and kernel tier — the contract
//! `rust/tests/proptest_protocol.rs` enforces.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::linalg::kernel::KernelTier;
use crate::serve::batcher::BatcherStats;
use crate::serve::histo::LatencySummary;
use crate::serve::scan;
use crate::util::json::Json;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Row-major points, `dim` implied by the served model.
    pub points: Vec<Vec<f64>>,
}

impl Request {
    /// Parse one request line (legacy byte-wise parser).
    pub fn parse(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }

    /// Extract a request from an already parsed document — the one
    /// code path both parsing front ends funnel into.
    pub fn from_json(j: &Json) -> Result<Request> {
        let id = j
            .get("id")
            .and_then(Json::as_f64)
            .filter(|v| *v >= 0.0 && v.fract() == 0.0)
            .ok_or_else(|| Error::Config("request: missing numeric `id`".into()))? as u64;
        let points = j
            .arr_field("points")
            .map_err(|_| Error::Config("request: missing `points` array".into()))?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| Error::Config("request: point must be an array".into()))?
                    .iter()
                    .map(|v| {
                        v.as_f64().ok_or_else(|| {
                            Error::Config("request: point coordinate must be a number".into())
                        })
                    })
                    .collect::<Result<Vec<f64>>>()
            })
            .collect::<Result<Vec<Vec<f64>>>>()?;
        if points.is_empty() {
            return Err(Error::Config("request: empty `points`".into()));
        }
        Ok(Request { id, points })
    }
}

/// Any line a client may send: an assignment request, the
/// observability probe `{"stats": true}`, the metrics-registry dump
/// `{"metrics": true}` (JSON) / `{"metrics": "text"}` (Prometheus
/// exposition text), the liveness/readiness probe `{"health": true}`,
/// or the model hot-reload command `{"reload": "path/to/model.pkm"}`.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientRequest {
    Assign(Request),
    Stats,
    Metrics {
        /// Prometheus text exposition instead of one JSON line.
        text: bool,
    },
    /// Live/ready probe — answered outside the batcher so it keeps
    /// working while the batcher is down or restarting.
    Health,
    /// Hot-swap the served model to the `.pkm` file at `path`.
    Reload { path: String },
}

impl ClientRequest {
    /// Parse one request line through the legacy byte-wise parser;
    /// `{"stats": true}` routes to [`ClientRequest::Stats`], everything
    /// else through [`Request::from_json`].
    pub fn parse(line: &str) -> Result<ClientRequest> {
        ClientRequest::from_json(&Json::parse(line)?)
    }

    /// Parse through the SIMD tape scanner on the process-global kernel
    /// tier — the poll loop's front end. Answer-equivalent to
    /// [`ClientRequest::parse`] (same extraction, equivalent parser).
    pub fn parse_tape(line: &str) -> Result<ClientRequest> {
        ClientRequest::from_json(&scan::parse_tape(line)?)
    }

    /// [`ClientRequest::parse_tape`] with an explicit tier (tests).
    pub fn parse_tape_tier(line: &str, tier: KernelTier) -> Result<ClientRequest> {
        ClientRequest::from_json(&scan::parse_tape_tier(line, tier)?)
    }

    /// Route an already parsed document.
    pub fn from_json(j: &Json) -> Result<ClientRequest> {
        if j.get("stats").and_then(Json::as_bool) == Some(true) {
            return Ok(ClientRequest::Stats);
        }
        if j.get("metrics").and_then(Json::as_bool) == Some(true) {
            return Ok(ClientRequest::Metrics { text: false });
        }
        if j.get("metrics").and_then(Json::as_str) == Some("text") {
            return Ok(ClientRequest::Metrics { text: true });
        }
        if j.get("health").and_then(Json::as_bool) == Some(true) {
            return Ok(ClientRequest::Health);
        }
        if let Some(path) = j.get("reload").and_then(Json::as_str) {
            return Ok(ClientRequest::Reload { path: path.to_string() });
        }
        Request::from_json(j).map(ClientRequest::Assign)
    }
}

/// One coherent snapshot of everything the server counts: the
/// batcher's own counters plus the acceptor-side rejection tiers and
/// the latency histogram digest (all tracked outside the batcher).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    pub batcher: BatcherStats,
    /// Connections rejected at the accept tier (connection cap).
    pub saturated: u64,
    /// Heavy requests rejected at the queue-pressure (soft shed) tier.
    pub shed_heavy: u64,
    /// Requests rejected at the queue-full (hard shed) tier.
    pub shed_load: u64,
    /// Request lines rejected for exceeding the line-length bound.
    pub oversized: u64,
    /// Per-request latency digest (both serve loops record into it).
    pub latency: LatencySummary,
    /// Artifact CRC integrity warnings observed process-wide
    /// ([`crate::data::io::artifact_warnings`], sampled at snapshot
    /// time by the serve loop).
    pub artifact_warnings: u64,
    /// Keep-centroid (empty-cluster) events observed process-wide
    /// ([`crate::util::trace::empty_events_total`]).
    pub empty_events: u64,
    /// Generation of the currently served model: 1 for the model the
    /// server started with, bumped by each successful hot-reload.
    pub model_generation: u64,
    /// Times the supervisor restarted a dead/panicked batcher thread.
    pub batcher_restarts: u64,
    /// Human-readable reason for the most recent batcher restart
    /// (empty while the original batcher is still on its first life).
    pub batcher_last_restart: String,
    /// Is a batcher thread currently alive?
    pub batcher_up: bool,
    /// Is the server draining (SIGTERM received, no longer accepting)?
    pub draining: bool,
}

/// Render the stats response line (no trailing newline):
/// `{"stats": {"batches": .., "errors": .., "padded_rows": ..,
/// "points": .., "requests": .., "saturated": .., "shed_heavy": ..,
/// "shed_load": .., "oversized": .., "lat_count": ..,
/// "lat_p50_us": .., "lat_p90_us": .., "lat_p99_us": ..,
/// "artifact_warnings": .., "empty_events": ..}}`.
/// `batches` is the batcher's device-call count; the `lat_*` fields
/// carry the log-bucket histogram digest of
/// [`crate::serve::histo::LatencyHisto`].
pub fn stats_line(s: &ServeStats) -> String {
    let mut inner = BTreeMap::new();
    inner.insert("requests".to_string(), Json::Num(s.batcher.requests as f64));
    inner.insert("points".to_string(), Json::Num(s.batcher.points as f64));
    inner.insert("batches".to_string(), Json::Num(s.batcher.device_calls as f64));
    inner.insert("padded_rows".to_string(), Json::Num(s.batcher.padded_rows as f64));
    inner.insert("errors".to_string(), Json::Num(s.batcher.errors as f64));
    inner.insert("saturated".to_string(), Json::Num(s.saturated as f64));
    inner.insert("shed_heavy".to_string(), Json::Num(s.shed_heavy as f64));
    inner.insert("shed_load".to_string(), Json::Num(s.shed_load as f64));
    inner.insert("oversized".to_string(), Json::Num(s.oversized as f64));
    inner.insert("lat_count".to_string(), Json::Num(s.latency.count as f64));
    inner.insert("lat_p50_us".to_string(), Json::Num(s.latency.p50_us));
    inner.insert("lat_p90_us".to_string(), Json::Num(s.latency.p90_us));
    inner.insert("lat_p99_us".to_string(), Json::Num(s.latency.p99_us));
    inner.insert("artifact_warnings".to_string(), Json::Num(s.artifact_warnings as f64));
    inner.insert("empty_events".to_string(), Json::Num(s.empty_events as f64));
    inner.insert("model_generation".to_string(), Json::Num(s.model_generation as f64));
    inner.insert("batcher_restarts".to_string(), Json::Num(s.batcher_restarts as f64));
    inner.insert(
        "batcher_last_restart".to_string(),
        Json::Str(s.batcher_last_restart.clone()),
    );
    inner.insert("batcher_up".to_string(), Json::Bool(s.batcher_up));
    inner.insert("draining".to_string(), Json::Bool(s.draining));
    let mut obj = BTreeMap::new();
    obj.insert("stats".to_string(), Json::Obj(inner));
    Json::Obj(obj).to_string()
}

/// Render the `{"health": true}` response line (no trailing newline):
/// `{"health": {"live": true, "ready": .., "batcher_up": ..,
/// "draining": .., "model_generation": .., "batcher_restarts": ..}}`.
/// *live* means the serve loop answered at all; *ready* means the
/// server can currently make progress on assignment requests: batcher
/// thread up ∧ a model generation installed ∧ not draining.
pub fn health_line(s: &ServeStats) -> String {
    let ready = s.batcher_up && s.model_generation >= 1 && !s.draining;
    let mut inner = BTreeMap::new();
    inner.insert("live".to_string(), Json::Bool(true));
    inner.insert("ready".to_string(), Json::Bool(ready));
    inner.insert("batcher_up".to_string(), Json::Bool(s.batcher_up));
    inner.insert("draining".to_string(), Json::Bool(s.draining));
    inner.insert("model_generation".to_string(), Json::Num(s.model_generation as f64));
    inner.insert("batcher_restarts".to_string(), Json::Num(s.batcher_restarts as f64));
    let mut obj = BTreeMap::new();
    obj.insert("health".to_string(), Json::Obj(inner));
    Json::Obj(obj).to_string()
}

/// Render the success response to `{"reload": "path"}` (no trailing
/// newline): `{"reload": {"generation": N}}` where `N` is the model
/// generation now being served. Failures are a plain error response
/// prefixed [`ERR_RELOAD`]; the previous model keeps serving.
pub fn reload_line(generation: u64) -> String {
    let mut inner = BTreeMap::new();
    inner.insert("generation".to_string(), Json::Num(generation as f64));
    let mut obj = BTreeMap::new();
    obj.insert("reload".to_string(), Json::Obj(inner));
    Json::Obj(obj).to_string()
}

/// The metrics-registry dump as one flat JSON object: the process-wide
/// [`crate::util::trace`] registry (counters, gauges, histogram
/// quantiles) merged with the serve counters under stable
/// `serve_*`-prefixed names. Both serve loops render through this one
/// function, so the poll/threads byte-identity contract extends to
/// `{"metrics"}` responses.
pub fn metrics_json(s: &ServeStats) -> Json {
    let mut obj = match crate::util::trace::metrics_snapshot() {
        Json::Obj(m) => m,
        _ => BTreeMap::new(),
    };
    obj.insert("serve_requests_total".to_string(), Json::Num(s.batcher.requests as f64));
    obj.insert("serve_points_total".to_string(), Json::Num(s.batcher.points as f64));
    obj.insert("serve_batches_total".to_string(), Json::Num(s.batcher.device_calls as f64));
    obj.insert("serve_padded_rows_total".to_string(), Json::Num(s.batcher.padded_rows as f64));
    obj.insert("serve_errors_total".to_string(), Json::Num(s.batcher.errors as f64));
    obj.insert("serve_saturated_total".to_string(), Json::Num(s.saturated as f64));
    obj.insert("serve_shed_heavy_total".to_string(), Json::Num(s.shed_heavy as f64));
    obj.insert("serve_shed_load_total".to_string(), Json::Num(s.shed_load as f64));
    obj.insert("serve_oversized_total".to_string(), Json::Num(s.oversized as f64));
    obj.insert("serve_latency_count".to_string(), Json::Num(s.latency.count as f64));
    obj.insert("serve_latency_p50_us".to_string(), Json::Num(s.latency.p50_us));
    obj.insert("serve_latency_p90_us".to_string(), Json::Num(s.latency.p90_us));
    obj.insert("serve_latency_p99_us".to_string(), Json::Num(s.latency.p99_us));
    obj.insert("artifact_warnings_total".to_string(), Json::Num(s.artifact_warnings as f64));
    obj.insert("empty_cluster_events_total".to_string(), Json::Num(s.empty_events as f64));
    obj.insert("serve_model_generation".to_string(), Json::Num(s.model_generation as f64));
    obj.insert(
        "serve_batcher_restarts_total".to_string(),
        Json::Num(s.batcher_restarts as f64),
    );
    obj.insert(
        "serve_batcher_up".to_string(),
        Json::Num(if s.batcher_up { 1.0 } else { 0.0 }),
    );
    obj.insert("serve_draining".to_string(), Json::Num(if s.draining { 1.0 } else { 0.0 }));
    Json::Obj(obj)
}

/// Render the `{"metrics": true}` response line (no trailing newline):
/// `{"metrics": {<registry + serve counters>}}`.
pub fn metrics_line(s: &ServeStats) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("metrics".to_string(), metrics_json(s));
    Json::Obj(obj).to_string()
}

/// Render the `{"metrics": "text"}` response: Prometheus exposition
/// text, one `name value` line per metric, terminated by `# EOF` —
/// the one multi-line response in the protocol (the terminator tells
/// scrapers where it ends).
pub fn metrics_text(s: &ServeStats) -> String {
    crate::util::trace::metrics_text_from(&metrics_json(s))
}

/// Error string of the typed saturation rejection: sent (with id 0 —
/// no request line was read) when the server is at its concurrent-
/// connection cap, right before the connection is closed. A constant
/// so clients and tests can match on it instead of scraping prose.
pub const ERR_SATURATED: &str = "saturated: concurrent connection limit reached";

/// Typed rejection for a request line that exceeded the configured
/// `--max-line-bytes` bound (sent with id 0 — the line was never
/// parsed), after which the server closes the connection.
pub const ERR_LINE_TOO_LONG: &str = "oversized: request line exceeds the configured byte limit";

/// Typed rejection for a request line that is not valid UTF-8 (sent
/// with id 0; the connection stays open).
pub const ERR_NOT_UTF8: &str = "request line is not valid utf-8";

/// Soft shed tier: the queue is under pressure and this request's
/// point count marks it heavy, so it is rejected before queueing.
pub const ERR_SHED_HEAVY: &str = "shedding: queue under pressure, heavy request rejected";

/// Hard shed tier: the bounded request queue is full.
pub const ERR_SHED_LOAD: &str = "shedding: request queue full";

/// Typed answer for an in-flight request dropped because the batcher
/// thread died mid-service (sent with the request's own id). The
/// supervisor restarts the batcher with capped backoff; the client
/// should simply resend.
pub const ERR_RETRY: &str = "retry: batcher restarting, request dropped";

/// Prefix of the typed rejection sent when a `{"reload"}` command
/// fails (unreadable file, CRC mismatch, dim/k mismatch). The
/// previously served model generation keeps serving untouched.
pub const ERR_RELOAD: &str = "reload failed";

/// A server response (success or error).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok {
        id: u64,
        clusters: Vec<i32>,
        /// Squared distance to the assigned centroid per point.
        distances: Vec<f32>,
    },
    Err {
        id: u64,
        error: String,
    },
}

impl Response {
    /// The typed rejection a saturated server sends before closing.
    pub fn saturated() -> Response {
        Response::Err { id: 0, error: ERR_SATURATED.to_string() }
    }

    /// Does this response signal server saturation?
    pub fn is_saturated(&self) -> bool {
        matches!(self, Response::Err { error, .. } if error == ERR_SATURATED)
    }

    /// The typed rejection for an over-long request line.
    pub fn line_too_long() -> Response {
        Response::Err { id: 0, error: ERR_LINE_TOO_LONG.to_string() }
    }

    /// The typed rejection for a non-UTF-8 request line.
    pub fn not_utf8() -> Response {
        Response::Err { id: 0, error: ERR_NOT_UTF8.to_string() }
    }

    /// Does this response signal a load-shed rejection (either tier)?
    pub fn is_shed(&self) -> bool {
        matches!(self, Response::Err { error, .. }
            if error == ERR_SHED_HEAVY || error == ERR_SHED_LOAD)
    }

    /// The typed answer for a request orphaned by a batcher death.
    pub fn retry(id: u64) -> Response {
        Response::Err { id, error: ERR_RETRY.to_string() }
    }

    /// Does this response tell the client to simply resend?
    pub fn is_retry(&self) -> bool {
        matches!(self, Response::Err { error, .. } if error == ERR_RETRY)
    }

    /// Serialize to one JSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Response::Ok { id, clusters, distances } => {
                let mut obj = BTreeMap::new();
                obj.insert("id".to_string(), Json::Num(*id as f64));
                obj.insert(
                    "clusters".to_string(),
                    Json::Arr(clusters.iter().map(|&c| Json::Num(c as f64)).collect()),
                );
                obj.insert(
                    "distances".to_string(),
                    Json::Arr(distances.iter().map(|&d| Json::Num(d as f64)).collect()),
                );
                Json::Obj(obj).to_string()
            }
            Response::Err { id, error } => {
                let mut obj = BTreeMap::new();
                obj.insert("id".to_string(), Json::Num(*id as f64));
                obj.insert("error".to_string(), Json::Str(error.clone()));
                Json::Obj(obj).to_string()
            }
        }
    }

    /// Parse a response line (client side / tests).
    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        let id = j
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Config("response: missing id".into()))? as u64;
        if let Some(err) = j.get("error").and_then(Json::as_str) {
            return Ok(Response::Err { id, error: err.to_string() });
        }
        let clusters = j
            .arr_field("clusters")?
            .iter()
            .map(|v| {
                v.as_f64().map(|f| f as i32).ok_or_else(|| Error::Config("bad cluster".into()))
            })
            .collect::<Result<Vec<i32>>>()?;
        let distances = j
            .arr_field("distances")?
            .iter()
            .map(|v| {
                v.as_f64().map(|f| f as f32).ok_or_else(|| Error::Config("bad distance".into()))
            })
            .collect::<Result<Vec<f32>>>()?;
        Ok(Response::Ok { id, clusters, distances })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request::parse(r#"{"id": 7, "points": [[1.0, 2.0], [3, 4]]}"#).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.points, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }

    #[test]
    fn request_rejects_malformed() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"points": [[1,2]]}"#).is_err()); // no id
        assert!(Request::parse(r#"{"id": 1}"#).is_err()); // no points
        assert!(Request::parse(r#"{"id": 1, "points": []}"#).is_err());
        assert!(Request::parse(r#"{"id": 1, "points": [["a"]]}"#).is_err());
        assert!(Request::parse(r#"{"id": -3, "points": [[1]]}"#).is_err());
    }

    #[test]
    fn stats_request_parses_and_assign_still_routes() {
        assert_eq!(ClientRequest::parse(r#"{"stats": true}"#).unwrap(), ClientRequest::Stats);
        // stats must be literally true — anything else is a normal
        // (here: malformed) request
        assert!(ClientRequest::parse(r#"{"stats": false}"#).is_err());
        assert!(ClientRequest::parse(r#"{"stats": 1}"#).is_err());
        match ClientRequest::parse(r#"{"id": 3, "points": [[1.0, 2.0]]}"#).unwrap() {
            ClientRequest::Assign(r) => assert_eq!(r.id, 3),
            other => panic!("unexpected {other:?}"),
        }
        assert!(ClientRequest::parse("not json").is_err());
    }

    #[test]
    fn stats_line_renders_every_counter() {
        let stats = ServeStats {
            batcher: BatcherStats {
                requests: 10,
                points: 640,
                device_calls: 2,
                padded_rows: 55,
                errors: 1,
            },
            saturated: 7,
            shed_heavy: 3,
            shed_load: 2,
            oversized: 4,
            latency: LatencySummary { count: 10, p50_us: 1.5, p90_us: 12.0, p99_us: 96.0 },
            artifact_warnings: 5,
            empty_events: 6,
            model_generation: 2,
            batcher_restarts: 1,
            batcher_last_restart: "panicked: chaos".to_string(),
            batcher_up: true,
            draining: false,
        };
        let line = stats_line(&stats);
        let j = Json::parse(&line).unwrap();
        let s = j.get("stats").expect("stats object");
        assert_eq!(s.get("requests").and_then(Json::as_f64), Some(10.0));
        assert_eq!(s.get("points").and_then(Json::as_f64), Some(640.0));
        assert_eq!(s.get("batches").and_then(Json::as_f64), Some(2.0));
        assert_eq!(s.get("padded_rows").and_then(Json::as_f64), Some(55.0));
        assert_eq!(s.get("errors").and_then(Json::as_f64), Some(1.0));
        assert_eq!(s.get("saturated").and_then(Json::as_f64), Some(7.0));
        assert_eq!(s.get("shed_heavy").and_then(Json::as_f64), Some(3.0));
        assert_eq!(s.get("shed_load").and_then(Json::as_f64), Some(2.0));
        assert_eq!(s.get("oversized").and_then(Json::as_f64), Some(4.0));
        assert_eq!(s.get("lat_count").and_then(Json::as_f64), Some(10.0));
        assert_eq!(s.get("lat_p50_us").and_then(Json::as_f64), Some(1.5));
        assert_eq!(s.get("lat_p90_us").and_then(Json::as_f64), Some(12.0));
        assert_eq!(s.get("lat_p99_us").and_then(Json::as_f64), Some(96.0));
        assert_eq!(s.get("artifact_warnings").and_then(Json::as_f64), Some(5.0));
        assert_eq!(s.get("empty_events").and_then(Json::as_f64), Some(6.0));
        assert_eq!(s.get("model_generation").and_then(Json::as_f64), Some(2.0));
        assert_eq!(s.get("batcher_restarts").and_then(Json::as_f64), Some(1.0));
        assert_eq!(s.get("batcher_last_restart").and_then(Json::as_str), Some("panicked: chaos"));
        assert_eq!(s.get("batcher_up").and_then(Json::as_bool), Some(true));
        assert_eq!(s.get("draining").and_then(Json::as_bool), Some(false));
        // one line, no embedded newlines (line-JSON protocol)
        assert!(!line.contains('\n'));
    }

    #[test]
    fn metrics_request_routes_both_forms() {
        assert_eq!(
            ClientRequest::parse(r#"{"metrics": true}"#).unwrap(),
            ClientRequest::Metrics { text: false }
        );
        assert_eq!(
            ClientRequest::parse(r#"{"metrics": "text"}"#).unwrap(),
            ClientRequest::Metrics { text: true }
        );
        // anything else under the key is a malformed assign request
        assert!(ClientRequest::parse(r#"{"metrics": false}"#).is_err());
        assert!(ClientRequest::parse(r#"{"metrics": "json"}"#).is_err());
        // both front ends agree on the new forms
        for line in [r#"{"metrics": true}"#, r#"{"metrics": "text"}"#] {
            assert_eq!(
                ClientRequest::parse(line).unwrap(),
                ClientRequest::parse_tape_tier(line, KernelTier::Scalar).unwrap(),
            );
        }
    }

    #[test]
    fn metrics_line_merges_registry_and_serve_counters() {
        let stats = ServeStats {
            batcher: BatcherStats {
                requests: 10,
                points: 640,
                device_calls: 2,
                padded_rows: 55,
                errors: 1,
            },
            saturated: 7,
            shed_heavy: 3,
            shed_load: 2,
            oversized: 4,
            latency: LatencySummary { count: 10, p50_us: 1.5, p90_us: 12.0, p99_us: 96.0 },
            artifact_warnings: 0,
            empty_events: 9,
            model_generation: 3,
            batcher_restarts: 2,
            batcher_last_restart: String::new(),
            batcher_up: true,
            draining: true,
        };
        let line = metrics_line(&stats);
        assert!(!line.contains('\n'));
        let j = Json::parse(&line).unwrap();
        let m = j.get("metrics").expect("metrics object");
        assert_eq!(m.get("serve_requests_total").and_then(Json::as_f64), Some(10.0));
        assert_eq!(m.get("serve_latency_p99_us").and_then(Json::as_f64), Some(96.0));
        assert_eq!(m.get("empty_cluster_events_total").and_then(Json::as_f64), Some(9.0));
        assert_eq!(m.get("artifact_warnings_total").and_then(Json::as_f64), Some(0.0));
        assert_eq!(m.get("serve_model_generation").and_then(Json::as_f64), Some(3.0));
        assert_eq!(m.get("serve_batcher_restarts_total").and_then(Json::as_f64), Some(2.0));
        assert_eq!(m.get("serve_batcher_up").and_then(Json::as_f64), Some(1.0));
        assert_eq!(m.get("serve_draining").and_then(Json::as_f64), Some(1.0));
        // registry counters appear alongside the serve counters
        crate::util::trace::counter_add("protocol_test_metric_total", 3);
        let j2 = Json::parse(&metrics_line(&stats)).unwrap();
        assert_eq!(
            j2.get("metrics").unwrap().get("protocol_test_metric_total").and_then(Json::as_f64),
            Some(3.0)
        );
        // the text rendering is Prometheus-shaped and EOF-terminated
        let text = metrics_text(&stats);
        assert!(text.lines().any(|l| l == "serve_requests_total 10"), "{text}");
        assert!(text.ends_with("# EOF\n"), "{text}");
    }

    #[test]
    fn health_and_reload_route_on_both_front_ends() {
        assert_eq!(ClientRequest::parse(r#"{"health": true}"#).unwrap(), ClientRequest::Health);
        assert_eq!(
            ClientRequest::parse(r#"{"reload": "m.pkm"}"#).unwrap(),
            ClientRequest::Reload { path: "m.pkm".to_string() }
        );
        // health must be literally true; reload must be a string —
        // anything else falls through to (malformed) assign parsing
        assert!(ClientRequest::parse(r#"{"health": false}"#).is_err());
        assert!(ClientRequest::parse(r#"{"health": 1}"#).is_err());
        assert!(ClientRequest::parse(r#"{"reload": true}"#).is_err());
        for line in [r#"{"health": true}"#, r#"{"reload": "m.pkm"}"#] {
            assert_eq!(
                ClientRequest::parse(line).unwrap(),
                ClientRequest::parse_tape_tier(line, KernelTier::Scalar).unwrap(),
            );
        }
    }

    #[test]
    fn health_line_distinguishes_live_from_ready() {
        let mut s = ServeStats { batcher_up: true, model_generation: 1, ..Default::default() };
        let j = Json::parse(&health_line(&s)).unwrap();
        let h = j.get("health").expect("health object");
        assert_eq!(h.get("live").and_then(Json::as_bool), Some(true));
        assert_eq!(h.get("ready").and_then(Json::as_bool), Some(true));
        // draining: still live, no longer ready
        s.draining = true;
        let j = Json::parse(&health_line(&s)).unwrap();
        let h = j.get("health").unwrap();
        assert_eq!(h.get("live").and_then(Json::as_bool), Some(true));
        assert_eq!(h.get("ready").and_then(Json::as_bool), Some(false));
        // dead batcher: live, not ready
        s.draining = false;
        s.batcher_up = false;
        let h2 = Json::parse(&health_line(&s)).unwrap();
        assert_eq!(h2.get("health").unwrap().get("ready").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn reload_line_and_retry_are_typed() {
        let j = Json::parse(&reload_line(4)).unwrap();
        assert_eq!(j.get("reload").unwrap().get("generation").and_then(Json::as_f64), Some(4.0));
        let r = Response::retry(9);
        assert!(r.is_retry());
        assert_eq!(Response::parse(&r.to_line()).unwrap(), r);
        assert!(!Response::saturated().is_retry());
    }

    #[test]
    fn tape_front_end_matches_legacy_on_protocol_lines() {
        let lines = [
            r#"{"id": 7, "points": [[1.0, 2.0], [3, 4]]}"#,
            r#"{"stats": true}"#,
            r#"{"stats": false}"#,
            r#"{"health": true}"#,
            r#"{"reload": "second.pkm"}"#,
            r#"{"id": -3, "points": [[1]]}"#,
            "not json",
            "",
        ];
        for line in lines {
            let legacy = ClientRequest::parse(line);
            let tape = ClientRequest::parse_tape_tier(line, KernelTier::Scalar);
            match (legacy, tape) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "mismatch on {line:?}"),
                (Err(_), Err(_)) => {}
                (l, t) => panic!("ok-ness mismatch on {line:?}: {l:?} vs {t:?}"),
            }
        }
    }

    #[test]
    fn typed_rejections_are_constants() {
        assert_eq!(
            Response::line_too_long(),
            Response::Err { id: 0, error: ERR_LINE_TOO_LONG.into() }
        );
        assert_eq!(Response::not_utf8(), Response::Err { id: 0, error: ERR_NOT_UTF8.into() });
        assert!(Response::Err { id: 5, error: ERR_SHED_HEAVY.into() }.is_shed());
        assert!(Response::Err { id: 5, error: ERR_SHED_LOAD.into() }.is_shed());
        assert!(!Response::saturated().is_shed());
    }

    #[test]
    fn saturated_is_typed_and_roundtrips() {
        let r = Response::saturated();
        assert!(r.is_saturated());
        let parsed = Response::parse(&r.to_line()).unwrap();
        assert!(parsed.is_saturated());
        let other = Response::Err { id: 0, error: "dim mismatch".into() };
        assert!(!other.is_saturated());
        assert!(!Response::Ok { id: 1, clusters: vec![], distances: vec![] }.is_saturated());
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Ok { id: 9, clusters: vec![0, 3], distances: vec![0.5, 1.25] };
        let line = r.to_line();
        assert_eq!(Response::parse(&line).unwrap(), r);
        let e = Response::Err { id: 9, error: "dim mismatch".into() };
        assert_eq!(Response::parse(&e.to_line()).unwrap(), e);
    }
}
