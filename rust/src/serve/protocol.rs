//! Line-delimited JSON protocol for the assignment service.
//!
//! Request  : `{"id": 7, "points": [[x,y,z], ...]}`
//! Response : `{"id": 7, "clusters": [0, 2, ...], "distances": [..]}`
//! Error    : `{"id": 7, "error": "..."}`
//! Stats    : `{"stats": true}` → `{"stats": {"requests": .., ...}}`
//!
//! One JSON document per line; a connection may pipeline any number of
//! requests. The stats request returns the server's live
//! [`BatcherStats`] counters plus the acceptor's saturation-rejection
//! count ([`stats_line`]) — answered from the connection thread, so it
//! works even while the batcher is busy. Parsing uses the in-crate
//! [`crate::util::json`].
//!
//! [`BatcherStats`]: crate::serve::batcher::BatcherStats

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Row-major points, `dim` implied by the served model.
    pub points: Vec<Vec<f64>>,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        let id = j
            .get("id")
            .and_then(Json::as_f64)
            .filter(|v| *v >= 0.0 && v.fract() == 0.0)
            .ok_or_else(|| Error::Config("request: missing numeric `id`".into()))? as u64;
        let points = j
            .arr_field("points")
            .map_err(|_| Error::Config("request: missing `points` array".into()))?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| Error::Config("request: point must be an array".into()))?
                    .iter()
                    .map(|v| {
                        v.as_f64().ok_or_else(|| {
                            Error::Config("request: point coordinate must be a number".into())
                        })
                    })
                    .collect::<Result<Vec<f64>>>()
            })
            .collect::<Result<Vec<Vec<f64>>>>()?;
        if points.is_empty() {
            return Err(Error::Config("request: empty `points`".into()));
        }
        Ok(Request { id, points })
    }
}

/// Any line a client may send: an assignment request or the
/// observability probe `{"stats": true}`.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientRequest {
    Assign(Request),
    Stats,
}

impl ClientRequest {
    /// Parse one request line; `{"stats": true}` routes to
    /// [`ClientRequest::Stats`], everything else through
    /// [`Request::parse`].
    pub fn parse(line: &str) -> Result<ClientRequest> {
        let j = Json::parse(line)?;
        if j.get("stats").and_then(Json::as_bool) == Some(true) {
            return Ok(ClientRequest::Stats);
        }
        Request::parse(line).map(ClientRequest::Assign)
    }
}

/// Render the stats response line (no trailing newline):
/// `{"stats": {"batches": .., "errors": .., "padded_rows": ..,
/// "points": .., "requests": .., "saturated": ..}}`. `batches` is the
/// batcher's device-call count; `saturated` is the acceptor-side
/// connection-rejection count (tracked outside the batcher).
pub fn stats_line(stats: &crate::serve::batcher::BatcherStats, saturated: u64) -> String {
    let mut inner = BTreeMap::new();
    inner.insert("requests".to_string(), Json::Num(stats.requests as f64));
    inner.insert("points".to_string(), Json::Num(stats.points as f64));
    inner.insert("batches".to_string(), Json::Num(stats.device_calls as f64));
    inner.insert("padded_rows".to_string(), Json::Num(stats.padded_rows as f64));
    inner.insert("errors".to_string(), Json::Num(stats.errors as f64));
    inner.insert("saturated".to_string(), Json::Num(saturated as f64));
    let mut obj = BTreeMap::new();
    obj.insert("stats".to_string(), Json::Obj(inner));
    Json::Obj(obj).to_string()
}

/// Error string of the typed saturation rejection: sent (with id 0 —
/// no request line was read) when the server is at its concurrent-
/// connection cap, right before the connection is closed. A constant
/// so clients and tests can match on it instead of scraping prose.
pub const ERR_SATURATED: &str = "saturated: concurrent connection limit reached";

/// A server response (success or error).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok {
        id: u64,
        clusters: Vec<i32>,
        /// Squared distance to the assigned centroid per point.
        distances: Vec<f32>,
    },
    Err {
        id: u64,
        error: String,
    },
}

impl Response {
    /// The typed rejection a saturated server sends before closing.
    pub fn saturated() -> Response {
        Response::Err { id: 0, error: ERR_SATURATED.to_string() }
    }

    /// Does this response signal server saturation?
    pub fn is_saturated(&self) -> bool {
        matches!(self, Response::Err { error, .. } if error == ERR_SATURATED)
    }

    /// Serialize to one JSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Response::Ok { id, clusters, distances } => {
                let mut obj = BTreeMap::new();
                obj.insert("id".to_string(), Json::Num(*id as f64));
                obj.insert(
                    "clusters".to_string(),
                    Json::Arr(clusters.iter().map(|&c| Json::Num(c as f64)).collect()),
                );
                obj.insert(
                    "distances".to_string(),
                    Json::Arr(distances.iter().map(|&d| Json::Num(d as f64)).collect()),
                );
                Json::Obj(obj).to_string()
            }
            Response::Err { id, error } => {
                let mut obj = BTreeMap::new();
                obj.insert("id".to_string(), Json::Num(*id as f64));
                obj.insert("error".to_string(), Json::Str(error.clone()));
                Json::Obj(obj).to_string()
            }
        }
    }

    /// Parse a response line (client side / tests).
    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        let id = j
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Config("response: missing id".into()))? as u64;
        if let Some(err) = j.get("error").and_then(Json::as_str) {
            return Ok(Response::Err { id, error: err.to_string() });
        }
        let clusters = j
            .arr_field("clusters")?
            .iter()
            .map(|v| {
                v.as_f64().map(|f| f as i32).ok_or_else(|| Error::Config("bad cluster".into()))
            })
            .collect::<Result<Vec<i32>>>()?;
        let distances = j
            .arr_field("distances")?
            .iter()
            .map(|v| {
                v.as_f64().map(|f| f as f32).ok_or_else(|| Error::Config("bad distance".into()))
            })
            .collect::<Result<Vec<f32>>>()?;
        Ok(Response::Ok { id, clusters, distances })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request::parse(r#"{"id": 7, "points": [[1.0, 2.0], [3, 4]]}"#).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.points, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }

    #[test]
    fn request_rejects_malformed() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"points": [[1,2]]}"#).is_err()); // no id
        assert!(Request::parse(r#"{"id": 1}"#).is_err()); // no points
        assert!(Request::parse(r#"{"id": 1, "points": []}"#).is_err());
        assert!(Request::parse(r#"{"id": 1, "points": [["a"]]}"#).is_err());
        assert!(Request::parse(r#"{"id": -3, "points": [[1]]}"#).is_err());
    }

    #[test]
    fn stats_request_parses_and_assign_still_routes() {
        assert_eq!(ClientRequest::parse(r#"{"stats": true}"#).unwrap(), ClientRequest::Stats);
        // stats must be literally true — anything else is a normal
        // (here: malformed) request
        assert!(ClientRequest::parse(r#"{"stats": false}"#).is_err());
        assert!(ClientRequest::parse(r#"{"stats": 1}"#).is_err());
        match ClientRequest::parse(r#"{"id": 3, "points": [[1.0, 2.0]]}"#).unwrap() {
            ClientRequest::Assign(r) => assert_eq!(r.id, 3),
            other => panic!("unexpected {other:?}"),
        }
        assert!(ClientRequest::parse("not json").is_err());
    }

    #[test]
    fn stats_line_renders_every_counter() {
        let stats = crate::serve::batcher::BatcherStats {
            requests: 10,
            points: 640,
            device_calls: 2,
            padded_rows: 55,
            errors: 1,
        };
        let line = stats_line(&stats, 7);
        let j = Json::parse(&line).unwrap();
        let s = j.get("stats").expect("stats object");
        assert_eq!(s.get("requests").and_then(Json::as_f64), Some(10.0));
        assert_eq!(s.get("points").and_then(Json::as_f64), Some(640.0));
        assert_eq!(s.get("batches").and_then(Json::as_f64), Some(2.0));
        assert_eq!(s.get("padded_rows").and_then(Json::as_f64), Some(55.0));
        assert_eq!(s.get("errors").and_then(Json::as_f64), Some(1.0));
        assert_eq!(s.get("saturated").and_then(Json::as_f64), Some(7.0));
        // one line, no embedded newlines (line-JSON protocol)
        assert!(!line.contains('\n'));
    }

    #[test]
    fn saturated_is_typed_and_roundtrips() {
        let r = Response::saturated();
        assert!(r.is_saturated());
        let parsed = Response::parse(&r.to_line()).unwrap();
        assert!(parsed.is_saturated());
        let other = Response::Err { id: 0, error: "dim mismatch".into() };
        assert!(!other.is_saturated());
        assert!(!Response::Ok { id: 1, clusters: vec![], distances: vec![] }.is_saturated());
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Ok { id: 9, clusters: vec![0, 3], distances: vec![0.5, 1.25] };
        let line = r.to_line();
        assert_eq!(Response::parse(&line).unwrap(), r);
        let e = Response::Err { id: 9, error: "dim mismatch".into() };
        assert_eq!(Response::parse(&e.to_line()).unwrap(), e);
    }
}
