//! Nonblocking `poll(2)` reactor: the event-driven serve loop.
//!
//! One thread multiplexes the listener, a wake fd and every client
//! socket. Each connection owns a read buffer (complete lines are
//! peeled off and handled as they arrive) and a write buffer (replies
//! are appended by token and flushed as the socket drains). Nothing in
//! the loop blocks: reads and writes stop at `WouldBlock`, assignment
//! requests are handed to the batcher with an event [`ReplySink`] and
//! come back through a completion channel plus a [`Waker`] poke.
//!
//! The `poll(2)` binding is hand-declared (the crate is dependency-
//! free), which is why this module — and the `--serve-loop poll` mode —
//! is unix-only; the thread-per-connection loop remains the portable
//! fallback. `poll` is chosen over `epoll`/`kqueue` deliberately: it is
//! POSIX-portable across unixes with a single declaration, and the
//! fd-set rebuild each iteration is O(connections), which is noise at
//! the connection counts a model server sees (the cap defaults to 64).
//!
//! Shutdown mirrors the threads loop: [`ServerHandle::shutdown`] sets
//! the stop flag and pokes the listener with a throwaway connect, which
//! makes `poll` return; a 100 ms timeout backstops both shutdown and
//! lost wake datagrams.
//!
//! [`ServerHandle::shutdown`]: crate::serve::server::ServerHandle::shutdown

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::serve::batcher::Job;
use crate::serve::protocol::{self, ClientRequest, Response};
use crate::serve::reply::{Completion, ReplySink, Waker};
use crate::serve::server::{reload_response, shed_decision, ServeShared, ShedConfig};
use crate::util::chaos;

/// Hand-declared `poll(2)` interface (no libc crate).
mod sys {
    use std::os::raw::{c_int, c_ulong};

    /// `struct pollfd` — layout fixed by POSIX: `int fd; short events;
    /// short revents;`.
    #[repr(C)]
    #[derive(Debug, Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    extern "C" {
        /// `nfds_t` is `c_ulong` on Linux; on macOS it is `u32`, but a
        /// wider register argument is harmless for the small counts we
        /// pass (the value always fits in 32 bits).
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }
}

use sys::{POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};

/// Safety-net poll timeout: bounds shutdown latency and recovers from a
/// lost wake datagram (see [`Waker`] docs).
const POLL_TIMEOUT_MS: i32 = 100;

/// Read chunk size per `read()` call; also the threshold past which a
/// partially-flushed write buffer is compacted.
const IO_CHUNK: usize = 16 * 1024;

/// Reactor knobs, copied out of `ServeConfig` by the server.
#[derive(Debug, Clone)]
pub struct PollCfg {
    pub queue_depth: usize,
    pub max_conns: usize,
    pub max_line_bytes: usize,
    pub shed: ShedConfig,
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet consumed as complete lines (bounded by
    /// `max_line_bytes` + one read chunk).
    rbuf: Vec<u8>,
    /// Reply bytes not yet written; `wstart..` is the unsent tail.
    wbuf: Vec<u8>,
    wstart: usize,
    /// Requests queued to the batcher whose completions are pending.
    inflight: usize,
    /// Reading is over (EOF or a protocol-fatal reply like an
    /// oversized line); close once `wbuf` drains and `inflight` is 0.
    closing: bool,
    /// Socket errored; drop without further I/O.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wstart: 0,
            inflight: 0,
            closing: false,
            dead: false,
        }
    }

    fn wants_write(&self) -> bool {
        self.wstart < self.wbuf.len()
    }

    fn push_line(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Queue bytes verbatim (the Prometheus text response carries its
    /// own newlines and `# EOF` terminator).
    fn push_raw(&mut self, raw: &str) {
        self.wbuf.extend_from_slice(raw.as_bytes());
    }

    fn finished(&self) -> bool {
        self.dead || (self.closing && self.inflight == 0 && !self.wants_write())
    }
}

/// Everything the per-connection handlers need besides the connection.
struct Ctx {
    queue: mpsc::SyncSender<Job>,
    shared: Arc<ServeShared>,
    cfg: PollCfg,
    waker: Waker,
    done_tx: mpsc::Sender<Completion>,
}

/// Run the reactor until `stop` is set. Consumes the listener.
pub fn run(
    listener: TcpListener,
    queue: mpsc::SyncSender<Job>,
    shared: Arc<ServeShared>,
    cfg: PollCfg,
    stop: Arc<AtomicBool>,
) {
    if listener.set_nonblocking(true).is_err() {
        eprintln!("reactor: cannot set listener nonblocking; serve loop unavailable");
        return;
    }
    let (waker, wake_rx) = match Waker::pair() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("reactor: cannot build waker: {e}");
            return;
        }
    };
    let (done_tx, done_rx) = mpsc::channel::<Completion>();
    let ctx = Ctx { queue, shared, cfg, waker, done_tx };

    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token: u64 = 0;
    let mut fds: Vec<sys::PollFd> = Vec::new();
    let mut toks: Vec<u64> = Vec::new();
    let mut scratch: Vec<u8> = Vec::new();

    loop {
        let draining = ctx.shared.lifecycle.draining.load(Ordering::Acquire);
        if stop.load(Ordering::Acquire) {
            if !draining {
                break;
            }
            // graceful drain: stop reading new requests, keep pumping
            // completions and write buffers, exit when the last reply
            // has flushed and every connection is gone
            for c in conns.values_mut() {
                c.closing = true;
            }
            if conns.is_empty() {
                break;
            }
        }

        // rebuild the fd set: listener, wake fd, then every connection
        fds.clear();
        toks.clear();
        fds.push(sys::PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
        fds.push(sys::PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        for (&tok, c) in &conns {
            let mut events = 0i16;
            if !c.closing {
                events |= POLLIN;
            }
            if c.wants_write() {
                events |= POLLOUT;
            }
            // events == 0 is fine: POLLERR/HUP/NVAL are always reported
            fds.push(sys::PollFd { fd: c.stream.as_raw_fd(), events, revents: 0 });
            toks.push(tok);
        }

        let rc = unsafe {
            sys::poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_ulong, POLL_TIMEOUT_MS)
        };
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == ErrorKind::Interrupted {
                continue;
            }
            eprintln!("reactor: poll failed: {err}");
            break;
        }
        if stop.load(Ordering::Acquire) && !draining {
            break;
        }

        // drain wake datagrams (their only job was to end the poll)
        let mut byte = [0u8; 8];
        while wake_rx.recv_from(&mut byte).is_ok() {}

        // completed requests → write buffers + latency histogram
        while let Ok(done) = done_rx.try_recv() {
            ctx.shared.inflight.fetch_sub(1, Ordering::AcqRel);
            ctx.shared.record_latency(done.started);
            if let Some(c) = conns.get_mut(&done.token) {
                c.inflight -= 1;
                c.push_line(&done.line);
            }
            // a vanished token means the connection died mid-request;
            // the counters above are still ours to settle
        }

        if fds[0].revents != 0 && !stop.load(Ordering::Acquire) {
            accept_ready(&listener, &mut conns, &mut next_token, &ctx);
        }

        for (slot, &tok) in toks.iter().enumerate() {
            let revents = fds[slot + 2].revents;
            if revents == 0 {
                continue;
            }
            let c = conns.get_mut(&tok).expect("token tracks conns");
            if revents & (POLLERR | POLLNVAL) != 0 {
                c.dead = true;
                continue;
            }
            if revents & (POLLIN | POLLHUP) != 0 && !c.closing {
                read_ready(c, tok, &ctx, &mut scratch);
            }
        }

        // flush everything with pending output — completions and inline
        // replies land in wbuf without a POLLOUT edge of their own
        for c in conns.values_mut() {
            if !c.dead && c.wants_write() {
                flush(c);
            }
        }

        conns.retain(|_, c| !c.finished());
    }
}

/// Accept until the listener would block. Over the cap: typed
/// saturation rejection on the (still blocking) fresh socket, then
/// close.
fn accept_ready(
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    ctx: &Ctx,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if chaos::hit(chaos::Site::ServeAccept).is_some() {
                    // injected accept failure: connection dropped unserved
                    drop(stream);
                    continue;
                }
                let _ = stream.set_nodelay(true);
                if conns.len() >= ctx.cfg.max_conns {
                    ctx.shared.saturated.fetch_add(1, Ordering::AcqRel);
                    // accepted sockets do not inherit O_NONBLOCK; one
                    // short line into an empty socket buffer cannot
                    // stall the reactor
                    let mut stream = stream;
                    let _ = writeln!(stream, "{}", Response::saturated().to_line());
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                conns.insert(*next_token, Conn::new(stream));
                *next_token += 1;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                eprintln!("reactor: accept error: {e}");
                break;
            }
        }
    }
}

/// Read until `WouldBlock`/EOF, peeling complete lines off as they
/// arrive and keeping the buffered partial line under the byte bound.
fn read_ready(c: &mut Conn, tok: u64, ctx: &Ctx, scratch: &mut Vec<u8>) {
    let mut tmp = [0u8; IO_CHUNK];
    loop {
        match c.stream.read(&mut tmp) {
            Ok(0) => {
                // EOF: a trailing unterminated line still counts
                // (BufRead::lines parity with the threads loop)
                if !c.rbuf.is_empty() {
                    scratch.clear();
                    scratch.append(&mut c.rbuf);
                    if scratch.len() > ctx.cfg.max_line_bytes {
                        reject_oversized(c, ctx);
                    } else {
                        handle_line(c, tok, ctx, scratch);
                    }
                }
                c.closing = true;
                return;
            }
            Ok(n) => {
                c.rbuf.extend_from_slice(&tmp[..n]);
                drain_lines(c, tok, ctx, scratch);
                if c.closing || c.dead {
                    return;
                }
                // the unbounded-line DoS guard: a partial line past the
                // bound is rejected now, not buffered forever
                if c.rbuf.len() > ctx.cfg.max_line_bytes {
                    c.rbuf.clear();
                    reject_oversized(c, ctx);
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
}

/// Peel complete `\n`-terminated lines out of `rbuf` and handle each.
fn drain_lines(c: &mut Conn, tok: u64, ctx: &Ctx, scratch: &mut Vec<u8>) {
    let mut start = 0usize;
    while let Some(rel) = c.rbuf[start..].iter().position(|&b| b == b'\n') {
        let end = start + rel;
        scratch.clear();
        scratch.extend_from_slice(&c.rbuf[start..end]);
        start = end + 1;
        if scratch.len() > ctx.cfg.max_line_bytes {
            c.rbuf.clear();
            reject_oversized(c, ctx);
            return;
        }
        handle_line(c, tok, ctx, scratch);
        if c.closing || c.dead {
            c.rbuf.clear();
            return;
        }
    }
    c.rbuf.drain(..start);
}

/// Typed oversized-line rejection; the rest of the stream cannot be
/// resynchronized, so the connection winds down after the reply.
fn reject_oversized(c: &mut Conn, ctx: &Ctx) {
    ctx.shared.oversized.fetch_add(1, Ordering::AcqRel);
    c.push_line(&Response::line_too_long().to_line());
    c.closing = true;
}

/// One request line: parse through the tape front end, answer stats
/// inline, shed or queue assignments.
fn handle_line(c: &mut Conn, tok: u64, ctx: &Ctx, raw: &[u8]) {
    let started = Instant::now();
    // mirror BufRead::lines(): drop one trailing \r
    let raw = match raw.split_last() {
        Some((&b'\r', head)) => head,
        _ => raw,
    };
    let Ok(line) = std::str::from_utf8(raw) else {
        c.push_line(&Response::not_utf8().to_line());
        ctx.shared.record_latency(started);
        return;
    };
    if line.trim().is_empty() {
        return;
    }
    match ClientRequest::parse_tape(line) {
        Ok(ClientRequest::Stats) => {
            c.push_line(&protocol::stats_line(&ctx.shared.snapshot()));
            ctx.shared.record_latency(started);
        }
        Ok(ClientRequest::Metrics { text: false }) => {
            c.push_line(&protocol::metrics_line(&ctx.shared.snapshot()));
            ctx.shared.record_latency(started);
        }
        Ok(ClientRequest::Metrics { text: true }) => {
            c.push_raw(&protocol::metrics_text(&ctx.shared.snapshot()));
            ctx.shared.record_latency(started);
        }
        Ok(ClientRequest::Health) => {
            c.push_line(&protocol::health_line(&ctx.shared.snapshot()));
            ctx.shared.record_latency(started);
        }
        Ok(ClientRequest::Reload { path }) => {
            // the file read + CRC validation run off-thread — the
            // reactor must not block on disk I/O; the answer comes
            // back like any completion
            ctx.shared.inflight.fetch_add(1, Ordering::AcqRel);
            c.inflight += 1;
            let shared = ctx.shared.clone();
            let done = ctx.done_tx.clone();
            let waker = ctx.waker.clone();
            std::thread::spawn(move || {
                let line = reload_response(&shared, &path);
                let _ = done.send(Completion { token: tok, started, line });
                waker.wake();
            });
        }
        Ok(ClientRequest::Assign(request)) => {
            if let Some(err) =
                shed_decision(&ctx.shared, ctx.cfg.queue_depth, &ctx.cfg.shed, request.points.len())
            {
                c.push_line(&Response::Err { id: request.id, error: err.to_string() }.to_line());
                ctx.shared.record_latency(started);
                return;
            }
            ctx.shared.inflight.fetch_add(1, Ordering::AcqRel);
            c.inflight += 1;
            let id = request.id;
            let reply = ReplySink::Event {
                tx: ctx.done_tx.clone(),
                token: tok,
                started,
                waker: ctx.waker.clone(),
            };
            let job = Job::new(request, reply);
            if chaos::hit(chaos::Site::ServeEnqueue).is_some() {
                // injected enqueue failure; dropping the job answers
                // its client with the typed retry error
                drop(job);
                return;
            }
            match ctx.queue.try_send(job) {
                Ok(()) => {}
                Err(mpsc::TrySendError::Full(mut job)) => {
                    // hard shed tier: the bounded queue is full (the
                    // threads loop would block this connection's own
                    // thread here; the reactor must not block)
                    job.dismiss();
                    ctx.shared.inflight.fetch_sub(1, Ordering::AcqRel);
                    c.inflight -= 1;
                    ctx.shared.shed_load.fetch_add(1, Ordering::AcqRel);
                    c.push_line(
                        &Response::Err { id, error: protocol::ERR_SHED_LOAD.to_string() }.to_line(),
                    );
                    ctx.shared.record_latency(started);
                }
                Err(mpsc::TrySendError::Disconnected(job)) => {
                    // supervisor gone (shutdown); dropping the job
                    // answers its client with the typed retry error
                    drop(job);
                }
            }
        }
        Err(e) => {
            c.push_line(&Response::Err { id: 0, error: e.to_string() }.to_line());
            ctx.shared.record_latency(started);
        }
    }
}

/// Write the pending tail until the socket would block; compact the
/// buffer when the flushed prefix grows past one I/O chunk.
fn flush(c: &mut Conn) {
    loop {
        if !c.wants_write() {
            c.wbuf.clear();
            c.wstart = 0;
            return;
        }
        match c.stream.write(&c.wbuf[c.wstart..]) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => c.wstart += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    if c.wstart > IO_CHUNK {
        c.wbuf.drain(..c.wstart);
        c.wstart = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pollfd_matches_posix_layout() {
        // int + short + short, no padding surprises
        assert_eq!(std::mem::size_of::<sys::PollFd>(), 8);
        assert_eq!(std::mem::align_of::<sys::PollFd>(), 4);
    }

    #[test]
    fn poll_binding_observes_udp_readability() {
        // end-to-end smoke of the hand-rolled binding: a wake datagram
        // must flip POLLIN on the receive socket
        let (waker, wake_rx) = Waker::pair().unwrap();
        let mut fds = [sys::PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 }];
        // nothing pending yet → timeout, zero fds ready
        let rc = unsafe { sys::poll(fds.as_mut_ptr(), 1, 0) };
        assert_eq!(rc, 0, "unexpected readiness before wake");
        waker.wake();
        let rc = unsafe { sys::poll(fds.as_mut_ptr(), 1, 5_000) };
        assert_eq!(rc, 1, "wake datagram not observed");
        assert_ne!(fds[0].revents & POLLIN, 0);
    }

    #[test]
    fn conn_lifecycle_flags() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut c = Conn::new(stream);
        assert!(!c.finished());
        c.push_line("hello");
        assert!(c.wants_write());
        c.closing = true;
        assert!(!c.finished(), "pending writes keep the conn alive");
        c.wstart = c.wbuf.len();
        assert!(c.finished());
    }
}
