//! SIMD tape scanner for serve-path request JSON.
//!
//! The serve hot path parses one small JSON document per request line.
//! [`crate::util::json`] walks it byte by byte; this module front-loads
//! that walk with a vectorized *structural scan* (the `squirrel-json`
//! idea): one pass over the line marks every structurally interesting
//! byte — `"` `\` `{` `}` `[` `]` `:` `,` — 32 bytes per AVX2 compare
//! (16 for NEON, with a portable scalar fallback), producing an
//! offsets **tape**. A second, branch-light pass pairs unescaped quotes
//! into string spans. The parser proper then runs over the tape: string
//! bodies with no escapes and no control bytes are sliced out wholesale
//! instead of being re-walked byte-wise, which is where request maps
//! (`{"id": …, "points": [[…]]}`) spend most of their parse time.
//!
//! **Contract — answer-equivalent to the legacy parser.** For every
//! input string and every kernel tier, [`parse_tape_tier`] returns
//! `Ok(v)` exactly when [`Json::parse`] returns `Ok(v)` with the same
//! value, and returns an error exactly when the legacy parser does
//! (error *messages/offsets* may differ only on documents both reject).
//! This holds by construction: the tape parser's control flow is a
//! method-for-method mirror of `util::json::Parser` (same dispatch,
//! same literal/number/whitespace handling, same [`MAX_DEPTH`] cap),
//! and the only shortcut — the clean-string slice — is guarded so any
//! span containing a backslash or control byte falls back to the
//! legacy-exact byte walk. `rust/tests/proptest_protocol.rs` hammers
//! the equivalence with thousands of generated, mutated, truncated and
//! non-UTF-8 inputs per tier.
//!
//! Tier selection follows the crate-wide `linalg::kernel` convention:
//! [`parse_tape`] uses [`kernel::active_tier`], so `PARAKM_KERNEL=scalar`
//! pins the scan to the reference tier; tests pass explicit tiers to
//! exercise scalar and the detected SIMD tier in one process.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::linalg::kernel::{self, KernelTier};
use crate::util::json::{Json, MAX_DEPTH};

/// Offsets tape produced by the structural pre-scan of one document.
#[derive(Debug, Default)]
pub struct Tape {
    /// Offsets of every structurally interesting byte, ascending.
    pub marks: Vec<u32>,
    /// `(open, close)` quote offsets of every complete string literal,
    /// ascending by `open`. Escaped quotes (odd run of preceding
    /// backslashes) do not close a string.
    pub strings: Vec<(u32, u32)>,
}

/// Same host-support gate as the compute kernels: SIMD tiers use
/// `target_feature` code, so a freely constructible unsupported tier
/// must never reach them from safe code.
fn assert_tier_supported(tier: KernelTier) {
    assert!(
        tier == KernelTier::Scalar || tier == kernel::detect(),
        "kernel tier {tier} not supported on this host (detected: {})",
        kernel::detect()
    );
}

fn is_interesting(b: u8) -> bool {
    matches!(b, b'"' | b'\\' | b'{' | b'}' | b'[' | b']' | b':' | b',')
}

fn scan_scalar_from(bytes: &[u8], start: usize, out: &mut Vec<u32>) {
    for (i, &b) in bytes.iter().enumerate().skip(start) {
        if is_interesting(b) {
            out.push(i as u32);
        }
    }
}

/// Offsets of every structural/string-machinery byte in `bytes`,
/// ascending — the raw tape. Public so the property tests can assert
/// scalar ≡ SIMD on arbitrary byte strings.
pub fn structural_offsets(bytes: &[u8], tier: KernelTier) -> Vec<u32> {
    assert_tier_supported(tier);
    assert!(bytes.len() <= u32::MAX as usize, "document too large for u32 offsets tape");
    let mut out = Vec::with_capacity(bytes.len() / 8);
    match tier {
        KernelTier::Scalar => scan_scalar_from(bytes, 0, &mut out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: assert_tier_supported guarantees AVX2 is present.
        KernelTier::Avx2 => unsafe { x86::scan(bytes, &mut out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: assert_tier_supported guarantees NEON is present.
        KernelTier::Neon => unsafe { arm::scan(bytes, &mut out) },
        // cross-compiled tier names that don't exist on this arch
        #[allow(unreachable_patterns)]
        _ => scan_scalar_from(bytes, 0, &mut out),
    }
    out
}

/// Run the structural scan and pair unescaped quotes into string spans.
pub fn scan_tape(text: &str, tier: KernelTier) -> Tape {
    let bytes = text.as_bytes();
    let marks = structural_offsets(bytes, tier);
    let mut strings = Vec::new();
    let mut in_str = false;
    let mut open = 0u32;
    // Track runs of consecutive backslashes: a quote is escaped iff the
    // run ending immediately before it has odd length. Runs only matter
    // inside strings; backslashes elsewhere are the parser's problem.
    let mut bs_end = usize::MAX; // index one past the current run
    let mut bs_len = 0usize;
    for &o32 in &marks {
        let o = o32 as usize;
        match bytes[o] {
            b'\\' => {
                if in_str {
                    bs_len = if bs_end == o { bs_len + 1 } else { 1 };
                    bs_end = o + 1;
                }
            }
            b'"' => {
                if in_str {
                    let escaped = bs_end == o && bs_len % 2 == 1;
                    if !escaped {
                        strings.push((open, o32));
                        in_str = false;
                    }
                } else {
                    in_str = true;
                    open = o32;
                }
            }
            // other structurals carry no string state
            _ => {}
        }
    }
    Tape { marks, strings }
}

/// Parse a complete JSON document through the tape scanner on the
/// process-global kernel tier (`PARAKM_KERNEL` pins it). Answer-
/// equivalent to [`Json::parse`]; see the module docs for the contract.
pub fn parse_tape(text: &str) -> Result<Json> {
    parse_tape_tier(text, kernel::active_tier())
}

/// [`parse_tape`] with an explicit tier (tests exercise scalar and the
/// detected SIMD tier in one process).
pub fn parse_tape_tier(text: &str, tier: KernelTier) -> Result<Json> {
    let tape = scan_tape(text, tier);
    let mut p = TapeParser { b: text.as_bytes(), text, i: 0, strings: &tape.strings, si: 0 };
    p.ws();
    let v = p.value(0)?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

/// Recursive-descent parser over the tape. Every method except
/// [`TapeParser::string`] is a verbatim mirror of the corresponding
/// `util::json::Parser` method — that mirroring, not cleverness, is
/// what makes the equivalence contract hold.
struct TapeParser<'a> {
    b: &'a [u8],
    text: &'a str,
    i: usize,
    strings: &'a [(u32, u32)],
    /// Monotone cursor into `strings` (parser positions only advance).
    si: usize,
}

impl<'a> TapeParser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, message: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek().ok_or_else(|| self.err("unexpected end of input"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(depth),
            b'{' => self.object(depth),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value(depth + 1)?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value(depth + 1)?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    /// Advance the string cursor to the pair opening exactly at `open`,
    /// if the scanner recorded one.
    fn find_pair(&mut self, open: usize) -> Option<usize> {
        while self.si < self.strings.len() && (self.strings[self.si].0 as usize) < open {
            self.si += 1;
        }
        match self.strings.get(self.si) {
            Some(&(o, c)) if o as usize == open => {
                self.si += 1;
                Some(c as usize)
            }
            _ => None,
        }
    }

    /// The tape fast path: a string whose span holds no backslash and
    /// no control byte is sliced out of the input wholesale. Anything
    /// else — escapes, malformed tails, spans the scanner couldn't pair
    /// — drops to [`TapeParser::string_slow`], a verbatim copy of the
    /// legacy byte walk, so errors and escape semantics stay identical.
    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let open = self.i - 1;
        if let Some(close) = self.find_pair(open) {
            let span = &self.b[open + 1..close];
            if span.iter().all(|&c| c != b'\\' && c >= 0x20) {
                // open and close are ASCII quotes, so both slice
                // boundaries are char boundaries
                let s = self.text[open + 1..close].to_string();
                self.i = close + 1;
                return Ok(s);
            }
        }
        self.string_slow()
    }

    fn string_slow(&mut self) -> Result<String> {
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences byte-for-byte
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 structural scan: 32 input bytes per iteration, one compare
    //! per interesting byte class, OR-folded into a movemask whose set
    //! bits are the tape offsets.
    use std::arch::x86_64::*;

    const REST: [u8; 7] = [b'\\', b'{', b'}', b'[', b']', b':', b','];

    #[target_feature(enable = "avx2")]
    unsafe fn classify32(p: *const u8) -> u32 {
        let v = _mm256_loadu_si256(p as *const __m256i);
        let mut m = _mm256_cmpeq_epi8(v, _mm256_set1_epi8(b'"' as i8));
        for &c in &REST {
            m = _mm256_or_si256(m, _mm256_cmpeq_epi8(v, _mm256_set1_epi8(c as i8)));
        }
        _mm256_movemask_epi8(m) as u32
    }

    /// # Safety
    /// Caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scan(bytes: &[u8], out: &mut Vec<u32>) {
        let mut i = 0usize;
        while i + 32 <= bytes.len() {
            let mut m = classify32(bytes.as_ptr().add(i));
            while m != 0 {
                out.push((i + m.trailing_zeros() as usize) as u32);
                m &= m - 1;
            }
            i += 32;
        }
        super::scan_scalar_from(bytes, i, out);
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    //! NEON structural scan: 16 bytes per iteration; the movemask is
    //! emulated with the crate's usual bit-weights + horizontal add.
    use std::arch::aarch64::*;

    const REST: [u8; 7] = [b'\\', b'{', b'}', b'[', b']', b':', b','];

    #[target_feature(enable = "neon")]
    unsafe fn classify16(p: *const u8) -> u16 {
        let v = vld1q_u8(p);
        let mut m = vceqq_u8(v, vdupq_n_u8(b'"'));
        for &c in &REST {
            m = vorrq_u8(m, vceqq_u8(v, vdupq_n_u8(c)));
        }
        const WEIGHTS: [u8; 16] = [1, 2, 4, 8, 16, 32, 64, 128, 1, 2, 4, 8, 16, 32, 64, 128];
        let bits = vandq_u8(m, vld1q_u8(WEIGHTS.as_ptr()));
        let lo = vaddv_u8(vget_low_u8(bits)) as u16;
        let hi = vaddv_u8(vget_high_u8(bits)) as u16;
        lo | (hi << 8)
    }

    /// # Safety
    /// Caller must have verified NEON support.
    #[target_feature(enable = "neon")]
    pub unsafe fn scan(bytes: &[u8], out: &mut Vec<u32>) {
        let mut i = 0usize;
        while i + 16 <= bytes.len() {
            let mut m = classify16(bytes.as_ptr().add(i));
            while m != 0 {
                out.push((i + m.trailing_zeros() as usize) as u32);
                m &= m - 1;
            }
            i += 16;
        }
        super::scan_scalar_from(bytes, i, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tiers() -> Vec<KernelTier> {
        let mut t = vec![KernelTier::Scalar];
        if kernel::detect() != KernelTier::Scalar {
            t.push(kernel::detect());
        }
        t
    }

    #[test]
    fn structural_offsets_scalar_matches_simd() {
        let mut rng = Pcg64::new(7, 0x51);
        for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 63, 64, 65, 200, 1000] {
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let reference = structural_offsets(&bytes, KernelTier::Scalar);
            for &tier in &tiers() {
                assert_eq!(structural_offsets(&bytes, tier), reference, "len={len} tier={tier}");
            }
        }
    }

    #[test]
    fn tape_pairs_quotes_with_escapes() {
        let t = scan_tape(r#"{"a\"b": "c\\", "d": []}"#, KernelTier::Scalar);
        // strings: `a\"b` (1..6), `c\\` (9..13), `d` (16..18)
        assert_eq!(t.strings, vec![(1, 6), (9, 13), (16, 18)]);
    }

    #[test]
    fn tape_parse_equals_legacy_on_corpus() {
        let corpus = [
            r#"{"id": 7, "points": [[1.0, 2.0], [3, 4]]}"#,
            r#"{"stats": true}"#,
            r#"{"a\"b": "c\\d", "u": "A😀"}"#,
            r#"[1, -2.5e3, "x", null, true, false, {}]"#,
            "  [ 1 ,\t2 ]  ",
            r#""just a string""#,
            "42",
            "",
            "not json",
            "{",
            "[1,]",
            r#"{"a" 1}"#,
            r#""unterminated"#,
            r#""bad \q escape""#,
            r#""trunc \u12""#,
            r#""lone \ud800 surrogate""#,
            "[1, 2] trailing",
            r#"{"deep": [[[[[[1]]]]]]}"#,
        ];
        for &tier in &tiers() {
            for doc in corpus {
                let legacy = Json::parse(doc);
                let tape = parse_tape_tier(doc, tier);
                match (legacy, tape) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "value mismatch on {doc:?} tier={tier}"),
                    (Err(_), Err(_)) => {}
                    (l, t) => panic!("ok-ness mismatch on {doc:?} tier={tier}: {l:?} vs {t:?}"),
                }
            }
        }
    }

    #[test]
    fn deep_nesting_is_typed_not_fatal() {
        for &tier in &tiers() {
            assert!(parse_tape_tier(&"[".repeat(100_000), tier).is_err());
            let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
            assert!(parse_tape_tier(&ok, tier).is_ok());
            let over = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
            assert!(parse_tape_tier(&over, tier).is_err());
        }
    }

    #[test]
    fn active_tier_entry_point_parses() {
        let v = parse_tape(r#"{"id": 1, "points": [[0.5]]}"#).unwrap();
        assert_eq!(v.get("id").and_then(Json::as_f64), Some(1.0));
    }
}
