//! Reply plumbing between the batcher and the two serve loops.
//!
//! The batcher thread answers a [`Job`] by calling `job.reply.send(..)`
//! without caring who is listening. In the thread-per-connection loop
//! the listener is the connection thread itself, blocked on a plain
//! channel ([`ReplySink::Channel`]). In the poll loop no thread blocks:
//! the reply is a [`Completion`] tagged with the connection token,
//! pushed onto the reactor's completion channel and followed by a
//! [`Waker`] poke so the reactor's `poll(2)` call returns immediately
//! instead of waiting out its safety-net timeout.
//!
//! The waker is a connected loopback UDP socket: sending one datagram
//! makes the reactor's wake fd readable, which is the cheapest
//! dependency-free self-pipe available through `std` (an actual pipe
//! would need another hand-rolled libc binding; a UDP socket gives the
//! same level-triggered readability with `std::net` alone). Wake sends
//! are fire-and-forget — the reactor also times out of `poll` every
//! 100 ms, so a dropped datagram delays a reply, never loses it.
//!
//! [`Job`]: crate::serve::batcher::Job

use std::io;
use std::net::UdpSocket;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::serve::protocol::Response;

/// A finished request on its way back to the reactor: which connection
/// it belongs to, when it started (for the latency histogram), and the
/// rendered reply line to append to that connection's write buffer
/// (pre-rendered so off-thread work like a model reload can complete
/// with a line that is not a [`Response`] variant).
#[derive(Debug)]
pub struct Completion {
    pub token: u64,
    pub started: Instant,
    pub line: String,
}

/// Pokes the reactor awake after a completion is queued.
#[derive(Debug, Clone)]
pub struct Waker {
    sock: Arc<UdpSocket>,
}

impl Waker {
    /// Build a waker and the nonblocking receive socket the reactor
    /// polls on.
    pub fn pair() -> io::Result<(Waker, UdpSocket)> {
        let rx = UdpSocket::bind("127.0.0.1:0")?;
        rx.set_nonblocking(true)?;
        let tx = UdpSocket::bind("127.0.0.1:0")?;
        tx.connect(rx.local_addr()?)?;
        Ok((Waker { sock: Arc::new(tx) }, rx))
    }

    /// Fire-and-forget poke (see module docs for why errors are moot).
    pub fn wake(&self) {
        let _ = self.sock.send(&[1u8]);
    }
}

/// Where a [`Job`]'s response goes — the batcher stays loop-agnostic.
///
/// [`Job`]: crate::serve::batcher::Job
#[derive(Debug)]
pub enum ReplySink {
    /// Thread-per-connection loop: the connection thread blocks on the
    /// receiving end until its response arrives.
    Channel(mpsc::Sender<Response>),
    /// Poll loop: deliver a [`Completion`] to the reactor and wake it.
    Event { tx: mpsc::Sender<Completion>, token: u64, started: Instant, waker: Waker },
}

impl ReplySink {
    /// Deliver the response. `Err(())` means the listener is gone
    /// (connection thread exited / reactor shut down) — the batcher
    /// treats that as a client that stopped caring, not an error.
    pub fn send(&self, response: Response) -> std::result::Result<(), ()> {
        match self {
            ReplySink::Channel(tx) => tx.send(response).map_err(|_| ()),
            ReplySink::Event { tx, token, started, waker } => {
                let done =
                    Completion { token: *token, started: *started, line: response.to_line() };
                let sent = tx.send(done).map_err(|_| ());
                waker.wake();
                sent
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_sink_delivers() {
        let (tx, rx) = mpsc::channel();
        let sink = ReplySink::Channel(tx);
        sink.send(Response::Err { id: 1, error: "x".into() }).unwrap();
        assert_eq!(rx.recv().unwrap(), Response::Err { id: 1, error: "x".into() });
    }

    #[test]
    fn event_sink_delivers_completion_and_wakes() {
        let (waker, wake_rx) = Waker::pair().unwrap();
        let (tx, rx) = mpsc::channel();
        let started = Instant::now();
        let sink = ReplySink::Event { tx, token: 42, started, waker };
        sink.send(Response::Err { id: 9, error: "y".into() }).unwrap();
        let done = rx.recv().unwrap();
        assert_eq!(done.token, 42);
        assert_eq!(done.line, Response::Err { id: 9, error: "y".into() }.to_line());
        // the wake datagram is observable (may take a scheduling beat)
        wake_rx.set_nonblocking(false).unwrap();
        wake_rx
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        let mut buf = [0u8; 8];
        let (n, _) = wake_rx.recv_from(&mut buf).unwrap();
        assert_eq!(n, 1);
    }

    #[test]
    fn dead_listener_is_err_not_panic() {
        let (tx, rx) = mpsc::channel::<Response>();
        drop(rx);
        let sink = ReplySink::Channel(tx);
        assert!(sink.send(Response::Err { id: 0, error: "z".into() }).is_err());
    }
}
