//! Dynamic batcher: coalesces queued assignment requests into padded
//! AOT `assign` calls.
//!
//! Policy (vLLM-router-style, adapted to fixed-shape artifacts): drain
//! the queue until `max_batch` points are staged or `max_delay` has
//! passed since the first staged request, then run ONE padded chunk
//! call and scatter results back per request. Latency-throughput
//! trade-off is the A-serve ablation in `benches/ablations.rs`.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::runtime::manifest::ExecKind;
use crate::runtime::{Runtime, TensorArg};
use crate::serve::protocol::{Request, Response};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum staged points per device call (must not exceed the
    /// largest available artifact chunk).
    pub max_batch: usize,
    /// Maximum time the first staged request may wait.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4096, max_delay: Duration::from_millis(2) }
    }
}

/// Counters exposed for tests and the `{"stats": true}` probe
/// ([`crate::serve::protocol::stats_line`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BatcherStats {
    pub requests: u64,
    pub points: u64,
    pub device_calls: u64,
    /// Padding rows device calls wasted (chunk size − staged points,
    /// summed per call) — the batching-efficiency observable.
    pub padded_rows: u64,
    pub errors: u64,
}

/// A queued unit of work: one request plus the reply channel.
pub struct Job {
    pub request: Request,
    pub reply: mpsc::Sender<Response>,
}

/// The batcher: owns the runtime + trained centroids.
pub struct Batcher {
    rt: Runtime,
    spec: crate::runtime::ExecSpec,
    centroids: Vec<f32>,
    dim: usize,
    #[allow(dead_code)] // retained for a future /stats endpoint
    k: usize,
    chunk: usize,
    cfg: BatcherConfig,
    pub stats: BatcherStats,
    /// Mirror the server installs ([`Batcher::publish_to`]) so
    /// connection threads can answer `{"stats": true}` without a round
    /// trip through the batcher queue.
    shared: Option<std::sync::Arc<std::sync::Mutex<BatcherStats>>>,
}

impl Batcher {
    /// Build a batcher for a trained model.
    pub fn new(
        artifacts_dir: &std::path::Path,
        centroids: Vec<f32>,
        dim: usize,
        k: usize,
        cfg: BatcherConfig,
    ) -> Result<Batcher> {
        if centroids.len() != dim * k {
            return Err(Error::Shape(format!(
                "centroids len {} != k {k} × dim {dim}",
                centroids.len()
            )));
        }
        let mut rt = Runtime::new_or_native(artifacts_dir)?;
        // smallest artifact chunk that covers max_batch (latency first)
        let mut sizes = crate::coordinator::shared::resolve_chunk_sizes(
            &rt,
            ExecKind::Assign,
            dim,
            k,
            0,
        )?;
        sizes.sort_unstable();
        let chunk = *sizes
            .iter()
            .find(|&&s| s >= cfg.max_batch)
            .or(sizes.last())
            .ok_or_else(|| Error::Manifest("no assign artifacts".into()))?;
        let spec = rt.find(ExecKind::Assign, dim, k, chunk)?;
        rt.prepare(&spec)?;
        Ok(Batcher {
            rt,
            spec,
            centroids,
            dim,
            k,
            chunk,
            cfg: BatcherConfig { max_batch: cfg.max_batch.min(chunk), ..cfg },
            stats: BatcherStats::default(),
            shared: None,
        })
    }

    /// Install a shared stats mirror: after every flush the counters
    /// are copied into it, so readers on other threads see a consistent
    /// point-in-time snapshot (counters are monotone).
    pub fn publish_to(&mut self, shared: std::sync::Arc<std::sync::Mutex<BatcherStats>>) {
        *shared.lock().unwrap() = self.stats.clone();
        self.shared = Some(shared);
    }

    fn publish(&self) {
        if let Some(shared) = &self.shared {
            *shared.lock().unwrap() = self.stats.clone();
        }
    }

    /// Drain the queue and serve until it disconnects (server shutdown).
    pub fn run(&mut self, queue: mpsc::Receiver<Job>) {
        loop {
            // block for the first job of a batch
            let first = match queue.recv() {
                Ok(j) => j,
                Err(_) => return, // all senders dropped
            };
            let deadline = Instant::now() + self.cfg.max_delay;
            let mut jobs = vec![first];
            let mut staged: usize = jobs[0].request.points.len();
            // stage more until full or the delay budget is spent
            while staged < self.cfg.max_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match queue.recv_timeout(left) {
                    Ok(j) => {
                        staged += j.request.points.len();
                        jobs.push(j);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            self.flush(jobs);
        }
    }

    /// Execute one padded device call for `jobs`, scattering replies.
    /// Oversized batches (staged > chunk) split across multiple calls.
    ///
    /// Counter visibility: the shared mirror is published before ANY
    /// reply of a given stage goes out (rejections, device errors,
    /// successes), so a client that receives its response and
    /// immediately probes `{"stats": true}` sees counters that include
    /// its own request.
    pub fn flush(&mut self, jobs: Vec<Job>) {
        // validate dims first; reject bad jobs without spending a call
        let mut valid = Vec::new();
        let mut rejected = Vec::new();
        for job in jobs {
            self.stats.requests += 1;
            if job.request.points.iter().any(|p| p.len() != self.dim) {
                self.stats.errors += 1;
                rejected.push(job);
            } else {
                self.stats.points += job.request.points.len() as u64;
                valid.push(job);
            }
        }
        self.publish();
        for job in rejected {
            let _ = job.reply.send(Response::Err {
                id: job.request.id,
                error: format!("expected {}-dimensional points", self.dim),
            });
        }

        let mut pending: Vec<(Job, Vec<i32>, Vec<f32>)> = Vec::new();
        let mut x = vec![0.0f32; self.chunk * self.dim];
        let mut filled = 0usize;
        // (job index, offset-in-batch, count)
        let mut spans: Vec<(usize, usize, usize)> = Vec::new();

        let flush_device =
            |this: &mut Batcher,
             x: &mut Vec<f32>,
             filled: &mut usize,
             spans: &mut Vec<(usize, usize, usize)>,
             pending: &mut Vec<(Job, Vec<i32>, Vec<f32>)>| {
                if *filled == 0 {
                    return;
                }
                let nv = [*filled as i32];
                let result = this.rt.execute(
                    &this.spec,
                    &[
                        TensorArg::F32(&x[..]),
                        TensorArg::F32(&this.centroids),
                        TensorArg::I32(&nv),
                    ],
                );
                this.stats.device_calls += 1;
                this.stats.padded_rows += (this.chunk - *filled) as u64;
                match result {
                    Ok(outs) => {
                        let assign = outs[0].as_i32();
                        for &(ji, off, cnt) in spans.iter() {
                            let (job, clusters, distances) = &mut pending[ji];
                            for i in 0..cnt {
                                let a = assign[off + i];
                                clusters.push(a);
                                // distance computed host-side (k·cnt tiny)
                                let p = &x[(off + i) * this.dim..(off + i + 1) * this.dim];
                                let c = &this.centroids
                                    [(a as usize) * this.dim..(a as usize + 1) * this.dim];
                                distances.push(crate::linalg::sqdist(p, c));
                            }
                            let _ = job;
                        }
                    }
                    Err(e) => {
                        this.stats.errors += spans.len() as u64;
                        this.publish();
                        for &(ji, _, _) in spans.iter() {
                            let (job, clusters, _) = &mut pending[ji];
                            clusters.clear();
                            let _ = job.reply.send(Response::Err {
                                id: job.request.id,
                                error: e.to_string(),
                            });
                        }
                    }
                }
                *filled = 0;
                spans.clear();
                x.iter_mut().for_each(|v| *v = 0.0);
            };

        for job in valid {
            let n = job.request.points.len();
            let ji = pending.len();
            pending.push((job, Vec::with_capacity(n), Vec::with_capacity(n)));
            let mut remaining = n;
            let mut src = 0usize;
            while remaining > 0 {
                if filled == self.chunk {
                    flush_device(self, &mut x, &mut filled, &mut spans, &mut pending);
                }
                let take = remaining.min(self.chunk - filled);
                for i in 0..take {
                    let p = &pending[ji].0.request.points[src + i];
                    for (jj, &v) in p.iter().enumerate() {
                        x[(filled + i) * self.dim + jj] = v as f32;
                    }
                }
                spans.push((ji, filled, take));
                filled += take;
                src += take;
                remaining -= take;
            }
        }
        flush_device(self, &mut x, &mut filled, &mut spans, &mut pending);

        // publish BEFORE the success replies: a client that receives
        // its response and immediately probes {"stats": true} must see
        // this batch's counters
        self.publish();
        for (job, clusters, distances) in pending {
            if clusters.len() == job.request.points.len() {
                let _ = job.reply.send(Response::Ok {
                    id: job.request.id,
                    clusters,
                    distances,
                });
            }
            // else: error already sent by flush_device
        }
    }

    /// Chunk actually used for device calls (tests).
    pub fn chunk(&self) -> usize {
        self.chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::{self, KmeansConfig};
    use std::sync::mpsc;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn trained_model() -> (Vec<f32>, crate::data::Dataset) {
        let ds = MixtureSpec::paper_3d(4).generate(5000, 3);
        let r = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        (r.centroids, ds)
    }

    fn job(id: u64, points: Vec<Vec<f64>>) -> (Job, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (Job { request: Request { id, points }, reply: tx }, rx)
    }

    #[test]
    fn assigns_to_nearest_centroid() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, ds) = trained_model();
        let mut b =
            Batcher::new(&dir, centroids.clone(), 3, 4, BatcherConfig::default()).unwrap();
        let pts: Vec<Vec<f64>> =
            (0..64).map(|i| ds.point(i).iter().map(|&v| v as f64).collect()).collect();
        let (j, rx) = job(1, pts.clone());
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { id, clusters, distances } => {
                assert_eq!(id, 1);
                assert_eq!(clusters.len(), 64);
                assert_eq!(distances.len(), 64);
                // verify nearest-centroid against host math
                for (i, &c) in clusters.iter().enumerate() {
                    let p: Vec<f32> = pts[i].iter().map(|&v| v as f32).collect();
                    let mut best = 0;
                    let mut best_d = f32::INFINITY;
                    for cc in 0..4 {
                        let d = crate::linalg::sqdist(&p, &centroids[cc * 3..cc * 3 + 3]);
                        if d < best_d {
                            best_d = d;
                            best = cc as i32;
                        }
                    }
                    assert_eq!(c, best, "point {i}");
                    assert!((distances[i] - best_d).abs() < 1e-4);
                }
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.stats.device_calls, 1);
        assert_eq!(b.stats.points, 64);
    }

    #[test]
    fn batches_multiple_requests_into_one_call() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, ds) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let mut rxs = Vec::new();
        let mut jobs = Vec::new();
        for r in 0..10 {
            let pts: Vec<Vec<f64>> = (0..16)
                .map(|i| ds.point(r * 16 + i).iter().map(|&v| v as f64).collect())
                .collect();
            let (j, rx) = job(r as u64, pts);
            jobs.push(j);
            rxs.push(rx);
        }
        b.flush(jobs);
        for (r, rx) in rxs.into_iter().enumerate() {
            match rx.recv().unwrap() {
                Response::Ok { id, clusters, .. } => {
                    assert_eq!(id, r as u64);
                    assert_eq!(clusters.len(), 16);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(b.stats.device_calls, 1, "10 small requests must share one call");
    }

    #[test]
    fn oversized_request_splits_across_calls() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, _) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let chunk = b.chunk();
        let n = chunk + 100; // forces 2 device calls
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 * 0.001, 0.0, 0.0]).collect();
        let (j, rx) = job(5, pts);
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { clusters, .. } => assert_eq!(clusters.len(), n),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.stats.device_calls, 2);
    }

    #[test]
    fn dim_mismatch_rejected_without_device_call() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, _) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let (j, rx) = job(2, vec![vec![1.0, 2.0]]); // 2D point, 3D model
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Err { id, error } => {
                assert_eq!(id, 2);
                assert!(error.contains("3-dimensional"));
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.stats.device_calls, 0);
        assert_eq!(b.stats.errors, 1);
    }

    #[test]
    fn padded_rows_counted_and_mirror_published() {
        // a never-existing artifacts dir forces the native fallback, so
        // this runs artifact-free (same pattern as
        // integration_native_runtime.rs)
        let dir = std::env::temp_dir().join("parakm_batcher_tests/no_artifacts_here");
        let (centroids, _) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let shared = std::sync::Arc::new(std::sync::Mutex::new(BatcherStats::default()));
        b.publish_to(shared.clone());

        let (j, rx) = job(1, vec![vec![0.0, 0.0, 0.0]; 3]);
        b.flush(vec![j]);
        assert!(matches!(rx.recv().unwrap(), Response::Ok { id: 1, .. }));
        assert_eq!(b.stats.device_calls, 1);
        // one call padded from 3 staged points up to the chunk size
        assert_eq!(b.stats.padded_rows, (b.chunk() - 3) as u64);
        // the mirror saw the same snapshot after the flush
        assert_eq!(*shared.lock().unwrap(), b.stats);
    }

    #[test]
    fn bad_centroid_shape_rejected() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(Batcher::new(&dir, vec![0.0; 7], 3, 4, BatcherConfig::default()).is_err());
    }
}
