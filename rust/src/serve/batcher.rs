//! Dynamic batcher: coalesces queued assignment requests into padded
//! AOT `assign` calls.
//!
//! Policy (vLLM-router-style, adapted to fixed-shape artifacts): drain
//! the queue until `max_batch` points are staged or `max_delay` has
//! passed since the first staged request, then run ONE padded chunk
//! call and scatter results back per request. Latency-throughput
//! trade-off is the A-serve ablation in `benches/ablations.rs`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::linalg::kernel::{self, DistancePolicy};
use crate::runtime::manifest::ExecKind;
use crate::runtime::{Runtime, TensorArg};
use crate::serve::protocol::{Request, Response};
use crate::serve::reply::ReplySink;
use crate::util::chaos;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum staged points per device call (must not exceed the
    /// largest available artifact chunk).
    pub max_batch: usize,
    /// Maximum time the first staged request may wait.
    pub max_delay: Duration,
    /// How the host-side response distances are computed (`--distance`;
    /// DESIGN.md §11): `Exact` is the subtract-square reference, `Dot`
    /// reuses the batch's staged point norms and the centroid norms
    /// cached at construction.
    pub distance: DistancePolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 4096,
            max_delay: Duration::from_millis(2),
            distance: DistancePolicy::Exact,
        }
    }
}

/// Counters exposed for tests and the `{"stats": true}` probe
/// ([`crate::serve::protocol::stats_line`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BatcherStats {
    pub requests: u64,
    pub points: u64,
    pub device_calls: u64,
    /// Padding rows device calls wasted (chunk size − staged points,
    /// summed per call) — the batching-efficiency observable.
    pub padded_rows: u64,
    pub errors: u64,
}

/// A queued unit of work: one request plus where its response goes —
/// a blocking channel (thread loop) or the reactor's completion queue
/// (poll loop); see [`ReplySink`].
///
/// A `Job` guarantees an answer: if it is dropped unanswered — the
/// batcher thread panicked mid-flush, the queue was torn down during a
/// restart, a chaos fault swallowed it — the [`Drop`] impl sends a
/// typed [`Response::retry`] to the waiting client. No code path can
/// leave a request hanging (or, on the poll loop, leak its in-flight
/// accounting, which settles through the same completion path).
pub struct Job {
    pub request: Request,
    reply: ReplySink,
    answered: bool,
}

impl Job {
    pub fn new(request: Request, reply: ReplySink) -> Job {
        Job { request, reply, answered: false }
    }

    /// Send the response for this job (at most once; later calls no-op).
    pub fn respond(&mut self, response: Response) {
        if !self.answered {
            self.answered = true;
            let _ = self.reply.send(response);
        }
    }

    /// Mark answered without sending — for callers that already wrote
    /// an inline rejection (e.g. the poll loop's shed path) and only
    /// need to defuse the drop guarantee.
    pub fn dismiss(&mut self) {
        self.answered = true;
    }
}

impl Drop for Job {
    fn drop(&mut self) {
        if !self.answered {
            self.answered = true;
            let _ = self.reply.send(Response::retry(self.request.id));
        }
    }
}

/// One-slot mailbox for hot model swaps: the serve loop publishes a
/// validated `(generation, centroids)` pair off-thread; the batcher
/// installs it at the top of its next flush, so a swap is atomic with
/// respect to batches — every request in one batch is answered by one
/// model generation.
#[derive(Default)]
pub struct ModelSlot {
    dirty: AtomicBool,
    pending: Mutex<Option<(u64, Vec<f32>)>>,
}

impl ModelSlot {
    pub fn new() -> Arc<ModelSlot> {
        Arc::new(ModelSlot::default())
    }

    /// Publish a new model (replaces any not-yet-installed one).
    pub fn publish(&self, generation: u64, centroids: Vec<f32>) {
        *self.pending.lock().unwrap() = Some((generation, centroids));
        self.dirty.store(true, Ordering::Release);
    }

    /// Take the pending model, if any (one relaxed-ish load when idle).
    pub fn take(&self) -> Option<(u64, Vec<f32>)> {
        if !self.dirty.load(Ordering::Acquire) {
            return None;
        }
        self.dirty.store(false, Ordering::Release);
        self.pending.lock().unwrap().take()
    }
}

/// The batcher: owns the runtime + trained centroids.
pub struct Batcher {
    rt: Runtime,
    spec: crate::runtime::ExecSpec,
    centroids: Vec<f32>,
    /// Per-centroid `‖μ‖²`, computed once at construction (the model is
    /// fixed) — the `dot` policy's centroid-norm cache.
    c_norms: Vec<f32>,
    dim: usize,
    k: usize,
    chunk: usize,
    cfg: BatcherConfig,
    pub stats: BatcherStats,
    /// Mirror the server installs ([`Batcher::publish_to`]) so
    /// connection threads can answer `{"stats": true}` without a round
    /// trip through the batcher queue.
    shared: Option<Arc<Mutex<BatcherStats>>>,
    /// Hot-reload mailbox ([`Batcher::watch_model`]); checked at the
    /// top of every flush.
    slot: Option<Arc<ModelSlot>>,
    // ---- flush scratch, reused across batches (no per-request
    // allocation churn): the staged device buffer, its per-row norms
    // (dot policy), and the request spans of the in-flight stage ------
    x: Vec<f32>,
    x_norms: Vec<f32>,
    spans: Vec<(usize, usize, usize)>,
    filled: usize,
}

impl Batcher {
    /// Build a batcher for a trained model.
    pub fn new(
        artifacts_dir: &std::path::Path,
        centroids: Vec<f32>,
        dim: usize,
        k: usize,
        cfg: BatcherConfig,
    ) -> Result<Batcher> {
        if centroids.len() != dim * k {
            return Err(Error::Shape(format!(
                "centroids len {} != k {k} × dim {dim}",
                centroids.len()
            )));
        }
        let mut rt = Runtime::new_or_native(artifacts_dir)?;
        // smallest artifact chunk that covers max_batch (latency first)
        let mut sizes = crate::coordinator::shared::resolve_chunk_sizes(
            &rt,
            ExecKind::Assign,
            dim,
            k,
            0,
        )?;
        sizes.sort_unstable();
        let chunk = *sizes
            .iter()
            .find(|&&s| s >= cfg.max_batch)
            .or(sizes.last())
            .ok_or_else(|| Error::Manifest("no assign artifacts".into()))?;
        let spec = rt.find(ExecKind::Assign, dim, k, chunk)?;
        rt.prepare(&spec)?;
        let c_norms = kernel::row_norms_vec(&centroids, dim);
        Ok(Batcher {
            rt,
            spec,
            centroids,
            c_norms,
            dim,
            k,
            chunk,
            cfg: BatcherConfig { max_batch: cfg.max_batch.min(chunk), ..cfg },
            stats: BatcherStats::default(),
            shared: None,
            slot: None,
            x: vec![0.0f32; chunk * dim],
            x_norms: vec![0.0f32; chunk],
            spans: Vec::new(),
            filled: 0,
        })
    }

    /// Install a shared stats mirror: after every flush the counters
    /// are copied into it, so readers on other threads see a consistent
    /// point-in-time snapshot (counters are monotone).
    pub fn publish_to(&mut self, shared: Arc<Mutex<BatcherStats>>) {
        *shared.lock().unwrap() = self.stats.clone();
        self.shared = Some(shared);
    }

    /// Watch a hot-reload mailbox: a model published into `slot` is
    /// installed at the top of the next flush (centroids + recomputed
    /// norms), so every batch is answered by exactly one generation.
    pub fn watch_model(&mut self, slot: Arc<ModelSlot>) {
        self.slot = Some(slot);
    }

    fn publish(&self) {
        if let Some(shared) = &self.shared {
            *shared.lock().unwrap() = self.stats.clone();
        }
    }

    /// Drain the queue and serve until it disconnects (server shutdown).
    pub fn run(&mut self, queue: mpsc::Receiver<Job>) {
        loop {
            // block for the first job of a batch
            let first = match queue.recv() {
                Ok(j) => j,
                Err(_) => return, // all senders dropped
            };
            let deadline = Instant::now() + self.cfg.max_delay;
            let mut jobs = vec![first];
            let mut staged: usize = jobs[0].request.points.len();
            // stage more until full or the delay budget is spent
            while staged < self.cfg.max_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match queue.recv_timeout(left) {
                    Ok(j) => {
                        staged += j.request.points.len();
                        jobs.push(j);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            self.flush(jobs);
        }
    }

    /// Execute one padded device call for `jobs`, scattering replies.
    /// Oversized batches (staged > chunk) split across multiple calls.
    ///
    /// Counter visibility: the shared mirror is published before ANY
    /// reply of a given stage goes out (rejections, device errors,
    /// successes), so a client that receives its response and
    /// immediately probes `{"stats": true}` sees counters that include
    /// its own request.
    pub fn flush(&mut self, jobs: Vec<Job>) {
        if chaos::hit(chaos::Site::Batcher).is_some() {
            // The supervisor must catch this, answer the staged jobs
            // with ERR_RETRY (via Job::drop) and restart the batcher.
            panic!("chaos: injected batcher panic");
        }
        // install a hot-reloaded model before staging anything, so the
        // whole batch is answered by one generation
        if let Some(slot) = &self.slot {
            if let Some((_generation, centroids)) = slot.take() {
                if centroids.len() == self.dim * self.k {
                    self.centroids = centroids;
                    self.c_norms = kernel::row_norms_vec(&self.centroids, self.dim);
                }
            }
        }
        // validate dims first; reject bad jobs without spending a call
        let mut valid = Vec::new();
        let mut rejected = Vec::new();
        for job in jobs {
            self.stats.requests += 1;
            if job.request.points.iter().any(|p| p.len() != self.dim) {
                self.stats.errors += 1;
                rejected.push(job);
            } else {
                self.stats.points += job.request.points.len() as u64;
                valid.push(job);
            }
        }
        self.publish();
        for mut job in rejected {
            let id = job.request.id;
            job.respond(Response::Err {
                id,
                error: format!("expected {}-dimensional points", self.dim),
            });
        }

        let mut pending: Vec<(Job, Vec<i32>, Vec<f32>)> = Vec::new();
        debug_assert_eq!(self.filled, 0);
        debug_assert!(self.spans.is_empty());

        for job in valid {
            let n = job.request.points.len();
            let ji = pending.len();
            pending.push((job, Vec::with_capacity(n), Vec::with_capacity(n)));
            let mut remaining = n;
            let mut src = 0usize;
            while remaining > 0 {
                if self.filled == self.chunk {
                    self.flush_device(&mut pending);
                }
                let take = remaining.min(self.chunk - self.filled);
                let want_norms = self.cfg.distance == DistancePolicy::Dot;
                for i in 0..take {
                    let p = &pending[ji].0.request.points[src + i];
                    let row = self.filled + i;
                    if want_norms {
                        // stage the row and its ‖x‖² in one pass
                        let mut norm = 0.0f32;
                        for (jj, &v) in p.iter().enumerate() {
                            let vf = v as f32;
                            self.x[row * self.dim + jj] = vf;
                            norm += vf * vf;
                        }
                        self.x_norms[row] = norm;
                    } else {
                        // exact policy never reads x_norms — skip it
                        for (jj, &v) in p.iter().enumerate() {
                            self.x[row * self.dim + jj] = v as f32;
                        }
                    }
                }
                self.spans.push((ji, self.filled, take));
                self.filled += take;
                src += take;
                remaining -= take;
            }
        }
        self.flush_device(&mut pending);

        // publish BEFORE the success replies: a client that receives
        // its response and immediately probes {"stats": true} must see
        // this batch's counters
        self.publish();
        for (mut job, clusters, distances) in pending {
            if clusters.len() == job.request.points.len() {
                let id = job.request.id;
                job.respond(Response::Ok { id, clusters, distances });
            }
            // else: error already sent by flush_device
        }
    }

    /// Execute one padded device call over the staged scratch
    /// (batcher-owned, reused across batches — no per-request
    /// allocation), scattering per-span results into `pending`.
    fn flush_device(&mut self, pending: &mut [(Job, Vec<i32>, Vec<f32>)]) {
        if self.filled == 0 {
            return;
        }
        let nv = [self.filled as i32];
        let result = self.rt.execute(
            &self.spec,
            &[
                TensorArg::F32(&self.x[..]),
                TensorArg::F32(&self.centroids),
                TensorArg::I32(&nv),
            ],
        );
        self.stats.device_calls += 1;
        self.stats.padded_rows += (self.chunk - self.filled) as u64;
        match result {
            Ok(outs) => {
                let assign = outs[0].as_i32();
                for &(ji, off, cnt) in self.spans.iter() {
                    let (job, clusters, distances) = &mut pending[ji];
                    for i in 0..cnt {
                        let a = assign[off + i];
                        clusters.push(a);
                        // distance computed host-side (k·cnt tiny),
                        // per the configured policy
                        let p = &self.x[(off + i) * self.dim..(off + i + 1) * self.dim];
                        let c = &self.centroids
                            [(a as usize) * self.dim..(a as usize + 1) * self.dim];
                        let dval = match self.cfg.distance {
                            DistancePolicy::Exact => crate::linalg::sqdist(p, c),
                            DistancePolicy::Dot => ((self.x_norms[off + i]
                                + self.c_norms[a as usize])
                                - 2.0 * crate::linalg::dot(p, c))
                            .max(0.0),
                        };
                        distances.push(dval);
                    }
                    let _ = job;
                }
            }
            Err(e) => {
                self.stats.errors += self.spans.len() as u64;
                self.publish();
                for &(ji, _, _) in self.spans.iter() {
                    let (job, clusters, _) = &mut pending[ji];
                    clusters.clear();
                    let id = job.request.id;
                    job.respond(Response::Err { id, error: e.to_string() });
                }
            }
        }
        self.filled = 0;
        self.spans.clear();
        self.x.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Chunk actually used for device calls (tests).
    pub fn chunk(&self) -> usize {
        self.chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::{self, KmeansConfig};
    use std::sync::mpsc;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn trained_model() -> (Vec<f32>, crate::data::Dataset) {
        let ds = MixtureSpec::paper_3d(4).generate(5000, 3);
        let r = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        (r.centroids, ds)
    }

    fn job(id: u64, points: Vec<Vec<f64>>) -> (Job, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (Job::new(Request { id, points }, ReplySink::Channel(tx)), rx)
    }

    #[test]
    fn assigns_to_nearest_centroid() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, ds) = trained_model();
        let mut b = Batcher::new(&dir, centroids.clone(), 3, 4, BatcherConfig::default()).unwrap();
        let pts: Vec<Vec<f64>> =
            (0..64).map(|i| ds.point(i).iter().map(|&v| v as f64).collect()).collect();
        let (j, rx) = job(1, pts.clone());
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { id, clusters, distances } => {
                assert_eq!(id, 1);
                assert_eq!(clusters.len(), 64);
                assert_eq!(distances.len(), 64);
                // verify nearest-centroid against host math
                for (i, &c) in clusters.iter().enumerate() {
                    let p: Vec<f32> = pts[i].iter().map(|&v| v as f32).collect();
                    let mut best = 0;
                    let mut best_d = f32::INFINITY;
                    for cc in 0..4 {
                        let d = crate::linalg::sqdist(&p, &centroids[cc * 3..cc * 3 + 3]);
                        if d < best_d {
                            best_d = d;
                            best = cc as i32;
                        }
                    }
                    assert_eq!(c, best, "point {i}");
                    assert!((distances[i] - best_d).abs() < 1e-4);
                }
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.stats.device_calls, 1);
        assert_eq!(b.stats.points, 64);
    }

    #[test]
    fn batches_multiple_requests_into_one_call() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, ds) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let mut rxs = Vec::new();
        let mut jobs = Vec::new();
        for r in 0..10 {
            let pts: Vec<Vec<f64>> = (0..16)
                .map(|i| ds.point(r * 16 + i).iter().map(|&v| v as f64).collect())
                .collect();
            let (j, rx) = job(r as u64, pts);
            jobs.push(j);
            rxs.push(rx);
        }
        b.flush(jobs);
        for (r, rx) in rxs.into_iter().enumerate() {
            match rx.recv().unwrap() {
                Response::Ok { id, clusters, .. } => {
                    assert_eq!(id, r as u64);
                    assert_eq!(clusters.len(), 16);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(b.stats.device_calls, 1, "10 small requests must share one call");
    }

    #[test]
    fn oversized_request_splits_across_calls() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, _) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let chunk = b.chunk();
        let n = chunk + 100; // forces 2 device calls
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 * 0.001, 0.0, 0.0]).collect();
        let (j, rx) = job(5, pts);
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { clusters, .. } => assert_eq!(clusters.len(), n),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.stats.device_calls, 2);
    }

    #[test]
    fn dim_mismatch_rejected_without_device_call() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (centroids, _) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let (j, rx) = job(2, vec![vec![1.0, 2.0]]); // 2D point, 3D model
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Err { id, error } => {
                assert_eq!(id, 2);
                assert!(error.contains("3-dimensional"));
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.stats.device_calls, 0);
        assert_eq!(b.stats.errors, 1);
    }

    #[test]
    fn padded_rows_counted_and_mirror_published() {
        // a never-existing artifacts dir forces the native fallback, so
        // this runs artifact-free (same pattern as
        // integration_native_runtime.rs)
        let dir = std::env::temp_dir().join("parakm_batcher_tests/no_artifacts_here");
        let (centroids, _) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let shared = std::sync::Arc::new(std::sync::Mutex::new(BatcherStats::default()));
        b.publish_to(shared.clone());

        let (j, rx) = job(1, vec![vec![0.0, 0.0, 0.0]; 3]);
        b.flush(vec![j]);
        assert!(matches!(rx.recv().unwrap(), Response::Ok { id: 1, .. }));
        assert_eq!(b.stats.device_calls, 1);
        // one call padded from 3 staged points up to the chunk size
        assert_eq!(b.stats.padded_rows, (b.chunk() - 3) as u64);
        // the mirror saw the same snapshot after the flush
        assert_eq!(*shared.lock().unwrap(), b.stats);
    }

    #[test]
    fn scratch_reuse_keeps_responses_identical_across_batches() {
        // artifact-free native fallback (same pattern as
        // padded_rows_counted_and_mirror_published)
        let dir = std::env::temp_dir().join("parakm_batcher_tests/no_artifacts_here");
        let (centroids, ds) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let pts: Vec<Vec<f64>> =
            (0..40).map(|i| ds.point(i).iter().map(|&v| v as f64).collect()).collect();

        // same request flushed three times through the same batcher:
        // the reused scratch must never leak state between batches
        let mut replies = Vec::new();
        for round in 0..3u64 {
            let (j, rx) = job(round, pts.clone());
            b.flush(vec![j]);
            match rx.recv().unwrap() {
                Response::Ok { clusters, distances, .. } => replies.push((clusters, distances)),
                other => panic!("round {round}: unexpected {other:?}"),
            }
        }
        assert_eq!(replies[0], replies[1]);
        assert_eq!(replies[1], replies[2]);

        // and identical to a freshly-constructed batcher's answer
        let (centroids2, _) = trained_model();
        let mut fresh = Batcher::new(&dir, centroids2, 3, 4, BatcherConfig::default()).unwrap();
        let (j, rx) = job(9, pts);
        fresh.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { clusters, distances, .. } => {
                assert_eq!((clusters, distances), replies[0]);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn dot_policy_matches_exact_responses() {
        let dir = std::env::temp_dir().join("parakm_batcher_tests/no_artifacts_here");
        let (centroids, ds) = trained_model();
        let pts: Vec<Vec<f64>> =
            (0..64).map(|i| ds.point(i).iter().map(|&v| v as f64).collect()).collect();

        let mut exact =
            Batcher::new(&dir, centroids.clone(), 3, 4, BatcherConfig::default()).unwrap();
        let (j, rx) = job(1, pts.clone());
        exact.flush(vec![j]);
        let (c_exact, d_exact) = match rx.recv().unwrap() {
            Response::Ok { clusters, distances, .. } => (clusters, distances),
            other => panic!("unexpected {other:?}"),
        };

        let cfg = BatcherConfig {
            distance: crate::linalg::kernel::DistancePolicy::Dot,
            ..BatcherConfig::default()
        };
        let mut dot = Batcher::new(&dir, centroids, 3, 4, cfg).unwrap();
        let (j, rx) = job(1, pts);
        dot.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { clusters, distances, .. } => {
                // assignment comes from the runtime either way; only
                // the reported distance formulation changes
                assert_eq!(clusters, c_exact);
                for (i, (a, b)) in distances.iter().zip(&d_exact).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                        "point {i}: dot {a} vs exact {b}"
                    );
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn bad_centroid_shape_rejected() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(Batcher::new(&dir, vec![0.0; 7], 3, 4, BatcherConfig::default()).is_err());
    }

    #[test]
    fn dropped_job_answers_with_typed_retry() {
        // a Job that dies unanswered — batcher panic, queue teardown —
        // must still answer its client, with ERR_RETRY under its own id
        let (j, rx) = job(17, vec![vec![0.0, 0.0, 0.0]]);
        drop(j);
        let r = rx.recv().unwrap();
        assert!(r.is_retry(), "{r:?}");
        assert!(matches!(r, Response::Err { id: 17, .. }), "{r:?}");
        // an answered job must NOT double-send on drop
        let (mut j, rx) = job(3, vec![vec![0.0, 0.0, 0.0]]);
        j.respond(Response::Ok { id: 3, clusters: vec![0], distances: vec![0.0] });
        drop(j);
        assert!(matches!(rx.recv().unwrap(), Response::Ok { id: 3, .. }));
        assert!(rx.recv().is_err(), "exactly one response per job");
        // a dismissed job sends nothing at all
        let (mut j, rx) = job(4, vec![vec![0.0, 0.0, 0.0]]);
        j.dismiss();
        drop(j);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn model_slot_swaps_centroids_between_batches() {
        let dir = std::env::temp_dir().join("parakm_batcher_tests/no_artifacts_here");
        let (centroids, _) = trained_model();
        let mut b = Batcher::new(&dir, centroids, 3, 4, BatcherConfig::default()).unwrap();
        let slot = ModelSlot::new();
        b.watch_model(slot.clone());

        let probe = vec![vec![100.0, 100.0, 100.0]];
        let (j, rx) = job(1, probe.clone());
        b.flush(vec![j]);
        let before = match rx.recv().unwrap() {
            Response::Ok { distances, .. } => distances[0],
            other => panic!("unexpected {other:?}"),
        };

        // second generation: every centroid at the probe point
        slot.publish(2, vec![100.0f32; 12]);
        let (j, rx) = job(2, probe);
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { distances, .. } => {
                assert!(distances[0] < 1e-6, "new model should be at the probe point");
                assert_ne!(distances[0], before);
            }
            other => panic!("unexpected {other:?}"),
        }

        // a wrong-shape publish is ignored defensively
        slot.publish(3, vec![1.0f32; 5]);
        let (j, rx) = job(3, vec![vec![100.0, 100.0, 100.0]]);
        b.flush(vec![j]);
        match rx.recv().unwrap() {
            Response::Ok { distances, .. } => assert!(distances[0] < 1e-6),
            other => panic!("unexpected {other:?}"),
        }
    }
}
