//! TCP front end: accept connections, parse line-JSON requests, queue
//! them to the batcher thread, route responses back.
//!
//! One OS thread per connection (blocking reads), one batcher thread
//! owning the runtime; a bounded `sync_channel` between them provides
//! backpressure: when the device falls behind, acceptors block instead
//! of buffering unboundedly. Connection threads themselves are capped
//! by [`ServeConfig::max_conns`]: past the cap the acceptor answers
//! with the typed [`Response::saturated`] rejection and closes, so a
//! connection flood cannot spawn unbounded OS threads.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::error::Result;
use crate::serve::batcher::{Batcher, BatcherConfig, BatcherStats, Job};
use crate::serve::protocol::{self, ClientRequest, Response};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7878`. Port 0 picks a free port.
    pub addr: String,
    pub artifacts_dir: PathBuf,
    pub batcher: BatcherConfig,
    /// Queue capacity (requests) between acceptors and the batcher.
    pub queue_depth: usize,
    /// Maximum concurrent connection-handler threads. Connections past
    /// the cap receive the typed [`Response::saturated`] rejection and
    /// are closed instead of spawning a thread.
    pub max_conns: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            artifacts_dir: "artifacts".into(),
            batcher: BatcherConfig::default(),
            queue_depth: 256,
            max_conns: 64,
        }
    }
}

/// RAII share of the connection cap: decrements the live-connection
/// counter when the handler thread exits (however it exits).
struct ConnPermit(Arc<AtomicUsize>);

impl ConnPermit {
    /// Try to take a slot under `cap`; `None` when saturated.
    fn acquire(active: &Arc<AtomicUsize>, cap: usize) -> Option<ConnPermit> {
        active
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |c| {
                (c < cap).then_some(c + 1)
            })
            .ok()
            .map(|_| ConnPermit(active.clone()))
    }
}

impl Drop for ConnPermit {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Handle to a running server (tests use it to stop cleanly).
pub struct ServerHandle {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and join the acceptor.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        // poke the listener out of accept()
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Start serving a trained model (non-blocking; returns a handle).
///
/// `centroids` is the trained k×dim model (row-major).
pub fn serve(
    cfg: ServeConfig,
    centroids: Vec<f32>,
    dim: usize,
    k: usize,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));

    let (queue_tx, queue_rx) = mpsc::sync_channel::<Job>(cfg.queue_depth);

    // live counters for the {"stats": true} probe: the batcher mirrors
    // its counters here after every flush; the acceptor counts
    // saturation rejections. Connection threads answer stats requests
    // from these directly — no batcher round trip, and the probe keeps
    // working even if the batcher thread died.
    let stats_shared = Arc::new(Mutex::new(BatcherStats::default()));
    let saturated = Arc::new(AtomicU64::new(0));

    // batcher thread owns the (non-Send) runtime
    let artifacts = cfg.artifacts_dir.clone();
    let bcfg = cfg.batcher.clone();
    let stats_for_batcher = stats_shared.clone();
    std::thread::Builder::new()
        .name("parakm-batcher".into())
        .spawn(move || {
            let mut batcher = match Batcher::new(&artifacts, centroids, dim, k, bcfg) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("batcher init failed: {e}");
                    return;
                }
            };
            batcher.publish_to(stats_for_batcher);
            // adapt sync_channel receiver to the batcher loop
            let (tx, rx) = mpsc::channel();
            std::thread::spawn(move || {
                while let Ok(job) = queue_rx.recv() {
                    if tx.send(job).is_err() {
                        break;
                    }
                }
            });
            batcher.run(rx);
        })
        .expect("spawn batcher");

    // acceptor thread
    let stop2 = stop.clone();
    let max_conns = cfg.max_conns;
    let active = Arc::new(AtomicUsize::new(0));
    let accept_thread = std::thread::Builder::new()
        .name("parakm-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Acquire) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        // small request/response lines: Nagle + delayed
                        // ACK would add ~40 ms stalls per round trip
                        let _ = stream.set_nodelay(true);
                        match ConnPermit::acquire(&active, max_conns) {
                            Some(permit) => {
                                let q = queue_tx.clone();
                                let stats = stats_shared.clone();
                                let saturated = saturated.clone();
                                std::thread::spawn(move || {
                                    let _permit = permit; // released on exit
                                    handle_conn(stream, q, stats, saturated);
                                });
                            }
                            None => {
                                saturated.fetch_add(1, Ordering::AcqRel);
                                // typed rejection, written inline: one
                                // short line into an empty socket
                                // buffer cannot block the acceptor
                                let mut stream = stream;
                                let _ = writeln!(stream, "{}", Response::saturated().to_line());
                            }
                        }
                    }
                    Err(e) => eprintln!("accept error: {e}"),
                }
            }
        })
        .expect("spawn acceptor");

    Ok(ServerHandle { local_addr, stop, accept_thread: Some(accept_thread) })
}

/// Per-connection loop: read request lines, queue jobs, write replies
/// in completion order (ids let clients correlate). `{"stats": true}`
/// lines are answered inline from the shared counters.
fn handle_conn(
    stream: TcpStream,
    queue: mpsc::SyncSender<Job>,
    stats: Arc<Mutex<BatcherStats>>,
    saturated: Arc<AtomicU64>,
) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client hung up
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match ClientRequest::parse(&line) {
            Ok(ClientRequest::Stats) => {
                let snapshot = stats.lock().unwrap().clone();
                protocol::stats_line(&snapshot, saturated.load(Ordering::Acquire))
            }
            Ok(ClientRequest::Assign(request)) => {
                let (tx, rx) = mpsc::channel();
                if queue.send(Job { request, reply: tx }).is_err() {
                    break; // batcher gone; drop connection
                }
                match rx.recv() {
                    Ok(r) => r.to_line(),
                    Err(_) => break,
                }
            }
            Err(e) => Response::Err { id: 0, error: e.to_string() }.to_line(),
        };
        if writeln!(writer, "{reply_line}").is_err() {
            break;
        }
    }
    let _ = peer;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::{self, KmeansConfig};
    use std::io::{BufRead, BufReader, Write};

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn start_server() -> Option<(ServerHandle, Vec<f32>)> {
        let dir = artifacts_dir()?;
        let ds = MixtureSpec::paper_3d(4).generate(3000, 3);
        let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            artifacts_dir: dir,
            ..Default::default()
        };
        let handle = serve(cfg, model.centroids.clone(), 3, 4).unwrap();
        Some((handle, model.centroids))
    }

    #[test]
    fn end_to_end_request_response() {
        let Some((server, centroids)) = start_server() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut conn = TcpStream::connect(server.local_addr).unwrap();
        writeln!(conn, r#"{{"id": 42, "points": [[0.0, 0.0, 0.0], [5.0, 5.0, 5.0]]}}"#)
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        match Response::parse(&line).unwrap() {
            Response::Ok { id, clusters, distances } => {
                assert_eq!(id, 42);
                assert_eq!(clusters.len(), 2);
                assert_eq!(distances.len(), 2);
                assert!(clusters.iter().all(|&c| (0..4).contains(&c)));
            }
            other => panic!("unexpected {other:?}"),
        }
        let _ = centroids;
        server.shutdown();
    }

    #[test]
    fn pipelined_requests_same_connection() {
        let Some((server, _)) = start_server() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut conn = TcpStream::connect(server.local_addr).unwrap();
        for i in 0..5 {
            writeln!(conn, r#"{{"id": {i}, "points": [[{i}.0, 0.0, 1.0]]}}"#).unwrap();
        }
        let reader = BufReader::new(conn.try_clone().unwrap());
        let mut seen = Vec::new();
        for line in reader.lines().take(5) {
            match Response::parse(&line.unwrap()).unwrap() {
                Response::Ok { id, .. } => seen.push(id),
                other => panic!("unexpected {other:?}"),
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        server.shutdown();
    }

    #[test]
    fn malformed_request_gets_error_not_disconnect() {
        let Some((server, _)) = start_server() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut conn = TcpStream::connect(server.local_addr).unwrap();
        writeln!(conn, "this is not json").unwrap();
        writeln!(conn, r#"{{"id": 1, "points": [[1.0, 2.0, 3.0]]}}"#).unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        let mut lines = reader.lines();
        let first = Response::parse(&lines.next().unwrap().unwrap()).unwrap();
        assert!(matches!(first, Response::Err { .. }), "{first:?}");
        let second = Response::parse(&lines.next().unwrap().unwrap()).unwrap();
        assert!(matches!(second, Response::Ok { id: 1, .. }), "{second:?}");
        server.shutdown();
    }

    #[test]
    fn zero_cap_rejects_every_connection_with_typed_error() {
        // the rejection path never touches the batcher, so this runs
        // artifact-free (the batcher falls back to the native runtime
        // or dies; the acceptor does not care)
        let ds = MixtureSpec::paper_3d(4).generate(200, 3);
        let model = kmeans::serial::run(&ds, &KmeansConfig::new(2).with_seed(1));
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_conns: 0,
            ..Default::default()
        };
        let server = serve(cfg, model.centroids.clone(), 3, 2).unwrap();
        for _ in 0..3 {
            let conn = TcpStream::connect(server.local_addr).unwrap();
            let mut reader = BufReader::new(conn);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Response::parse(&line).unwrap();
            assert!(resp.is_saturated(), "{resp:?}");
            // and the connection is closed, not left dangling
            line.clear();
            assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        }
        server.shutdown();
    }

    #[test]
    fn capacity_frees_when_connection_closes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ds = MixtureSpec::paper_3d(4).generate(3000, 3);
        let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            artifacts_dir: dir,
            max_conns: 1,
            ..Default::default()
        };
        let server = serve(cfg, model.centroids.clone(), 3, 4).unwrap();

        // first client occupies the only slot (round-trip proves the
        // handler thread is live, not just queued in the accept loop)
        let mut c1 = TcpStream::connect(server.local_addr).unwrap();
        writeln!(c1, r#"{{"id": 1, "points": [[0.0, 0.0, 0.0]]}}"#).unwrap();
        let mut r1 = BufReader::new(c1.try_clone().unwrap());
        let mut line = String::new();
        r1.read_line(&mut line).unwrap();
        assert!(matches!(Response::parse(&line).unwrap(), Response::Ok { id: 1, .. }));

        // second client is rejected with the typed error
        let c2 = TcpStream::connect(server.local_addr).unwrap();
        let mut r2 = BufReader::new(c2);
        line.clear();
        r2.read_line(&mut line).unwrap();
        assert!(Response::parse(&line).unwrap().is_saturated(), "{line}");

        // slot frees once c1 hangs up (poll: the handler thread needs
        // a moment to observe the close and drop its permit)
        drop(r1);
        drop(c1);
        let mut ok = false;
        for _ in 0..100 {
            let mut c3 = TcpStream::connect(server.local_addr).unwrap();
            writeln!(c3, r#"{{"id": 3, "points": [[1.0, 1.0, 1.0]]}}"#).unwrap();
            let mut r3 = BufReader::new(c3);
            line.clear();
            r3.read_line(&mut line).unwrap();
            if matches!(Response::parse(&line).unwrap(), Response::Ok { id: 3, .. }) {
                ok = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(ok, "slot never freed after client disconnect");
        server.shutdown();
    }

    #[test]
    fn stats_probe_reports_counters() {
        use crate::util::json::Json;
        // never-existing artifacts dir: native fallback, artifact-free
        let dir = std::env::temp_dir().join("parakm_server_tests/no_artifacts_here");
        let ds = MixtureSpec::paper_3d(4).generate(500, 3);
        let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            artifacts_dir: dir,
            max_conns: 1,
            ..Default::default()
        };
        let server = serve(cfg, model.centroids.clone(), 3, 4).unwrap();

        let mut conn = TcpStream::connect(server.local_addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        // a fresh server reports zeros
        writeln!(conn, r#"{{"stats": true}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        let s = j.get("stats").expect("stats object");
        assert_eq!(s.get("requests").and_then(Json::as_f64), Some(0.0));
        assert_eq!(s.get("saturated").and_then(Json::as_f64), Some(0.0));

        // one assignment, one saturated rejection...
        writeln!(conn, r#"{{"id": 1, "points": [[0.0, 0.0, 0.0], [1.0, 1.0, 1.0]]}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(matches!(Response::parse(&line).unwrap(), Response::Ok { id: 1, .. }), "{line}");
        let rej = TcpStream::connect(server.local_addr).unwrap();
        let mut rej_reader = BufReader::new(rej);
        line.clear();
        rej_reader.read_line(&mut line).unwrap();
        assert!(Response::parse(&line).unwrap().is_saturated(), "{line}");

        // ...and the probe reflects both on the still-open connection
        writeln!(conn, r#"{{"stats": true}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        let s = j.get("stats").expect("stats object");
        assert_eq!(s.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(s.get("points").and_then(Json::as_f64), Some(2.0));
        assert_eq!(s.get("batches").and_then(Json::as_f64), Some(1.0));
        assert_eq!(s.get("saturated").and_then(Json::as_f64), Some(1.0));
        assert!(s.get("padded_rows").and_then(Json::as_f64).unwrap() >= 0.0, "{line}");
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let Some((server, _)) = start_server() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let addr = server.local_addr;
        let handles: Vec<_> = (0..8)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut conn = TcpStream::connect(addr).unwrap();
                    writeln!(
                        conn,
                        r#"{{"id": {c}, "points": [[{c}.5, 1.0, -2.0], [0.0, 0.0, 0.0]]}}"#
                    )
                    .unwrap();
                    let mut reader = BufReader::new(conn);
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    match Response::parse(&line).unwrap() {
                        Response::Ok { id, clusters, .. } => {
                            assert_eq!(id, c);
                            assert_eq!(clusters.len(), 2);
                        }
                        other => panic!("unexpected {other:?}"),
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }
}
