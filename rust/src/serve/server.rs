//! TCP front end: accept connections, parse line-JSON requests, queue
//! them to the batcher thread, route responses back.
//!
//! Two interchangeable serve loops ([`ServeLoop`], `--serve-loop`):
//!
//! - **Poll** (default on unix): one reactor thread multiplexes every
//!   client socket through a nonblocking `poll(2)` event loop
//!   ([`crate::serve::poll`]) with per-connection read/write buffers —
//!   connection count is bounded by fd budget, not OS threads, and the
//!   request parser is the SIMD tape scanner.
//! - **Threads** (the legacy escape hatch, default off-unix): one OS
//!   thread per connection (blocking reads) capped by
//!   [`ServeConfig::max_conns`]; past the cap the acceptor answers the
//!   typed [`Response::saturated`] rejection and closes.
//!
//! Both loops feed the same bounded `sync_channel` into the single
//! batcher thread that owns the (non-`Send`) runtime, share one
//! [`ServeShared`] counter block (so `{"stats": true}` reads
//! identically), bound request lines to [`ServeConfig::max_line_bytes`]
//! (an endless un-newlined line is a one-socket memory DoS otherwise),
//! record every request into the latency histogram, and apply the
//! graduated shed tiers of [`ShedConfig`]. Their responses are
//! byte-identical on the same request corpus — pinned by tests here
//! and by the CI serve-smoke diff.
//!
//! The lifecycle layer (DESIGN.md §16) rides on the same shared block:
//! a supervisor thread restarts a dead or panicked batcher with capped
//! backoff, `{"reload": path}` / SIGHUP hot-swap the model by atomic
//! generation, [`ServerHandle::drain`] implements the SIGTERM graceful
//! drain, and `{"health": true}` distinguishes live from ready.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::serve::batcher::{Batcher, BatcherConfig, BatcherStats, Job, ModelSlot};
use crate::serve::histo::LatencyHisto;
use crate::serve::protocol::{self, ClientRequest, Response, ServeStats};
use crate::serve::reply::ReplySink;
use crate::util::chaos;

/// Which event loop drives the front end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeLoop {
    /// Nonblocking poll-based reactor (unix only).
    Poll,
    /// Thread-per-connection (the legacy path; any host).
    Threads,
}

impl ServeLoop {
    /// Poll where the `poll(2)` binding exists, threads elsewhere.
    pub fn default_for_host() -> ServeLoop {
        if cfg!(unix) {
            ServeLoop::Poll
        } else {
            ServeLoop::Threads
        }
    }
}

impl Default for ServeLoop {
    fn default() -> Self {
        ServeLoop::default_for_host()
    }
}

impl std::str::FromStr for ServeLoop {
    type Err = Error;

    fn from_str(s: &str) -> Result<ServeLoop> {
        match s {
            "poll" => Ok(ServeLoop::Poll),
            "threads" => Ok(ServeLoop::Threads),
            other => Err(Error::Config(format!("unknown serve loop `{other}` (poll|threads)"))),
        }
    }
}

impl std::fmt::Display for ServeLoop {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ServeLoop::Poll => "poll",
            ServeLoop::Threads => "threads",
        })
    }
}

/// Graduated load-shedding knobs (DESIGN.md §13). The tiers, in order:
///
/// 1. **accept** — past [`ServeConfig::max_conns`] live connections the
///    typed saturation rejection closes the socket (both loops).
/// 2. **queue (soft)** — once in-flight requests reach `soft_pct`% of
///    the queue depth, requests carrying `heavy_points`+ points get the
///    typed [`protocol::ERR_SHED_HEAVY`] rejection instead of queueing:
///    under pressure, bulk traffic yields to interactive traffic.
/// 3. **shed (hard)** — poll loop only: when the bounded queue is
///    completely full the request gets [`protocol::ERR_SHED_LOAD`]
///    instead of blocking the reactor (the threads loop blocks the
///    connection's own thread instead — per-connection backpressure).
///
/// Stats probes are always answered inline and are never shed.
#[derive(Debug, Clone)]
pub struct ShedConfig {
    /// Queue-pressure threshold for the soft tier, percent of
    /// [`ServeConfig::queue_depth`] (0 sheds every heavy request,
    /// 100 only sheds at a full queue).
    pub soft_pct: u32,
    /// Point count at which a request counts as heavy.
    pub heavy_points: usize,
}

impl Default for ShedConfig {
    fn default() -> Self {
        ShedConfig { soft_pct: 75, heavy_points: 1024 }
    }
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7878`. Port 0 picks a free port.
    pub addr: String,
    pub artifacts_dir: PathBuf,
    pub batcher: BatcherConfig,
    /// Queue capacity (requests) between the front end and the batcher.
    pub queue_depth: usize,
    /// Maximum concurrent connections (handler threads in the threads
    /// loop, registered sockets in the poll loop). Connections past the
    /// cap receive the typed [`Response::saturated`] rejection.
    pub max_conns: usize,
    /// Which event loop runs the front end.
    pub loop_mode: ServeLoop,
    /// Maximum request line length in bytes; longer lines get the typed
    /// [`protocol::ERR_LINE_TOO_LONG`] rejection and the connection is
    /// closed (the remainder of the line cannot be resynchronized).
    pub max_line_bytes: usize,
    /// Load-shedding tiers.
    pub shed: ShedConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            artifacts_dir: "artifacts".into(),
            batcher: BatcherConfig::default(),
            queue_depth: 256,
            max_conns: 64,
            loop_mode: ServeLoop::default_for_host(),
            max_line_bytes: 1 << 20,
            shed: ShedConfig::default(),
        }
    }
}

/// Serve-lifecycle state (DESIGN.md §16): model generations, batcher
/// supervision and drain progress. Lives inside [`ServeShared`] so the
/// `{"stats"}` / `{"health"}` probes read it without extra plumbing.
#[derive(Debug)]
pub struct Lifecycle {
    /// Model dimensionality the server was started with (reload gate).
    pub dim: usize,
    /// Cluster count the server was started with (reload gate).
    pub k: usize,
    /// Monotonic model generation; 1 is the model `serve()` started
    /// with, each successful reload bumps it.
    pub generation: AtomicU64,
    /// Completed batcher restarts (0 on a healthy server).
    pub restarts: AtomicU64,
    /// Human-readable reason for the most recent batcher restart.
    pub last_restart: Mutex<String>,
    /// The batcher thread is initialized and consuming jobs.
    pub batcher_up: AtomicBool,
    /// SIGTERM drain in progress: not accepting, flushing in-flight.
    pub draining: AtomicBool,
    /// Hot-reload mailbox the batcher swaps from between batches.
    pub slot: Arc<ModelSlot>,
}

/// Counters and instruments shared by the front end, the batcher
/// mirror and the `{"stats": true}` probe — one block, so both serve
/// loops report identically.
#[derive(Debug)]
pub struct ServeShared {
    /// Batcher counter mirror ([`Batcher::publish_to`]).
    pub batcher: Arc<Mutex<BatcherStats>>,
    /// Lifecycle state: generations, supervision, drain.
    pub lifecycle: Lifecycle,
    /// Accept-tier rejections (connection cap).
    pub saturated: AtomicU64,
    /// Soft-tier rejections (queue pressure × heavy request).
    pub shed_heavy: AtomicU64,
    /// Hard-tier rejections (queue full, poll loop).
    pub shed_load: AtomicU64,
    /// Oversized-line rejections.
    pub oversized: AtomicU64,
    /// Requests accepted but not yet answered (shed-tier input).
    pub inflight: AtomicUsize,
    /// Per-request latency histogram (log-bucketed).
    pub latency: Mutex<LatencyHisto>,
}

impl ServeShared {
    fn new(dim: usize, k: usize) -> Arc<ServeShared> {
        Arc::new(ServeShared {
            batcher: Arc::new(Mutex::new(BatcherStats::default())),
            lifecycle: Lifecycle {
                dim,
                k,
                generation: AtomicU64::new(1),
                restarts: AtomicU64::new(0),
                last_restart: Mutex::new(String::new()),
                batcher_up: AtomicBool::new(false),
                draining: AtomicBool::new(false),
                slot: ModelSlot::new(),
            },
            saturated: AtomicU64::new(0),
            shed_heavy: AtomicU64::new(0),
            shed_load: AtomicU64::new(0),
            oversized: AtomicU64::new(0),
            inflight: AtomicUsize::new(0),
            latency: Mutex::new(LatencyHisto::default()),
        })
    }

    /// Point-in-time snapshot for [`protocol::stats_line`] / the CLI.
    pub fn snapshot(&self) -> ServeStats {
        ServeStats {
            batcher: self.batcher.lock().unwrap().clone(),
            saturated: self.saturated.load(Ordering::Acquire),
            shed_heavy: self.shed_heavy.load(Ordering::Acquire),
            shed_load: self.shed_load.load(Ordering::Acquire),
            oversized: self.oversized.load(Ordering::Acquire),
            latency: self.latency.lock().unwrap().summary(),
            artifact_warnings: crate::data::io::artifact_warnings(),
            empty_events: crate::util::trace::empty_events_total(),
            model_generation: self.lifecycle.generation.load(Ordering::Acquire),
            batcher_restarts: self.lifecycle.restarts.load(Ordering::Acquire),
            batcher_last_restart: self.lifecycle.last_restart.lock().unwrap().clone(),
            batcher_up: self.lifecycle.batcher_up.load(Ordering::Acquire),
            draining: self.lifecycle.draining.load(Ordering::Acquire),
        }
    }

    pub(crate) fn record_latency(&self, started: Instant) {
        self.latency.lock().unwrap().record(started.elapsed());
    }
}

/// The soft shed tier, shared by both loops: under queue pressure,
/// heavy requests are rejected before they are queued. Returns the
/// typed error string (and counts the rejection) when the request
/// must be shed.
pub(crate) fn shed_decision(
    shared: &ServeShared,
    queue_depth: usize,
    shed: &ShedConfig,
    points: usize,
) -> Option<&'static str> {
    let soft_limit = queue_depth.saturating_mul(shed.soft_pct as usize) / 100;
    if points >= shed.heavy_points && shared.inflight.load(Ordering::Acquire) >= soft_limit {
        shared.shed_heavy.fetch_add(1, Ordering::AcqRel);
        return Some(protocol::ERR_SHED_HEAVY);
    }
    None
}

/// Load, validate and publish a replacement model — the `{"reload"}`
/// request and SIGHUP both land here. The file is CRC-validated
/// ([`crate::data::io::read_model`]) and shape-checked before anything
/// is swapped, so a bad file leaves the serving model untouched
/// (rollback is "never installed"). Returns the new generation.
pub fn reload_model(shared: &ServeShared, path: &Path) -> Result<u64> {
    let model = crate::data::io::read_model(path)?;
    let lc = &shared.lifecycle;
    if model.dim != lc.dim || model.k != lc.k {
        return Err(Error::Config(format!(
            "model {} has k={} dim={}, server expects k={} dim={}",
            path.display(),
            model.k,
            model.dim,
            lc.k,
            lc.dim
        )));
    }
    let generation = lc.generation.fetch_add(1, Ordering::AcqRel) + 1;
    lc.slot.publish(generation, model.centroids);
    Ok(generation)
}

/// Answer a `{"reload": path}` request: the success line with the new
/// generation, or the typed [`protocol::ERR_RELOAD`] error. Shared by
/// both loops so their responses stay byte-identical.
pub(crate) fn reload_response(shared: &ServeShared, path: &str) -> String {
    match reload_model(shared, Path::new(path)) {
        Ok(generation) => protocol::reload_line(generation),
        Err(e) => {
            Response::Err { id: 0, error: format!("{}: {e}", protocol::ERR_RELOAD) }.to_line()
        }
    }
}

/// RAII share of the connection cap: decrements the live-connection
/// counter when the handler thread exits (however it exits).
struct ConnPermit(Arc<AtomicUsize>);

impl ConnPermit {
    /// Try to take a slot under `cap`; `None` when saturated.
    fn acquire(active: &Arc<AtomicUsize>, cap: usize) -> Option<ConnPermit> {
        active
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |c| {
                (c < cap).then_some(c + 1)
            })
            .ok()
            .map(|_| ConnPermit(active.clone()))
    }
}

impl Drop for ConnPermit {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Handle to a running server (tests use it to stop cleanly).
pub struct ServerHandle {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shared: Arc<ServeShared>,
}

impl ServerHandle {
    /// Live counters (the CLI `--stats-every` summary reads these).
    pub fn stats(&self) -> ServeStats {
        self.shared.snapshot()
    }

    /// Signal shutdown and join the front-end thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        // poke the listener out of accept()/poll()
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }

    /// Hot-swap the serving model (the SIGHUP path; `{"reload"}`
    /// requests go through the serve loops). Returns the generation.
    pub fn reload_from(&self, path: &Path) -> Result<u64> {
        reload_model(&self.shared, path)
    }

    /// Graceful drain (the SIGTERM path): stop accepting, let in-flight
    /// requests finish and their replies flush (bounded by `timeout`),
    /// then return the final stats snapshot for the shutdown summary.
    pub fn drain(mut self, timeout: Duration) -> ServeStats {
        self.shared.lifecycle.draining.store(true, Ordering::Release);
        self.stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        let deadline = Instant::now() + timeout;
        while self.shared.inflight.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.shared.snapshot()
    }
}

/// Start serving a trained model (non-blocking; returns a handle).
///
/// `centroids` is the trained k×dim model (row-major).
pub fn serve(cfg: ServeConfig, centroids: Vec<f32>, dim: usize, k: usize) -> Result<ServerHandle> {
    #[cfg(not(unix))]
    if cfg.loop_mode == ServeLoop::Poll {
        return Err(Error::Config(
            "--serve-loop poll needs a unix host (poll(2)); use --serve-loop threads".into(),
        ));
    }

    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let shared = ServeShared::new(dim, k);

    let (queue_tx, queue_rx) = mpsc::sync_channel::<Job>(cfg.queue_depth);

    // the supervisor owns the queue receiver and (re)spawns the batcher
    // thread — which owns the non-Send runtime — with capped backoff
    let artifacts = cfg.artifacts_dir.clone();
    let bcfg = cfg.batcher.clone();
    let shared_sup = shared.clone();
    std::thread::Builder::new()
        .name("parakm-batcher-supervisor".into())
        .spawn(move || {
            supervise_batcher(queue_rx, shared_sup, artifacts, centroids, dim, k, bcfg);
        })
        .expect("spawn batcher supervisor");

    let accept_thread = match cfg.loop_mode {
        ServeLoop::Threads => {
            let stop2 = stop.clone();
            let shared2 = shared.clone();
            let max_conns = cfg.max_conns;
            let queue_depth = cfg.queue_depth;
            let max_line_bytes = cfg.max_line_bytes;
            let shed = cfg.shed.clone();
            let active = Arc::new(AtomicUsize::new(0));
            std::thread::Builder::new()
                .name("parakm-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop2.load(Ordering::Acquire) {
                            break;
                        }
                        match conn {
                            Ok(stream) => {
                                if chaos::hit(chaos::Site::ServeAccept).is_some() {
                                    // injected accept failure: the
                                    // connection is dropped unserved
                                    drop(stream);
                                    continue;
                                }
                                // small request/response lines: Nagle +
                                // delayed ACK would add ~40 ms stalls
                                // per round trip
                                let _ = stream.set_nodelay(true);
                                match ConnPermit::acquire(&active, max_conns) {
                                    Some(permit) => {
                                        let q = queue_tx.clone();
                                        let sh = shared2.clone();
                                        let shed = shed.clone();
                                        std::thread::spawn(move || {
                                            let _permit = permit; // released on exit
                                            handle_conn(
                                                stream,
                                                q,
                                                sh,
                                                queue_depth,
                                                shed,
                                                max_line_bytes,
                                            );
                                        });
                                    }
                                    None => {
                                        shared2.saturated.fetch_add(1, Ordering::AcqRel);
                                        // typed rejection, written inline:
                                        // one short line into an empty
                                        // socket buffer cannot block the
                                        // acceptor
                                        let mut stream = stream;
                                        let _ = writeln!(
                                            stream,
                                            "{}",
                                            Response::saturated().to_line()
                                        );
                                    }
                                }
                            }
                            Err(e) => {
                                // listener errors during shutdown or
                                // drain are clean termination, not a
                                // per-connection error storm
                                if stop2.load(Ordering::Acquire) {
                                    break;
                                }
                                eprintln!("accept error: {e}");
                            }
                        }
                    }
                })
                .expect("spawn acceptor")
        }
        ServeLoop::Poll => {
            #[cfg(unix)]
            {
                let pcfg = crate::serve::poll::PollCfg {
                    queue_depth: cfg.queue_depth,
                    max_conns: cfg.max_conns,
                    max_line_bytes: cfg.max_line_bytes,
                    shed: cfg.shed.clone(),
                };
                let shared2 = shared.clone();
                let stop2 = stop.clone();
                std::thread::Builder::new()
                    .name("parakm-reactor".into())
                    .spawn(move || {
                        crate::serve::poll::run(listener, queue_tx, shared2, pcfg, stop2);
                    })
                    .expect("spawn reactor")
            }
            #[cfg(not(unix))]
            unreachable!("poll loop rejected above on non-unix hosts")
        }
    };

    Ok(ServerHandle { local_addr, stop, accept_thread: Some(accept_thread), shared })
}

/// Supervision backoff ladder: first restart after 50 ms, doubling to
/// a 2 s cap while the batcher keeps dying; a healthy incarnation
/// resets the ladder.
const RESTART_BACKOFF_MIN_MS: u64 = 50;
const RESTART_BACKOFF_MAX_MS: u64 = 2_000;

fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("batcher thread panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("batcher thread panicked: {s}")
    } else {
        "batcher thread panicked".to_string()
    }
}

/// Run one batcher incarnation per loop pass: spawn it, feed it jobs
/// from the bounded queue, and on death (panic or premature exit)
/// record the restart reason and back off before respawning. No reply
/// bookkeeping happens here — a [`Job`] dropped anywhere on the dead
/// path answers its client with the typed retry error by itself.
/// Returns when the front end drops the queue sender (shutdown).
fn supervise_batcher(
    queue_rx: mpsc::Receiver<Job>,
    shared: Arc<ServeShared>,
    artifacts: PathBuf,
    centroids: Vec<f32>,
    dim: usize,
    k: usize,
    bcfg: BatcherConfig,
) {
    let mut backoff_ms = RESTART_BACKOFF_MIN_MS;
    loop {
        let (tx, rx) = mpsc::channel::<Job>();
        let artifacts2 = artifacts.clone();
        let centroids2 = centroids.clone();
        let bcfg2 = bcfg.clone();
        let shared2 = shared.clone();
        let incarnation = std::thread::Builder::new()
            .name("parakm-batcher".into())
            .spawn(move || -> Option<String> {
                let mut batcher = match Batcher::new(&artifacts2, centroids2, dim, k, bcfg2) {
                    Ok(b) => b,
                    Err(e) => return Some(format!("batcher init failed: {e}")),
                };
                batcher.publish_to(shared2.batcher.clone());
                batcher.watch_model(shared2.lifecycle.slot.clone());
                shared2.lifecycle.batcher_up.store(true, Ordering::Release);
                batcher.run(rx);
                None // clean exit: every sender dropped
            })
            .expect("spawn batcher");

        // feed jobs forward until the batcher stops receiving (died)
        // or the front end hangs up (shutdown)
        let died = loop {
            match queue_rx.recv() {
                Ok(job) => {
                    if let Err(mpsc::SendError(job)) = tx.send(job) {
                        break Some(job);
                    }
                }
                Err(_) => break None,
            }
        };
        let was_up = shared.lifecycle.batcher_up.swap(false, Ordering::AcqRel);
        let Some(job) = died else {
            // shutdown: let the batcher finish what it already holds
            drop(tx);
            let _ = incarnation.join();
            return;
        };
        drop(job); // answers its client with the typed retry error
        drop(tx);
        let reason = match incarnation.join() {
            Ok(Some(init_err)) => init_err,
            Ok(None) => "batcher thread exited unexpectedly".to_string(),
            Err(payload) => panic_reason(payload.as_ref()),
        };
        eprintln!("serve: {reason}; restarting batcher in {backoff_ms} ms");
        shared.lifecycle.restarts.fetch_add(1, Ordering::AcqRel);
        *shared.lifecycle.last_restart.lock().unwrap() = reason;
        if was_up {
            backoff_ms = RESTART_BACKOFF_MIN_MS;
        }
        // back off, dropping (= retry-answering) whatever arrives, so
        // clients see the typed error instead of a stalled socket
        let deadline = Instant::now() + Duration::from_millis(backoff_ms);
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match queue_rx.recv_timeout(left) {
                Ok(job) => drop(job),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        backoff_ms = (backoff_ms * 2).min(RESTART_BACKOFF_MAX_MS);
    }
}

/// What one bounded line read produced.
enum LineRead {
    /// A complete line is in the buffer (without its `\n`; a trailing
    /// unterminated line at EOF also lands here, mirroring
    /// `BufRead::lines`).
    Line,
    /// Clean end of stream, nothing buffered.
    Eof,
    /// The line exceeded `max` content bytes before its `\n` arrived.
    Oversized,
}

/// `read_line` with a hard byte bound — the fix for the unbounded
/// `reader.lines()` DoS: a client streaming an endless line without a
/// newline previously grew the heap without limit. Stops buffering the
/// moment the bound is crossed, even mid-line.
fn read_line_bounded(
    r: &mut impl BufRead,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    loop {
        let avail = r.fill_buf()?;
        if avail.is_empty() {
            return Ok(if buf.is_empty() { LineRead::Eof } else { LineRead::Line });
        }
        if let Some(pos) = avail.iter().position(|&b| b == b'\n') {
            if buf.len() + pos > max {
                r.consume(pos + 1);
                return Ok(LineRead::Oversized);
            }
            buf.extend_from_slice(&avail[..pos]);
            r.consume(pos + 1);
            return Ok(LineRead::Line);
        }
        let n = avail.len();
        if buf.len() + n > max {
            r.consume(n);
            return Ok(LineRead::Oversized);
        }
        buf.extend_from_slice(avail);
        r.consume(n);
    }
}

/// Per-connection loop (threads mode): read request lines (bounded),
/// queue jobs, write replies in completion order (ids let clients
/// correlate). `{"stats": true}` lines are answered inline from the
/// shared counters.
fn handle_conn(
    stream: TcpStream,
    queue: mpsc::SyncSender<Job>,
    shared: Arc<ServeShared>,
    queue_depth: usize,
    shed: ShedConfig,
    max_line_bytes: usize,
) {
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        match read_line_bounded(&mut reader, &mut buf, max_line_bytes) {
            Err(_) => break, // client hung up mid-line
            Ok(LineRead::Eof) => break,
            Ok(LineRead::Oversized) => {
                shared.oversized.fetch_add(1, Ordering::AcqRel);
                let _ = writeln!(writer, "{}", Response::line_too_long().to_line());
                break; // the rest of the line cannot be resynchronized
            }
            Ok(LineRead::Line) => {}
        }
        // mirror BufRead::lines(): drop one trailing \r
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
        let started = Instant::now();
        let Ok(line) = std::str::from_utf8(&buf) else {
            shared.record_latency(started);
            if writeln!(writer, "{}", Response::not_utf8().to_line()).is_err() {
                break;
            }
            continue;
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match ClientRequest::parse(line) {
            Ok(ClientRequest::Stats) => protocol::stats_line(&shared.snapshot()),
            Ok(ClientRequest::Metrics { text: false }) => {
                protocol::metrics_line(&shared.snapshot())
            }
            Ok(ClientRequest::Metrics { text: true }) => {
                // the one multi-line response: Prometheus exposition
                // text, already `# EOF`-terminated (no extra newline)
                shared.record_latency(started);
                if write!(writer, "{}", protocol::metrics_text(&shared.snapshot())).is_err() {
                    break;
                }
                continue;
            }
            Ok(ClientRequest::Health) => protocol::health_line(&shared.snapshot()),
            Ok(ClientRequest::Reload { path }) => reload_response(&shared, &path),
            Ok(ClientRequest::Assign(request)) => {
                if let Some(err) = shed_decision(&shared, queue_depth, &shed, request.points.len())
                {
                    Response::Err { id: request.id, error: err.to_string() }.to_line()
                } else {
                    shared.inflight.fetch_add(1, Ordering::AcqRel);
                    let (tx, rx) = mpsc::channel();
                    let job = Job::new(request, ReplySink::Channel(tx));
                    if chaos::hit(chaos::Site::ServeEnqueue).is_some() {
                        drop(job); // answers with the typed retry error
                    } else if let Err(send_err) = queue.send(job) {
                        // supervisor gone (shutdown); the returned job
                        // answers itself with the typed retry error
                        drop(send_err);
                    }
                    let got = rx.recv();
                    let line = match got {
                        Ok(r) => r.to_line(),
                        Err(_) => {
                            shared.inflight.fetch_sub(1, Ordering::AcqRel);
                            break;
                        }
                    };
                    shared.record_latency(started);
                    // decrement only after the reply hits the socket so
                    // a SIGTERM drain cannot exit with a reply buffered
                    let wrote = writeln!(writer, "{line}");
                    shared.inflight.fetch_sub(1, Ordering::AcqRel);
                    if wrote.is_err() {
                        break;
                    }
                    continue;
                }
            }
            Err(e) => Response::Err { id: 0, error: e.to_string() }.to_line(),
        };
        shared.record_latency(started);
        if writeln!(writer, "{reply_line}").is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::{self, KmeansConfig};
    use std::io::{BufRead, BufReader, Write};

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    /// Never-existing artifacts dir: the batcher falls back to the
    /// in-crate native runtime, so these tests run artifact-free.
    fn no_artifacts() -> PathBuf {
        std::env::temp_dir().join("parakm_server_tests/no_artifacts_here")
    }

    fn test_modes() -> Vec<ServeLoop> {
        if cfg!(unix) {
            vec![ServeLoop::Threads, ServeLoop::Poll]
        } else {
            vec![ServeLoop::Threads]
        }
    }

    fn start_server(loop_mode: ServeLoop) -> Option<(ServerHandle, Vec<f32>)> {
        let dir = artifacts_dir()?;
        let ds = MixtureSpec::paper_3d(4).generate(3000, 3);
        let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            artifacts_dir: dir,
            loop_mode,
            ..Default::default()
        };
        let handle = serve(cfg, model.centroids.clone(), 3, 4).unwrap();
        Some((handle, model.centroids))
    }

    fn start_server_artifact_free(cfg: ServeConfig) -> ServerHandle {
        let ds = MixtureSpec::paper_3d(4).generate(500, 3);
        let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        serve(cfg, model.centroids.clone(), 3, 4).unwrap()
    }

    #[test]
    fn serve_loop_parses_and_displays() {
        assert_eq!("poll".parse::<ServeLoop>().unwrap(), ServeLoop::Poll);
        assert_eq!("threads".parse::<ServeLoop>().unwrap(), ServeLoop::Threads);
        assert!("epoll".parse::<ServeLoop>().is_err());
        assert_eq!(ServeLoop::Poll.to_string(), "poll");
        assert_eq!(ServeLoop::Threads.to_string(), "threads");
    }

    #[test]
    fn end_to_end_request_response() {
        for mode in test_modes() {
            let Some((server, _)) = start_server(mode) else {
                eprintln!("skipping: artifacts not built");
                return;
            };
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            writeln!(conn, r#"{{"id": 42, "points": [[0.0, 0.0, 0.0], [5.0, 5.0, 5.0]]}}"#)
                .unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Ok { id, clusters, distances } => {
                    assert_eq!(id, 42, "mode {mode}");
                    assert_eq!(clusters.len(), 2);
                    assert_eq!(distances.len(), 2);
                    assert!(clusters.iter().all(|&c| (0..4).contains(&c)));
                }
                other => panic!("mode {mode}: unexpected {other:?}"),
            }
            server.shutdown();
        }
    }

    #[test]
    fn pipelined_requests_same_connection() {
        for mode in test_modes() {
            let Some((server, _)) = start_server(mode) else {
                eprintln!("skipping: artifacts not built");
                return;
            };
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            for i in 0..5 {
                writeln!(conn, r#"{{"id": {i}, "points": [[{i}.0, 0.0, 1.0]]}}"#).unwrap();
            }
            let reader = BufReader::new(conn.try_clone().unwrap());
            let mut seen = Vec::new();
            for line in reader.lines().take(5) {
                match Response::parse(&line.unwrap()).unwrap() {
                    Response::Ok { id, .. } => seen.push(id),
                    other => panic!("mode {mode}: unexpected {other:?}"),
                }
            }
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3, 4], "mode {mode}");
            server.shutdown();
        }
    }

    #[test]
    fn malformed_request_gets_error_not_disconnect() {
        for mode in test_modes() {
            let Some((server, _)) = start_server(mode) else {
                eprintln!("skipping: artifacts not built");
                return;
            };
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            writeln!(conn, "this is not json").unwrap();
            writeln!(conn, r#"{{"id": 1, "points": [[1.0, 2.0, 3.0]]}}"#).unwrap();
            let reader = BufReader::new(conn.try_clone().unwrap());
            let mut lines = reader.lines();
            let first = Response::parse(&lines.next().unwrap().unwrap()).unwrap();
            assert!(matches!(first, Response::Err { .. }), "mode {mode}: {first:?}");
            let second = Response::parse(&lines.next().unwrap().unwrap()).unwrap();
            assert!(matches!(second, Response::Ok { id: 1, .. }), "mode {mode}: {second:?}");
            server.shutdown();
        }
    }

    #[test]
    fn zero_cap_rejects_every_connection_with_typed_error() {
        // the rejection path never touches the batcher, so this runs
        // artifact-free
        for mode in test_modes() {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                max_conns: 0,
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            for _ in 0..3 {
                let conn = TcpStream::connect(server.local_addr).unwrap();
                let mut reader = BufReader::new(conn);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let resp = Response::parse(&line).unwrap();
                assert!(resp.is_saturated(), "mode {mode}: {resp:?}");
                // and the connection is closed, not left dangling
                line.clear();
                assert_eq!(reader.read_line(&mut line).unwrap(), 0, "mode {mode}");
            }
            assert!(server.stats().saturated >= 3, "mode {mode}");
            server.shutdown();
        }
    }

    #[test]
    fn capacity_frees_when_connection_closes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ds = MixtureSpec::paper_3d(4).generate(3000, 3);
        let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            artifacts_dir: dir,
            max_conns: 1,
            loop_mode: ServeLoop::Threads,
            ..Default::default()
        };
        let server = serve(cfg, model.centroids.clone(), 3, 4).unwrap();

        // first client occupies the only slot (round-trip proves the
        // handler thread is live, not just queued in the accept loop)
        let mut c1 = TcpStream::connect(server.local_addr).unwrap();
        writeln!(c1, r#"{{"id": 1, "points": [[0.0, 0.0, 0.0]]}}"#).unwrap();
        let mut r1 = BufReader::new(c1.try_clone().unwrap());
        let mut line = String::new();
        r1.read_line(&mut line).unwrap();
        assert!(matches!(Response::parse(&line).unwrap(), Response::Ok { id: 1, .. }));

        // second client is rejected with the typed error
        let c2 = TcpStream::connect(server.local_addr).unwrap();
        let mut r2 = BufReader::new(c2);
        line.clear();
        r2.read_line(&mut line).unwrap();
        assert!(Response::parse(&line).unwrap().is_saturated(), "{line}");

        // slot frees once c1 hangs up (poll: the handler thread needs
        // a moment to observe the close and drop its permit)
        drop(r1);
        drop(c1);
        let mut ok = false;
        for _ in 0..100 {
            let mut c3 = TcpStream::connect(server.local_addr).unwrap();
            writeln!(c3, r#"{{"id": 3, "points": [[1.0, 1.0, 1.0]]}}"#).unwrap();
            let mut r3 = BufReader::new(c3);
            line.clear();
            r3.read_line(&mut line).unwrap();
            if matches!(Response::parse(&line).unwrap(), Response::Ok { id: 3, .. }) {
                ok = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(ok, "slot never freed after client disconnect");
        server.shutdown();
    }

    #[test]
    fn stats_probe_reports_counters() {
        use crate::util::json::Json;
        for mode in test_modes() {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                max_conns: 1,
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);

            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();

            // a fresh server reports zeros
            writeln!(conn, r#"{{"stats": true}}"#).unwrap();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            let s = j.get("stats").expect("stats object");
            assert_eq!(s.get("requests").and_then(Json::as_f64), Some(0.0), "mode {mode}");
            assert_eq!(s.get("saturated").and_then(Json::as_f64), Some(0.0), "mode {mode}");

            // one assignment, one saturated rejection...
            writeln!(conn, r#"{{"id": 1, "points": [[0.0, 0.0, 0.0], [1.0, 1.0, 1.0]]}}"#).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(
                matches!(Response::parse(&line).unwrap(), Response::Ok { id: 1, .. }),
                "mode {mode}: {line}"
            );
            let rej = TcpStream::connect(server.local_addr).unwrap();
            let mut rej_reader = BufReader::new(rej);
            line.clear();
            rej_reader.read_line(&mut line).unwrap();
            assert!(Response::parse(&line).unwrap().is_saturated(), "mode {mode}: {line}");

            // ...and the probe reflects both on the still-open
            // connection, including the latency histogram fields
            writeln!(conn, r#"{{"stats": true}}"#).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            let s = j.get("stats").expect("stats object");
            assert_eq!(s.get("requests").and_then(Json::as_f64), Some(1.0), "mode {mode}");
            assert_eq!(s.get("points").and_then(Json::as_f64), Some(2.0), "mode {mode}");
            assert_eq!(s.get("batches").and_then(Json::as_f64), Some(1.0), "mode {mode}");
            assert_eq!(s.get("saturated").and_then(Json::as_f64), Some(1.0), "mode {mode}");
            assert!(s.get("padded_rows").and_then(Json::as_f64).unwrap() >= 0.0, "{line}");
            // at least the stats probe and the assignment were timed
            assert!(
                s.get("lat_count").and_then(Json::as_f64).unwrap() >= 2.0,
                "mode {mode}: {line}"
            );
            assert!(s.get("lat_p50_us").and_then(Json::as_f64).unwrap() >= 0.0, "{line}");
            assert!(s.get("lat_p99_us").and_then(Json::as_f64).unwrap() >= 0.0, "{line}");
            assert_eq!(s.get("shed_heavy").and_then(Json::as_f64), Some(0.0), "mode {mode}");
            assert_eq!(s.get("shed_load").and_then(Json::as_f64), Some(0.0), "mode {mode}");
            assert_eq!(s.get("oversized").and_then(Json::as_f64), Some(0.0), "mode {mode}");
            // consistency satellites: process-wide warning/event
            // counters ride along on every probe (other tests in the
            // process may have bumped them — presence + type only)
            assert!(s.get("artifact_warnings").and_then(Json::as_f64).is_some(), "{line}");
            assert!(s.get("empty_events").and_then(Json::as_f64).is_some(), "{line}");
            server.shutdown();
        }
    }

    #[test]
    fn metrics_probe_answers_both_forms() {
        use crate::util::json::Json;
        for mode in test_modes() {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();

            // JSON form: one line, registry + serve counters merged
            writeln!(conn, r#"{{"metrics": true}}"#).unwrap();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            let m = j.get("metrics").expect("metrics object");
            assert_eq!(m.get("serve_requests_total").and_then(Json::as_f64), Some(0.0));
            assert!(m.get("artifact_warnings_total").and_then(Json::as_f64).is_some());
            assert!(m.get("empty_cluster_events_total").and_then(Json::as_f64).is_some());

            // text form: Prometheus lines terminated by `# EOF`
            writeln!(conn, r#"{{"metrics": "text"}}"#).unwrap();
            let mut text = String::new();
            loop {
                line.clear();
                reader.read_line(&mut line).unwrap();
                text.push_str(&line);
                if line.starts_with("# EOF") {
                    break;
                }
            }
            assert!(
                text.lines().any(|l| l.starts_with("serve_requests_total ")),
                "mode {mode}: {text}"
            );

            // the connection still serves requests after both probes
            writeln!(conn, r#"{{"id": 1, "points": [[0.0, 0.0, 0.0]]}}"#).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(
                matches!(Response::parse(&line).unwrap(), Response::Ok { id: 1, .. }),
                "mode {mode}: {line}"
            );
            server.shutdown();
        }
    }

    #[test]
    fn concurrent_clients() {
        for mode in test_modes() {
            let Some((server, _)) = start_server(mode) else {
                eprintln!("skipping: artifacts not built");
                return;
            };
            let addr = server.local_addr;
            let handles: Vec<_> = (0..8)
                .map(|c| {
                    std::thread::spawn(move || {
                        let mut conn = TcpStream::connect(addr).unwrap();
                        writeln!(
                            conn,
                            r#"{{"id": {c}, "points": [[{c}.5, 1.0, -2.0], [0.0, 0.0, 0.0]]}}"#
                        )
                        .unwrap();
                        let mut reader = BufReader::new(conn);
                        let mut line = String::new();
                        reader.read_line(&mut line).unwrap();
                        match Response::parse(&line).unwrap() {
                            Response::Ok { id, clusters, .. } => {
                                assert_eq!(id, c);
                                assert_eq!(clusters.len(), 2);
                            }
                            other => panic!("unexpected {other:?}"),
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            server.shutdown();
        }
    }

    #[test]
    fn oversized_line_gets_typed_error_and_close() {
        // the satellite bugfix pin: an endless line without `\n` must
        // not grow the read buffer unboundedly — in either loop
        for mode in test_modes() {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                max_line_bytes: 256,
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);

            // (a) a complete-but-huge line
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            let huge = "x".repeat(1024);
            writeln!(conn, "{huge}").unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Response::parse(&line).unwrap();
            assert_eq!(resp, Response::line_too_long(), "mode {mode}: {line}");
            line.clear();
            assert_eq!(reader.read_line(&mut line).unwrap(), 0, "mode {mode}: must close");

            // (b) an endless line that never sends `\n`
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            conn.write_all(&vec![b'y'; 4096]).unwrap();
            conn.flush().unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Response::parse(&line).unwrap();
            assert_eq!(resp, Response::line_too_long(), "mode {mode}: {line}");
            line.clear();
            assert_eq!(reader.read_line(&mut line).unwrap(), 0, "mode {mode}: must close");

            assert!(server.stats().oversized >= 2, "mode {mode}");
            server.shutdown();
        }
    }

    #[test]
    fn shed_tiers_reject_heavy_requests_under_pressure() {
        // soft_pct 0 + heavy_points 1 makes the soft tier deterministic:
        // every assign request is "heavy" and the queue always counts
        // as under pressure
        for mode in test_modes() {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                shed: ShedConfig { soft_pct: 0, heavy_points: 1 },
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            writeln!(conn, r#"{{"id": 7, "points": [[0.0, 0.0, 0.0]]}}"#).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Response::parse(&line).unwrap();
            assert!(resp.is_shed(), "mode {mode}: {resp:?}");
            assert_eq!(
                resp,
                Response::Err { id: 7, error: protocol::ERR_SHED_HEAVY.into() },
                "mode {mode}"
            );
            // stats probes are never shed
            writeln!(conn, r#"{{"stats": true}}"#).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("\"shed_heavy\":1"), "mode {mode}: {line}");
            assert!(server.stats().shed_heavy >= 1, "mode {mode}");
            server.shutdown();
        }
    }

    #[test]
    fn poll_and_threads_responses_byte_identical() {
        // the tentpole contract at the socket level: the same request
        // corpus (valid, malformed-but-typed, empty) must produce
        // byte-identical response lines from both loops. Malformed
        // JSON errors are compared for err-ness only (parser error
        // prose is not part of the cross-loop contract).
        if !cfg!(unix) {
            return;
        }
        let corpus: Vec<String> = {
            let mut c = vec![
                r#"{"id": 1, "points": [[0.0, 0.0, 0.0]]}"#.to_string(),
                r#"{"id": 2, "points": [[1.5, -2.0, 3.25], [4.0, 5.0, 6.0]]}"#.to_string(),
                r#"{ "id" : 3 , "points" : [ [ 7e-1 , 0.125 , -9 ] ] }"#.to_string(),
                r#"{"id": 4, "points": [[1, 2]]}"#.to_string(), // dim mismatch: typed error
                r#"{"id": 5}"#.to_string(),                     // missing points
            ];
            for i in 0..20 {
                let x = i as f64 * 0.37 - 3.0;
                c.push(format!(r#"{{"id": {}, "points": [[{x}, {x}, {x}]]}}"#, 100 + i));
            }
            c
        };
        let drive = |mode: ServeLoop| -> Vec<String> {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            for line in &corpus {
                writeln!(conn, "{line}").unwrap();
            }
            let reader = BufReader::new(conn.try_clone().unwrap());
            let out: Vec<String> = reader.lines().take(corpus.len()).map(|l| l.unwrap()).collect();
            server.shutdown();
            out
        };
        let threads = drive(ServeLoop::Threads);
        let poll = drive(ServeLoop::Poll);
        assert_eq!(threads.len(), poll.len());
        assert_eq!(threads, poll, "poll loop must answer byte-identically to threads loop");
    }

    #[cfg(unix)]
    #[test]
    fn poll_loop_interleaves_many_connections() {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            artifacts_dir: no_artifacts(),
            loop_mode: ServeLoop::Poll,
            ..Default::default()
        };
        let server = start_server_artifact_free(cfg);
        // more connections than the threads loop would dare per-thread:
        // all multiplexed on the single reactor
        let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> = (0..32)
            .map(|_| {
                let c = TcpStream::connect(server.local_addr).unwrap();
                let r = BufReader::new(c.try_clone().unwrap());
                (c, r)
            })
            .collect();
        for (i, (c, _)) in conns.iter_mut().enumerate() {
            writeln!(c, r#"{{"id": {i}, "points": [[0.5, 0.5, 0.5]]}}"#).unwrap();
        }
        for (i, (_, r)) in conns.iter_mut().enumerate() {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Ok { id, .. } => assert_eq!(id, i as u64),
                other => panic!("conn {i}: unexpected {other:?}"),
            }
        }
        server.shutdown();
    }

    #[test]
    fn bounded_line_reader_contract() {
        use std::io::Cursor;
        let mut buf = Vec::new();

        // a normal line
        let mut r = Cursor::new(b"hello\nworld\n".to_vec());
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 64).unwrap(), LineRead::Line));
        assert_eq!(buf, b"hello");
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 64).unwrap(), LineRead::Line));
        assert_eq!(buf, b"world");
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 64).unwrap(), LineRead::Eof));

        // a trailing unterminated line still comes through (lines() parity)
        let mut r = Cursor::new(b"tail".to_vec());
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 64).unwrap(), LineRead::Line));
        assert_eq!(buf, b"tail");

        // over-long with newline
        let mut r = Cursor::new([vec![b'a'; 100], b"\n".to_vec()].concat());
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 10).unwrap(), LineRead::Oversized));

        // over-long without newline: bounded buffering, not unbounded growth
        let mut r = Cursor::new(vec![b'b'; 1 << 16]);
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 10).unwrap(), LineRead::Oversized));
        assert!(buf.len() <= 10);

        // exactly at the bound is fine
        let mut r = Cursor::new(b"0123456789\n".to_vec());
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 10).unwrap(), LineRead::Line));
        assert_eq!(buf, b"0123456789");
    }

    #[test]
    fn drain_terminates_cleanly_with_open_idle_connection() {
        // satellite pin: a SIGTERM drain with an idle connection still
        // open must terminate promptly (no accept-error storm, no
        // hang) after flushing in-flight replies
        for mode in test_modes() {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            // an idle connection that never sends and never hangs up
            let idle = TcpStream::connect(server.local_addr).unwrap();
            // a live connection with one answered request
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            writeln!(conn, r#"{{"id": 1, "points": [[0.0, 0.0, 0.0]]}}"#).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(
                matches!(Response::parse(&line).unwrap(), Response::Ok { id: 1, .. }),
                "mode {mode}: {line}"
            );
            let stats = server.drain(std::time::Duration::from_secs(10));
            assert!(stats.draining, "mode {mode}");
            drop(idle);
        }
    }

    #[test]
    fn health_probe_reports_ready_after_first_answered_request() {
        for mode in test_modes() {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            // one answered assign proves the batcher came up, which
            // makes the subsequent health probe deterministic
            writeln!(conn, r#"{{"id": 1, "points": [[0.0, 0.0, 0.0]]}}"#).unwrap();
            reader.read_line(&mut line).unwrap();
            line.clear();
            writeln!(conn, r#"{{"health": true}}"#).unwrap();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains(r#""live":true"#), "mode {mode}: {line}");
            assert!(line.contains(r#""ready":true"#), "mode {mode}: {line}");
            assert!(line.contains(r#""model_generation":1"#), "mode {mode}: {line}");
            assert!(line.contains(r#""batcher_restarts":0"#), "mode {mode}: {line}");
            server.shutdown();
        }
    }

    #[test]
    fn reload_swaps_model_and_rejects_bad_files() {
        use crate::data::io::{write_model, Model};
        for mode in test_modes() {
            let dir = std::env::temp_dir().join(format!("parakm_server_tests/reload_{mode}"));
            std::fs::create_dir_all(&dir).unwrap();
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();

            // a valid replacement model with every centroid at 100 so
            // reloaded assignments are distinguishable by distance
            let second = Model {
                k: 4,
                dim: 3,
                seed: 9,
                engine: "serial".into(),
                iterations: 1,
                sse: 0.0,
                centroids: vec![100.0; 12],
            };
            let good = dir.join("second.pkm");
            write_model(&good, &second).unwrap();
            writeln!(conn, r#"{{"reload": "{}"}}"#, good.display()).unwrap();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains(r#""generation":2"#), "mode {mode}: {line}");

            // the swap lands between batches: the next assign must be
            // answered from the new centroids
            line.clear();
            writeln!(conn, r#"{{"id": 5, "points": [[100.0, 100.0, 100.0]]}}"#).unwrap();
            reader.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Ok { id, distances, .. } => {
                    assert_eq!(id, 5, "mode {mode}");
                    assert!(distances[0] < 1e-3, "mode {mode}: {distances:?}");
                }
                other => panic!("mode {mode}: unexpected {other:?}"),
            }

            // wrong shape: typed reload error, generation unchanged
            let bad = Model {
                k: 2,
                dim: 5,
                seed: 0,
                engine: "serial".into(),
                iterations: 1,
                sse: 0.0,
                centroids: vec![0.0; 10],
            };
            let bad_path = dir.join("bad.pkm");
            write_model(&bad_path, &bad).unwrap();
            line.clear();
            writeln!(conn, r#"{{"reload": "{}"}}"#, bad_path.display()).unwrap();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains(protocol::ERR_RELOAD), "mode {mode}: {line}");
            line.clear();
            writeln!(conn, r#"{{"health": true}}"#).unwrap();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains(r#""model_generation":2"#), "mode {mode}: {line}");
            server.shutdown();
        }
    }

    #[test]
    fn lifecycle_responses_byte_identical_across_loops() {
        // satellite gate: health, reload-failure and malformed
        // lifecycle lines must answer byte-identically on both loops.
        // Driven in lockstep (write one, read one) so response order
        // is deterministic on the reactor too.
        if !cfg!(unix) {
            return;
        }
        let corpus = [
            r#"{"id": 1, "points": [[0.0, 0.0, 0.0]]}"#,
            r#"{"health": true}"#,
            r#"{"reload": "/nonexistent/parakm/model.pkm"}"#,
            r#"{"health": 1}"#,
            r#"{"reload": true}"#,
        ];
        let drive = |mode: ServeLoop| -> Vec<String> {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifacts_dir: no_artifacts(),
                loop_mode: mode,
                ..Default::default()
            };
            let server = start_server_artifact_free(cfg);
            let mut conn = TcpStream::connect(server.local_addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut out = Vec::new();
            for line in corpus {
                writeln!(conn, "{line}").unwrap();
                let mut reply = String::new();
                reader.read_line(&mut reply).unwrap();
                out.push(reply);
            }
            server.shutdown();
            out
        };
        let threads = drive(ServeLoop::Threads);
        let poll = drive(ServeLoop::Poll);
        assert_eq!(threads, poll, "lifecycle responses must match across loops");
    }
}
