//! Mini-batch K-Means (Sculley 2010) — the big-data extension the
//! paper's conclusion motivates ("extremely large datasets with
//! real-world data").
//!
//! Instead of full passes, each iteration samples a batch, assigns it,
//! and moves each touched centroid toward the batch mean with a
//! per-centroid learning rate 1/count. Converges approximately but
//! touches a fraction of the data per step; the A3 ablation bench
//! compares wall-clock-to-quality against full Lloyd.

use crate::config::DistancePolicy;
use crate::data::Dataset;
use crate::kmeans::step::{assign_accumulate_mode, DistanceMode, PartialStats};
use crate::kmeans::{init, KmeansConfig, KmeansResult};
use crate::linalg::kernel;
use crate::rng::Pcg64;
use crate::util::trace;

/// Run mini-batch K-Means with batch size `batch`.
///
/// Convergence: EWMA of centroid movement per step below `cfg.tol`
/// (scaled by batch/n) or `cfg.max_iters` batches.
pub fn run(ds: &Dataset, cfg: &KmeansConfig, batch: usize) -> KmeansResult {
    let centroids0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from(ds, cfg, batch, &centroids0)
}

/// Run from explicit initial centroids.
pub fn run_from(
    ds: &Dataset,
    cfg: &KmeansConfig,
    batch: usize,
    centroids0: &[f32],
) -> KmeansResult {
    let n = ds.len();
    let d = ds.dim();
    let k = cfg.k;
    let b = batch.max(1).min(n);
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d);
    let mut mu = centroids0.to_vec();
    let mut rng = Pcg64::new(cfg.seed ^ 0xBA7C4, 0x31);

    let policy = cfg.distance;
    let mut counts = vec![0u64; k]; // lifetime per-centroid counts
    let mut batch_rows = vec![0.0f32; b * d];
    let mut batch_assign = vec![-1i32; b];
    // per-batch point norms, reused across iterations (dot policy only)
    let mut batch_norms =
        vec![0.0f32; if policy == DistancePolicy::Dot { b } else { 0 }];
    let mut stats = PartialStats::zeros(k, d);
    let mut history = Vec::new();
    let mut converged = false;
    let mut iterations = 0usize;
    let mut ewma_shift = f64::NAN;

    for _ in 0..cfg.max_iters {
        // sample the batch (with replacement: standard for mini-batch)
        for bi in 0..b {
            let src = rng.next_below(n as u64) as usize;
            batch_rows[bi * d..(bi + 1) * d].copy_from_slice(ds.point(src));
        }
        let c_norms = match policy {
            DistancePolicy::Dot => {
                kernel::row_norms(&batch_rows, d, &mut batch_norms);
                kernel::row_norms_vec(&mu, d)
            }
            DistancePolicy::Exact => Vec::new(),
        };
        let mode = match policy {
            DistancePolicy::Exact => DistanceMode::Exact,
            DistancePolicy::Dot => {
                DistanceMode::Dot { x_norms: &batch_norms, c_norms: &c_norms }
            }
        };
        {
            let _s = trace::span(trace::Phase::Assign);
            assign_accumulate_mode(&batch_rows, d, &mu, k, &mut batch_assign, &mut stats, &mode)
                .expect("shapes validated above");
        }

        // per-centroid gradient step toward the batch mean
        let update_span = trace::span(trace::Phase::Update);
        let mut shift = 0.0f64;
        for c in 0..k {
            let bc = stats.counts[c];
            if bc == 0 {
                continue;
            }
            counts[c] += bc;
            let eta = bc as f64 / counts[c] as f64;
            let target_scale = 1.0 / bc as f64;
            for j in 0..d {
                let idx = c * d + j;
                let batch_mean = stats.sums[idx] * target_scale;
                let old = mu[idx] as f64;
                let new = old + eta * (batch_mean - old);
                mu[idx] = new as f32;
                shift += (new - old) * (new - old);
            }
        }
        drop(update_span);
        iterations += 1;
        ewma_shift = if ewma_shift.is_nan() { shift } else { 0.7 * ewma_shift + 0.3 * shift };
        history.push((stats.sse * (n as f64 / b as f64), shift));
        trace::emit_iter(iterations, stats.sse * (n as f64 / b as f64), 0, &[]);
        // tolerance scaled: a batch step moves centroids ~b/n as much
        if ewma_shift < cfg.tol * (b as f64 / n as f64).max(1e-3) && iterations > 10 {
            converged = true;
            break;
        }
    }

    // final full assignment pass for a comparable result/objective
    let mut assign = vec![-1i32; n];
    let mut full_stats = PartialStats::zeros(k, d);
    let c_norms = match policy {
        DistancePolicy::Dot => kernel::row_norms_vec(&mu, d),
        DistancePolicy::Exact => Vec::new(),
    };
    let mode = match policy {
        DistancePolicy::Exact => DistanceMode::Exact,
        DistancePolicy::Dot => DistanceMode::Dot { x_norms: ds.norms(), c_norms: &c_norms },
    };
    assign_accumulate_mode(ds.raw(), d, &mu, k, &mut assign, &mut full_stats, &mode)
        .expect("shapes validated above");
    let sse = full_stats.sse;
    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    KmeansResult {
        centroids: mu,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
        empty_events: Vec::new(),
        pruning: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::serial;

    #[test]
    fn near_lloyd_quality_on_separated_data() {
        let spec = MixtureSpec::random(2, 4, 80.0, 0.6, 3);
        let ds = spec.generate(20_000, 2);
        let cfg = KmeansConfig::new(4).with_seed(5).with_max_iters(300);
        let lloyd = serial::run(&ds, &cfg);
        let mb = run(&ds, &cfg, 1024);
        // within 5% of full-Lloyd SSE on an easy mixture
        assert!(
            mb.sse <= lloyd.sse * 1.05,
            "minibatch sse {} vs lloyd {}",
            mb.sse,
            lloyd.sse
        );
        let ari = crate::metrics::adjusted_rand_index(&mb.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.95, "ari {ari}");
    }

    #[test]
    fn deterministic() {
        let ds = MixtureSpec::paper_2d(8).generate(5000, 7);
        let cfg = KmeansConfig::new(8).with_seed(9);
        let a = run(&ds, &cfg, 512);
        let b = run(&ds, &cfg, 512);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn dot_policy_matches_exact() {
        // sampling is RNG-driven (distance-blind) and centroid updates
        // depend only on assignments, so dot tracks exact whenever the
        // per-batch argmins agree — which they do on the paper mixtures
        let ds = MixtureSpec::paper_2d(8).generate(5000, 7);
        let cfg = KmeansConfig::new(8).with_seed(9);
        let exact = run(&ds, &cfg, 512);
        let dot = run(
            &ds,
            &cfg.clone().with_distance(crate::config::DistancePolicy::Dot),
            512,
        );
        assert_eq!(dot.assign, exact.assign);
        assert_eq!(dot.iterations, exact.iterations);
        assert!((dot.sse - exact.sse).abs() / exact.sse.max(1.0) < 1e-5);
    }

    #[test]
    fn batch_larger_than_n_clamped() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 1);
        let r = run(&ds, &KmeansConfig::new(4).with_seed(2).with_max_iters(50), 10_000);
        assert_eq!(r.assign.len(), 100);
        assert!(r.assign.iter().all(|&a| a >= 0));
    }

    #[test]
    fn full_assignment_pass_covers_everything() {
        let ds = MixtureSpec::paper_3d(4).generate(3000, 4);
        let r = run(&ds, &KmeansConfig::new(4).with_seed(3).with_max_iters(100), 256);
        let total: usize = r.cluster_sizes().iter().sum();
        assert_eq!(total, 3000);
    }
}
