//! Choosing K: elbow (SSE-vs-K knee) and silhouette-based selection.
//!
//! The paper fixes K per experiment; real deployments of its system
//! must pick K. This module sweeps a K range with any engine-agnostic
//! runner and applies two standard criteria:
//!
//! - **elbow**: the K maximizing distance from the SSE(K) curve to the
//!   chord between its endpoints (the "kneedle" construction);
//! - **silhouette**: the K maximizing the sampled silhouette score.

use crate::data::Dataset;
use crate::kmeans::{serial, KmeansConfig};
use crate::metrics;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct KPoint {
    pub k: usize,
    pub sse: f64,
    pub silhouette: f64,
    pub iterations: usize,
}

/// Sweep K ∈ `ks` with serial Lloyd (deterministic per seed).
pub fn sweep(ds: &Dataset, ks: &[usize], seed: u64, silhouette_sample: usize) -> Vec<KPoint> {
    ks.iter()
        .map(|&k| {
            let r = serial::run(ds, &KmeansConfig::new(k).with_seed(seed));
            let sil = if k >= 2 {
                metrics::silhouette_sampled(ds, &r.assign, k, silhouette_sample, seed)
            } else {
                0.0
            };
            KPoint { k, sse: r.sse, silhouette: sil, iterations: r.iterations }
        })
        .collect()
}

/// Elbow selection: K whose SSE point is farthest below the chord from
/// the first to the last sweep point (requires ≥ 3 points).
pub fn elbow(points: &[KPoint]) -> Option<usize> {
    if points.len() < 3 {
        return None;
    }
    let (x0, y0) = (points[0].k as f64, points[0].sse);
    let (x1, y1) = (
        points[points.len() - 1].k as f64,
        points[points.len() - 1].sse,
    );
    let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
    if len == 0.0 {
        return None;
    }
    let mut best = None;
    let mut best_dist = f64::NEG_INFINITY;
    for p in &points[1..points.len() - 1] {
        // signed distance to the chord; below-chord (convex knee) > 0
        let d = ((y1 - y0) * (p.k as f64) - (x1 - x0) * p.sse + x1 * y0 - y1 * x0) / len;
        if d > best_dist {
            best_dist = d;
            best = Some(p.k);
        }
    }
    best
}

/// Silhouette selection: K with the best sampled silhouette.
pub fn best_silhouette(points: &[KPoint]) -> Option<usize> {
    points
        .iter()
        .filter(|p| p.k >= 2)
        .max_by(|a, b| a.silhouette.partial_cmp(&b.silhouette).unwrap())
        .map(|p| p.k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    #[test]
    fn finds_true_k_on_separated_mixture() {
        // 4 well-separated blobs: both criteria should pick ~4
        let spec = MixtureSpec::random(2, 4, 60.0, 0.6, 5);
        let ds = spec.generate(2000, 2);
        let ks: Vec<usize> = (1..=8).collect();
        let pts = sweep(&ds, &ks, 7, 200);
        assert_eq!(pts.len(), 8);
        // SSE decreases (weakly) with K
        for w in pts.windows(2) {
            assert!(w[1].sse <= w[0].sse * 1.05, "{:?}", w);
        }
        let e = elbow(&pts).unwrap();
        assert!((3..=5).contains(&e), "elbow picked {e}");
        let s = best_silhouette(&pts).unwrap();
        assert!((3..=5).contains(&s), "silhouette picked {s}");
    }

    #[test]
    fn elbow_needs_three_points() {
        let two = vec![
            KPoint { k: 1, sse: 10.0, silhouette: 0.0, iterations: 1 },
            KPoint { k: 2, sse: 5.0, silhouette: 0.5, iterations: 1 },
        ];
        assert_eq!(elbow(&two), None);
    }

    #[test]
    fn silhouette_ignores_k1() {
        let pts = vec![
            KPoint { k: 1, sse: 10.0, silhouette: 0.99, iterations: 1 },
            KPoint { k: 2, sse: 5.0, silhouette: 0.4, iterations: 1 },
            KPoint { k: 3, sse: 4.0, silhouette: 0.6, iterations: 1 },
        ];
        assert_eq!(best_silhouette(&pts), Some(3));
    }
}
