//! Elkan's algorithm — exact Lloyd acceleration with k lower bounds per
//! point plus inter-centroid distances (Elkan 2003; the stronger sibling
//! of [`crate::kmeans::hamerly`], same family as the paper's ref [4]).
//!
//! Memory trade-off: O(n·k) bounds vs Hamerly's O(n) — the A3 ablation
//! bench shows where each wins on the paper's workloads (low-d, modest
//! k: Hamerly usually does).

use crate::data::Dataset;
use crate::kmeans::step::{finalize, PartialStats};
use crate::kmeans::{init, KmeansConfig, KmeansResult};
use crate::linalg;

/// Run Elkan-accelerated Lloyd.
pub fn run(ds: &Dataset, cfg: &KmeansConfig) -> KmeansResult {
    let centroids0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from(ds, cfg, &centroids0)
}

/// Run from explicit initial centroids.
pub fn run_from(ds: &Dataset, cfg: &KmeansConfig, centroids0: &[f32]) -> KmeansResult {
    let n = ds.len();
    let d = ds.dim();
    let k = cfg.k;
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d);
    let mut mu = centroids0.to_vec();

    let mut assign = vec![0i32; n];
    let mut upper = vec![0.0f32; n];
    let mut lower = vec![0.0f32; n * k];
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut stats = PartialStats::zeros(k, d);

    // initial exact assignment, seeding all bounds: the dense n×k
    // distance matrix comes from the SIMD kernel subsystem, then the
    // (data-dependent) bound seeding stays scalar
    linalg::kernel::sqdist_matrix(ds.raw(), d, &mu, k, &mut lower, linalg::kernel::active_tier());
    for i in 0..n {
        let p = ds.point(i);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let dist = lower[i * k + c].sqrt();
            lower[i * k + c] = dist;
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
        assign[i] = best as i32;
        upper[i] = best_d;
        counts[best] += 1;
        for j in 0..d {
            sums[best * d + j] += p[j] as f64;
        }
    }

    let mut cc = vec![0.0f32; k * k]; // inter-centroid distances
    let mut s_half = vec![0.0f32; k];
    let mut history = Vec::new();
    let mut converged = false;
    let mut iterations = 0usize;

    for _ in 0..cfg.max_iters {
        stats.reset();
        stats.sums.copy_from_slice(&sums);
        stats.counts.copy_from_slice(&counts);
        let (mu_new, shift) = finalize(&stats, &mu);

        let mut moved = vec![0.0f32; k];
        for c in 0..k {
            moved[c] =
                linalg::sqdist(&mu_new[c * d..(c + 1) * d], &mu[c * d..(c + 1) * d]).sqrt();
        }
        mu = mu_new;
        iterations += 1;
        history.push((f64::NAN, shift));
        if shift < cfg.tol {
            converged = true;
            break;
        }

        // bound maintenance
        for i in 0..n {
            let a = assign[i] as usize;
            upper[i] += moved[a];
            for c in 0..k {
                lower[i * k + c] = (lower[i * k + c] - moved[c]).max(0.0);
            }
        }

        // inter-centroid distances and s(c)
        for c in 0..k {
            let mut nearest = f32::INFINITY;
            for o in 0..k {
                if o == c {
                    cc[c * k + o] = 0.0;
                    continue;
                }
                let dist =
                    linalg::sqdist(&mu[c * d..(c + 1) * d], &mu[o * d..(o + 1) * d]).sqrt();
                cc[c * k + o] = dist;
                nearest = nearest.min(dist);
            }
            s_half[c] = nearest * 0.5;
        }

        for i in 0..n {
            let mut a = assign[i] as usize;
            if upper[i] <= s_half[a] {
                continue; // lemma 1: no other centroid can be closer
            }
            let p = ds.point(i);
            let mut u_exact = false;
            for c in 0..k {
                if c == a {
                    continue;
                }
                // candidate filter: both conditions must pass
                if upper[i] <= lower[i * k + c] || upper[i] <= 0.5 * cc[a * k + c] {
                    continue;
                }
                if !u_exact {
                    upper[i] = linalg::sqdist(p, &mu[a * d..(a + 1) * d]).sqrt();
                    lower[i * k + a] = upper[i];
                    u_exact = true;
                    if upper[i] <= lower[i * k + c] || upper[i] <= 0.5 * cc[a * k + c] {
                        continue;
                    }
                }
                let dist = linalg::sqdist(p, &mu[c * d..(c + 1) * d]).sqrt();
                lower[i * k + c] = dist;
                if dist < upper[i] {
                    // reassign: update running sums
                    counts[a] -= 1;
                    counts[c] += 1;
                    for j in 0..d {
                        sums[a * d + j] -= p[j] as f64;
                        sums[c * d + j] += p[j] as f64;
                    }
                    a = c;
                    assign[i] = c as i32;
                    upper[i] = dist;
                    u_exact = true;
                }
            }
        }
    }

    let sse = crate::metrics::sse(ds, &mu, k, &assign);
    if let Some(last) = history.last_mut() {
        last.0 = sse;
    }
    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    KmeansResult {
        centroids: mu,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::serial;

    #[test]
    fn matches_lloyd_clustering_2d() {
        let ds = MixtureSpec::paper_2d(8).generate(3000, 3);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let elk = run_from(&ds, &cfg, &mu0);
        assert_eq!(elk.iterations, lloyd.iterations);
        let ari = crate::metrics::adjusted_rand_index(&elk.assign, &lloyd.assign);
        assert!(ari > 0.9999, "ari {ari}");
        assert!((elk.sse - lloyd.sse).abs() / lloyd.sse < 1e-5);
    }

    #[test]
    fn matches_lloyd_clustering_3d_k11() {
        let ds = MixtureSpec::paper_3d(4).generate(2000, 13);
        let cfg = KmeansConfig::new(11).with_seed(17);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let elk = run_from(&ds, &cfg, &mu0);
        let ari = crate::metrics::adjusted_rand_index(&elk.assign, &lloyd.assign);
        assert!(ari > 0.999, "ari {ari}");
    }

    #[test]
    fn agrees_with_hamerly() {
        let ds = MixtureSpec::paper_2d(8).generate(2500, 21);
        let cfg = KmeansConfig::new(8).with_seed(23);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let elk = run_from(&ds, &cfg, &mu0);
        let ham = crate::kmeans::hamerly::run_from(&ds, &cfg, &mu0);
        assert_eq!(elk.assign, ham.assign);
        assert_eq!(elk.iterations, ham.iterations);
    }

    #[test]
    fn converges() {
        // kmeans++ init: random init can land in a local minimum on a
        // crisp mixture (two seeds in one blob), which is a property of
        // Lloyd, not of the acceleration this test exercises.
        let ds = MixtureSpec::random(3, 4, 90.0, 0.5, 31).generate(1500, 1);
        let cfg = KmeansConfig::new(4)
            .with_seed(7)
            .with_init(crate::config::Init::KmeansPlusPlus);
        let r = run(&ds, &cfg);
        assert!(r.converged);
        let ari = crate::metrics::adjusted_rand_index(&r.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.99);
    }
}
