//! Elkan's algorithm — exact Lloyd acceleration with k lower bounds per
//! point plus inter-centroid distances (Elkan 2003; the stronger sibling
//! of [`crate::kmeans::hamerly`], same family as the paper's ref [4]).
//!
//! Memory trade-off: O(n·k) bounds vs Hamerly's O(n) — the A3 ablation
//! bench shows where each wins on the paper's workloads (low-d, modest
//! k: Hamerly usually does).
//!
//! ## Parallel structure (DESIGN.md §9)
//!
//! The run is decomposed into fixed [`sched::CHUNK_ROWS`]-row chunks
//! (a pure function of `n`, never of the worker count) handed to
//! spawn-once workers through the [`sched::ChunkQueue`] work-stealing
//! scheduler. Per chunk, a worker:
//!
//! 1. maintains bounds and builds a per-block candidate mask;
//! 2. batch-refreshes the masked distances through the SIMD
//!    [`kernel::sqdist_pruned`] kernel (bit-identical to
//!    [`crate::linalg::sqdist`] per entry);
//! 3. replays the serial per-point candidate loop against the buffer,
//!    recording reassignments as events instead of touching the global
//!    f64 running sums.
//!
//! The leader then applies the events in ascending row order — exactly
//! the serial engine's `-=`/`+=` chain — so results are **bit-identical
//! to the single-threaded run for every worker count, both scheduler
//! modes, and any steal schedule** (`rust/tests/integration_pruned.rs`
//! pins this). Pruning effectiveness is recorded per iteration in
//! [`KmeansResult::pruning`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex, RwLock};

use crate::config::{DistancePolicy, SchedMode};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::ckpt::{Bounds, CkptSink, CkptState};
use crate::kmeans::sched::{self, ChunkQueue};
use crate::kmeans::step::{finalize_counted, PartialStats};
use crate::kmeans::{init, KmeansConfig, KmeansResult, PruneStats};
use crate::linalg;
use crate::linalg::kernel::{self, KernelTier, POINTS_BLOCK};
use crate::util::trace;

/// Run Elkan-accelerated Lloyd (single worker).
pub fn run(ds: &Dataset, cfg: &KmeansConfig) -> KmeansResult {
    run_threads(ds, cfg, 1, SchedMode::Steal)
}

/// Run from explicit initial centroids (single worker).
pub fn run_from(ds: &Dataset, cfg: &KmeansConfig, centroids0: &[f32]) -> KmeansResult {
    run_from_threads(ds, cfg, 1, SchedMode::Steal, centroids0)
}

/// Run with `threads` workers over the chunk scheduler. Bit-identical
/// to `threads = 1` for every worker count and scheduler mode.
pub fn run_threads(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
) -> KmeansResult {
    let centroids0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from_threads(ds, cfg, threads, sched_mode, &centroids0)
}

/// [`run_threads`] with checkpoint/resume (DESIGN.md §14). The snapshot
/// carries the full triangle-inequality state (bounds, running sums,
/// prune counters); the tol-break precedes the reassignment round, so a
/// converged snapshot is never written — resume re-runs the converging
/// finalize deterministically from the restored f64 sums.
pub fn run_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
    sink: Option<&CkptSink>,
    resume: Option<CkptState>,
) -> Result<KmeansResult> {
    match resume {
        Some(state) => {
            let c0 = state.centroids.clone();
            run_from_threads_ckpt(ds, cfg, threads, sched_mode, &c0, sink, Some(&state))
        }
        None => {
            let c0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
            run_from_threads_ckpt(ds, cfg, threads, sched_mode, &c0, sink, None)
        }
    }
}

/// A deferred reassignment: the worker records it, the leader replays
/// it into the global f64 running sums in ascending row order — the
/// serial engine's exact update chain.
#[derive(Debug, Clone, Copy)]
struct Reassign {
    row: u32,
    from: u32,
    to: u32,
}

/// One chunk's share of the row-indexed state. Locked by whichever
/// worker pops the chunk (exactly one per round), and by the leader
/// between barriers.
struct ChunkSlot<'a> {
    lo: usize,
    assign: &'a mut [i32],
    upper: &'a mut [f32],
    /// `rows × k` lower bounds (this chunk's slice of the global array).
    lower: &'a mut [f32],
    events: Vec<Reassign>,
    computed: u64,
}

/// Read-only per-iteration context the leader publishes to workers.
struct Ctx {
    mu: Vec<f32>,
    moved: Vec<f32>,
    s_half: Vec<f32>,
    /// k×k inter-centroid distances.
    cc: Vec<f32>,
    /// Per-centroid `‖μ‖²` for the `dot` distance policy, recomputed
    /// once per iteration by the leader (empty under `exact`).
    c_norms: Vec<f32>,
}

/// Per-worker scratch: the chunk-sized distance buffer and per-block
/// candidate mask (validity map for the buffer — unmasked entries are
/// stale and never read).
struct Scratch {
    dist: Vec<f32>,
    mask: Vec<bool>,
}

impl Scratch {
    fn new(k: usize) -> Scratch {
        Scratch {
            dist: vec![0.0; sched::CHUNK_ROWS * k],
            mask: vec![false; (sched::CHUNK_ROWS / POINTS_BLOCK) * k],
        }
    }
}

/// Run from explicit initial centroids with `threads` workers.
pub fn run_from_threads(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
    centroids0: &[f32],
) -> KmeansResult {
    run_from_threads_ckpt(ds, cfg, threads, sched_mode, centroids0, None, None)
        .expect("no checkpoint io configured")
}

/// The core loop behind every Elkan entry point. On resume,
/// `centroids0` must be the snapshot's centroids; the bounds arrays are
/// restored before the per-chunk slot split and the dense seeding round
/// is skipped (its result is already baked into the restored state).
fn run_from_threads_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
    centroids0: &[f32],
    sink: Option<&CkptSink>,
    resumed: Option<&CkptState>,
) -> Result<KmeansResult> {
    let n = ds.len();
    let d = ds.dim();
    let k = cfg.k;
    let policy = cfg.distance;
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d);
    // resolve the hot-path tier on the main thread so a bad
    // PARAKM_KERNEL aborts here, not inside a worker
    let tier = kernel::active_tier();
    if policy == DistancePolicy::Dot {
        // materialize the point-norm cache before the workers race
        let _ = ds.norms();
    }

    let nchunks = sched::chunk_count(n);
    let p = threads.max(1).min(nchunks);

    let mut assign = vec![0i32; n];
    let mut upper = vec![0.0f32; n];
    let mut lower = vec![0.0f32; n * k];
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut stats = PartialStats::zeros(k, d);
    if let Some(state) = resumed {
        // Elkan: k lower bounds per point
        let b = state.check_bounds(k, d, n, k)?;
        assign.copy_from_slice(&b.assign);
        upper.copy_from_slice(&b.upper);
        lower.copy_from_slice(&b.lower);
        sums.copy_from_slice(&b.sums);
        counts.copy_from_slice(&b.counts);
    }

    // split the row-indexed state into per-chunk exclusive slices
    let mut slots: Vec<Mutex<ChunkSlot>> = Vec::with_capacity(nchunks);
    {
        let mut ra: &mut [i32] = &mut assign;
        let mut ru: &mut [f32] = &mut upper;
        let mut rl: &mut [f32] = &mut lower;
        for ci in 0..nchunks {
            let (lo, hi) = sched::chunk_range(ci, n);
            let rows = hi - lo;
            let (a, ta) = ra.split_at_mut(rows);
            let (u, tu) = ru.split_at_mut(rows);
            let (l, tl) = rl.split_at_mut(rows * k);
            ra = ta;
            ru = tu;
            rl = tl;
            slots.push(Mutex::new(ChunkSlot {
                lo,
                assign: a,
                upper: u,
                lower: l,
                events: Vec::new(),
                computed: 0,
            }));
        }
    }

    let queue = ChunkQueue::new(p, sched_mode);
    let ctx = RwLock::new(Ctx {
        mu: centroids0.to_vec(),
        moved: vec![0.0f32; k],
        s_half: vec![0.0f32; k],
        cc: vec![0.0f32; k * k],
        c_norms: match policy {
            DistancePolicy::Dot => kernel::row_norms_vec(centroids0, d),
            DistancePolicy::Exact => Vec::new(),
        },
    });
    let barrier = Barrier::new(p + 1);
    let done = AtomicBool::new(false);
    let seeding = AtomicBool::new(resumed.is_none());

    let mut mu = centroids0.to_vec();
    let mut history: Vec<(f64, f64)> = resumed.map(|s| s.history.clone()).unwrap_or_default();
    let mut empty_events: Vec<u64> = resumed.map(|s| s.empty_events.clone()).unwrap_or_default();
    let mut prune = match resumed.and_then(|s| s.bounds.as_ref()) {
        Some(b) => PruneStats {
            seed_computed: b.prune_seed_computed,
            per_iter: b.prune_per_iter.clone(),
        },
        None => PruneStats { seed_computed: n as u64 * k as u64, per_iter: Vec::new() },
    };
    let mut converged = false;
    let mut iterations = resumed.map(|s| s.iteration as usize).unwrap_or(0);
    let mut ckpt_err: Option<Error> = None;

    std::thread::scope(|scope| {
        // ---- workers: spawned once, live across all rounds ------------
        for wid in 0..p {
            let queue = &queue;
            let ctx = &ctx;
            let slots = &slots;
            let barrier = &barrier;
            let done = &done;
            let seeding = &seeding;
            scope.spawn(move || {
                let mut scratch = Scratch::new(k);
                loop {
                    barrier.wait(); // (A) leader published ctx/done
                    if done.load(Ordering::Acquire) {
                        break;
                    }
                    let c = ctx.read().unwrap();
                    if seeding.load(Ordering::Acquire) {
                        while let Some(ci) = queue.pop(wid) {
                            seed_chunk(ds, k, &c, policy, tier, &mut slots[ci].lock().unwrap());
                        }
                    } else {
                        while let Some(ci) = queue.pop(wid) {
                            let mut slot = slots[ci].lock().unwrap();
                            iterate_chunk(ds, k, &c, policy, tier, &mut slot, &mut scratch);
                        }
                    }
                    drop(c);
                    barrier.wait(); // (B) round complete
                }
            });
        }

        // ---- leader ----------------------------------------------------
        if resumed.is_none() {
            // seeding round: dense n×k bound seeding, chunk-parallel
            queue.fill(nchunks);
            barrier.wait(); // (A)
            barrier.wait(); // (B)
            seeding.store(false, Ordering::Release);
            // fold counts/sums in ascending row order — the serial chain
            for slot in &slots {
                let s = slot.lock().unwrap();
                for (r, &a) in s.assign.iter().enumerate() {
                    let best = a as usize;
                    counts[best] += 1;
                    let pt = ds.point(s.lo + r);
                    for j in 0..d {
                        sums[best * d + j] += pt[j] as f64;
                    }
                }
            }
        }

        for _ in iterations..cfg.max_iters {
            stats.reset();
            stats.sums.copy_from_slice(&sums);
            stats.counts.copy_from_slice(&counts);
            let (mu_new, shift, empties) = {
                let _s = trace::span(trace::Phase::Update);
                finalize_counted(&stats, &mu)
            };

            let mut c = ctx.write().unwrap();
            for ci in 0..k {
                let (new, old) = (&mu_new[ci * d..(ci + 1) * d], &mu[ci * d..(ci + 1) * d]);
                c.moved[ci] = linalg::sqdist(new, old).sqrt();
            }
            mu = mu_new;
            c.mu.copy_from_slice(&mu);
            if policy == DistancePolicy::Dot {
                // centroid norms: recomputed once per iteration
                c.c_norms = kernel::row_norms_vec(&mu, d);
            }
            iterations += 1;
            history.push((f64::NAN, shift));
            empty_events.push(empties);
            if shift < cfg.tol {
                converged = true;
                prune.per_iter.push((0, 0)); // no reassignment phase ran
                trace::emit_iter(iterations, f64::NAN, empties, &[]);
                break;
            }

            // inter-centroid distances and s(c)
            let bounds_span = trace::span(trace::Phase::Bounds);
            for a in 0..k {
                let mut nearest = f32::INFINITY;
                for o in 0..k {
                    if o == a {
                        c.cc[a * k + o] = 0.0;
                        continue;
                    }
                    let dist =
                        linalg::sqdist(&mu[a * d..(a + 1) * d], &mu[o * d..(o + 1) * d]).sqrt();
                    c.cc[a * k + o] = dist;
                    nearest = nearest.min(dist);
                }
                c.s_half[a] = nearest * 0.5;
            }
            drop(c);
            drop(bounds_span);

            queue.fill(nchunks);
            {
                let _s = trace::span(trace::Phase::Assign);
                barrier.wait(); // (A)
                barrier.wait(); // (B)
            }

            // replay reassignment events: ascending chunk, emission
            // order within — bitwise the serial engine's update chain
            let merge_span = trace::span(trace::Phase::Merge);
            let mut computed = 0u64;
            for slot in &slots {
                let mut s = slot.lock().unwrap();
                computed += s.computed;
                s.computed = 0;
                for ev in s.events.drain(..) {
                    let (from, to) = (ev.from as usize, ev.to as usize);
                    counts[from] -= 1;
                    counts[to] += 1;
                    let pt = ds.point(ev.row as usize);
                    for j in 0..d {
                        sums[from * d + j] -= pt[j] as f64;
                        sums[to * d + j] += pt[j] as f64;
                    }
                }
            }
            prune.per_iter.push((computed, (n as u64 * k as u64).saturating_sub(computed)));
            drop(merge_span);

            if let Some(sink) = sink {
                let _s = trace::span(trace::Phase::Ckpt);
                if sink.should(iterations) {
                    // gather the chunk-sliced arrays back into row order
                    let mut b_assign = Vec::with_capacity(n);
                    let mut b_upper = Vec::with_capacity(n);
                    let mut b_lower = Vec::with_capacity(n * k);
                    for slot in &slots {
                        let s = slot.lock().unwrap();
                        b_assign.extend_from_slice(s.assign);
                        b_upper.extend_from_slice(s.upper);
                        b_lower.extend_from_slice(s.lower);
                    }
                    let res = sink.save(&CkptState {
                        fingerprint: sink.fingerprint().clone(),
                        iteration: iterations as u64,
                        converged: false,
                        centroids: mu.clone(),
                        prev_centroids: mu.clone(),
                        history: history.clone(),
                        empty_events: empty_events.clone(),
                        bounds: Some(Bounds {
                            assign: b_assign,
                            upper: b_upper,
                            lower: b_lower,
                            sums: sums.clone(),
                            counts: counts.clone(),
                            prune_seed_computed: prune.seed_computed,
                            prune_per_iter: prune.per_iter.clone(),
                        }),
                    });
                    if let Err(e) = res {
                        ckpt_err = Some(e);
                        break;
                    }
                }
            }
            trace::emit_iter(iterations, f64::NAN, empties, &[]);
        }
        done.store(true, Ordering::Release);
        barrier.wait(); // release workers into the exit branch
    });
    drop(slots); // release the per-chunk borrows of assign/upper/lower

    if let Some(e) = ckpt_err {
        return Err(e);
    }
    let sse = crate::metrics::sse(ds, &mu, k, &assign);
    if let Some(last) = history.last_mut() {
        last.0 = sse;
    }
    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    Ok(KmeansResult {
        centroids: mu,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
        empty_events,
        pruning: Some(prune),
    })
}

/// Seeding pass over one chunk: dense squared-distance matrix through
/// the SIMD kernel (per the distance policy), then scalar sqrt/argmin
/// bound seeding — the exact values the serial seeding computes
/// (per-row pure functions).
fn seed_chunk(
    ds: &Dataset,
    k: usize,
    ctx: &Ctx,
    policy: DistancePolicy,
    tier: KernelTier,
    slot: &mut ChunkSlot,
) {
    let d = ds.dim();
    let mu = &ctx.mu;
    let rows = slot.assign.len();
    if rows == 0 {
        return;
    }
    match policy {
        DistancePolicy::Exact => {
            kernel::sqdist_matrix(ds.rows(slot.lo, slot.lo + rows), d, mu, k, slot.lower, tier)
        }
        DistancePolicy::Dot => kernel::sqdist_matrix_dot(
            ds.rows(slot.lo, slot.lo + rows),
            d,
            mu,
            k,
            ds.norms_range(slot.lo, slot.lo + rows),
            &ctx.c_norms,
            slot.lower,
            tier,
        ),
    }
    for r in 0..rows {
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let dist = slot.lower[r * k + c].sqrt();
            slot.lower[r * k + c] = dist;
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
        slot.assign[r] = best as i32;
        slot.upper[r] = best_d;
    }
}

/// One iteration's work on one chunk: bound maintenance, batched
/// bound refresh (per the distance policy), and an exact replay of the
/// serial candidate loop. Under `dot`, the batched refresh runs the
/// norm-trick kernel while the rare off-mask scalar fallback stays
/// subtract-square — both are valid distances, and the bounds logic
/// only needs distances, not a single formulation.
#[allow(clippy::too_many_arguments)]
fn iterate_chunk(
    ds: &Dataset,
    k: usize,
    ctx: &Ctx,
    policy: DistancePolicy,
    tier: KernelTier,
    slot: &mut ChunkSlot,
    scratch: &mut Scratch,
) {
    let d = ds.dim();
    let rows = slot.assign.len();
    if rows == 0 {
        return;
    }
    let lo = slot.lo;
    let nblocks = rows.div_ceil(POINTS_BLOCK);
    let mask = &mut scratch.mask[..nblocks * k];
    mask.fill(false);

    // pass 1: bound maintenance + per-block candidate mask. The mask is
    // built from the pre-tightening bounds, which only shrink during
    // the replay, so it covers a superset of the candidates the serial
    // loop evaluates — except after a mid-loop reassignment changes the
    // cc row, which the replay covers with a scalar fallback.
    for r in 0..rows {
        let a = slot.assign[r] as usize;
        slot.upper[r] += ctx.moved[a];
        for c in 0..k {
            slot.lower[r * k + c] = (slot.lower[r * k + c] - ctx.moved[c]).max(0.0);
        }
        if slot.upper[r] <= ctx.s_half[a] {
            continue; // lemma 1: no other centroid can be closer
        }
        let b = r / POINTS_BLOCK;
        let mut any = false;
        for c in 0..k {
            if c == a {
                continue;
            }
            if slot.upper[r] > slot.lower[r * k + c] && slot.upper[r] > 0.5 * ctx.cc[a * k + c] {
                mask[b * k + c] = true;
                any = true;
            }
        }
        if any {
            mask[b * k + a] = true; // the lazy upper-tightening distance
        }
    }

    // batched bound refresh: one SIMD pass over the masked pairs
    let dist = &mut scratch.dist[..rows * k];
    let mut computed = match policy {
        DistancePolicy::Exact => {
            kernel::sqdist_pruned(ds.rows(lo, lo + rows), d, &ctx.mu, k, mask, dist, tier)
        }
        DistancePolicy::Dot => kernel::sqdist_pruned_dot(
            ds.rows(lo, lo + rows),
            d,
            &ctx.mu,
            k,
            ds.norms_range(lo, lo + rows),
            &ctx.c_norms,
            mask,
            dist,
            tier,
        ),
    };

    // pass 2: the serial candidate loop, verbatim, reading exact
    // distances from the buffer (scalar fallback off-mask)
    let mut fallback = 0u64;
    let exact = |r: usize, c: usize, fallback: &mut u64| -> f32 {
        if mask[(r / POINTS_BLOCK) * k + c] {
            dist[r * k + c].sqrt()
        } else {
            *fallback += 1;
            linalg::sqdist(ds.point(lo + r), &ctx.mu[c * d..(c + 1) * d]).sqrt()
        }
    };
    for r in 0..rows {
        let mut a = slot.assign[r] as usize;
        if slot.upper[r] <= ctx.s_half[a] {
            continue;
        }
        let mut u_exact = false;
        for c in 0..k {
            if c == a {
                continue;
            }
            // candidate filter: both conditions must pass
            if slot.upper[r] <= slot.lower[r * k + c]
                || slot.upper[r] <= 0.5 * ctx.cc[a * k + c]
            {
                continue;
            }
            if !u_exact {
                let du = exact(r, a, &mut fallback);
                slot.upper[r] = du;
                slot.lower[r * k + a] = du;
                u_exact = true;
                if slot.upper[r] <= slot.lower[r * k + c]
                    || slot.upper[r] <= 0.5 * ctx.cc[a * k + c]
                {
                    continue;
                }
            }
            let dc = exact(r, c, &mut fallback);
            slot.lower[r * k + c] = dc;
            if dc < slot.upper[r] {
                // reassign: defer the running-sum update to the leader
                slot.events.push(Reassign {
                    row: (lo + r) as u32,
                    from: a as u32,
                    to: c as u32,
                });
                a = c;
                slot.assign[r] = c as i32;
                slot.upper[r] = dc;
                u_exact = true;
            }
        }
    }
    computed += fallback;
    slot.computed += computed;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::serial;
    use crate::testutil::assert_bit_identical;

    #[test]
    fn matches_lloyd_clustering_2d() {
        let ds = MixtureSpec::paper_2d(8).generate(3000, 3);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let elk = run_from(&ds, &cfg, &mu0);
        assert_eq!(elk.iterations, lloyd.iterations);
        let ari = crate::metrics::adjusted_rand_index(&elk.assign, &lloyd.assign);
        assert!(ari > 0.9999, "ari {ari}");
        assert!((elk.sse - lloyd.sse).abs() / lloyd.sse < 1e-5);
    }

    #[test]
    fn matches_lloyd_clustering_3d_k11() {
        let ds = MixtureSpec::paper_3d(4).generate(2000, 13);
        let cfg = KmeansConfig::new(11).with_seed(17);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let elk = run_from(&ds, &cfg, &mu0);
        let ari = crate::metrics::adjusted_rand_index(&elk.assign, &lloyd.assign);
        assert!(ari > 0.999, "ari {ari}");
    }

    #[test]
    fn agrees_with_hamerly() {
        let ds = MixtureSpec::paper_2d(8).generate(2500, 21);
        let cfg = KmeansConfig::new(8).with_seed(23);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let elk = run_from(&ds, &cfg, &mu0);
        let ham = crate::kmeans::hamerly::run_from(&ds, &cfg, &mu0);
        assert_eq!(elk.assign, ham.assign);
        assert_eq!(elk.iterations, ham.iterations);
    }

    #[test]
    fn converges() {
        // kmeans++ init: random init can land in a local minimum on a
        // crisp mixture (two seeds in one blob), which is a property of
        // Lloyd, not of the acceleration this test exercises.
        let ds = MixtureSpec::random(3, 4, 90.0, 0.5, 31).generate(1500, 1);
        let cfg = KmeansConfig::new(4)
            .with_seed(7)
            .with_init(crate::config::Init::KmeansPlusPlus);
        let r = run(&ds, &cfg);
        assert!(r.converged);
        let ari = crate::metrics::adjusted_rand_index(&r.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.99);
    }

    #[test]
    fn threads_bit_identical_to_single_worker_both_modes() {
        let ds = MixtureSpec::paper_2d(8).generate(4003, 9); // ragged tail chunk
        let cfg = KmeansConfig::new(8).with_seed(3);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let one = run_from_threads(&ds, &cfg, 1, SchedMode::Steal, &mu0);
        for p in [2usize, 3, 4, 8] {
            for mode in [SchedMode::Static, SchedMode::Steal] {
                let r = run_from_threads(&ds, &cfg, p, mode, &mu0);
                assert_bit_identical(&r, &one, &format!("elkan p={p} {mode}"));
                assert_eq!(r.pruning, one.pruning, "p={p} {mode}: prune counters");
            }
        }
    }

    #[test]
    fn dot_policy_matches_lloyd_and_stays_p_independent() {
        use crate::config::DistancePolicy;
        let ds = MixtureSpec::paper_2d(8).generate(3000, 3);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let dcfg = cfg.clone().with_distance(DistancePolicy::Dot);
        let one = run_from_threads(&ds, &dcfg, 1, SchedMode::Steal, &mu0);
        // cross-policy: the same clustering as exact Lloyd (same
        // tolerance the exact-elkan-vs-lloyd pin grants: bound
        // arithmetic in f32 sqrt space can flip a razor-edge point)
        assert_eq!(one.iterations, lloyd.iterations);
        let ari = crate::metrics::adjusted_rand_index(&one.assign, &lloyd.assign);
        assert!(ari > 0.9999, "ari {ari}");
        assert!((one.sse - lloyd.sse).abs() / lloyd.sse < 1e-5);
        // within-policy: chunk-deterministic, so p/sched cannot matter
        for p in [2usize, 4] {
            for mode in [SchedMode::Static, SchedMode::Steal] {
                let r = run_from_threads(&ds, &dcfg, p, mode, &mu0);
                assert_bit_identical(&r, &one, &format!("elkan dot p={p} {mode:?}"));
            }
        }
    }

    #[test]
    fn pruning_counters_recorded_and_bounded() {
        let ds = MixtureSpec::paper_3d(4).generate(3000, 5);
        let cfg = KmeansConfig::new(4).with_seed(11);
        let r = run(&ds, &cfg);
        let prune = r.pruning.as_ref().expect("elkan records pruning");
        assert_eq!(prune.seed_computed, 3000 * 4);
        assert_eq!(prune.per_iter.len(), r.iterations);
        for &(c, s) in &prune.per_iter {
            // each (point, centroid) pair is evaluated at most once per
            // iteration (kernel pairs and scalar fallbacks are disjoint),
            // so computed never exceeds the dense n·k cost and every
            // phase that ran accounts for exactly n·k pairs; the
            // convergence-break iteration records (0, 0)
            assert!(c <= 3000 * 4, "computed {c} exceeds the dense cost");
            assert!(c + s == 3000 * 4 || (c, s) == (0, 0), "computed {c} + skipped {s} != n·k");
        }
        // an easy mixture prunes most of the dense work
        assert!(prune.skip_rate() > 0.3, "skip rate {}", prune.skip_rate());
    }
}
