//! Durable checkpoint/resume with bit-identical recovery (DESIGN.md §14).
//!
//! A long run (oocore streaming 100× past RAM, a multi-hour distributed
//! job) must be killable at any instant and resumed to the *same bits*
//! the uninterrupted run would have produced. The determinism contracts
//! that already make serial ≡ threads ≡ oocore ≡ dist (ascending-order
//! f64 folds, [`crate::kmeans::step::merge_ordered`]) make this
//! provable: every iteration is a pure function of the centroids it
//! starts from, so a snapshot of leader state at an iteration boundary
//! is a complete resume point.
//!
//! Mechanics:
//! - snapshots are `.pkc` files (codec in [`crate::data::io`]): magic,
//!   version, a CRC32-protected fingerprint section (engine/seed/k/
//!   distance/sched/n/d + FNV hash), a state section (iteration,
//!   centroid bits, convergence history) and an optional bounds section
//!   (Elkan/Hamerly triangle-inequality state);
//! - writes are atomic (temp file + fsync + rename) into a two-slot
//!   A/B rotation — a crash *during* checkpointing can only tear the
//!   slot being overwritten, never the previous good snapshot;
//! - [`load`] picks the newest slot that decodes and CRC-verifies;
//!   [`load_validated`] additionally requires the fingerprint to match
//!   the resuming run ([`crate::error::Error::Ckpt`] on mismatch —
//!   wrong seed/engine/data shape must fail loudly, never resume wrong).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};

use crate::data::io as dio;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::step::{self, DistanceMode, PartialStats};
use crate::kmeans::{KmeansConfig, KmeansResult};
use crate::linalg::kernel::{self, DistancePolicy};
use crate::util::chaos;

/// Slot file names of the A/B rotation inside a checkpoint directory.
pub const SLOT_A: &str = "ckpt_a.pkc";
pub const SLOT_B: &str = "ckpt_b.pkc";

/// Identity of a run for resume validation: everything that changes
/// the bits an engine produces. Two runs with equal fingerprints and
/// equal iteration state are bit-interchangeable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint {
    /// Engine family (`"serial"`, `"threads"`, `"elkan"`, ...).
    pub engine: String,
    pub seed: u64,
    pub k: u32,
    /// Distance policy string (`"exact"` / `"dot"`).
    pub distance: String,
    /// Schedule string (`"static"` / `"steal"` / `"elastic"`) — the
    /// fold shape, which changes bits for threads/dist engines.
    pub sched: String,
    /// Dataset rows.
    pub n: u64,
    /// Dataset dimensionality.
    pub d: u32,
}

impl Fingerprint {
    /// FNV-1a over the serialized fields — stored in the `.pkc`
    /// fingerprint section as a cheap cross-check on top of the CRC.
    pub fn hash(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
            // field separator so ("ab","c") != ("a","bc")
            h ^= 0xFF;
            h = h.wrapping_mul(0x100_0000_01b3);
        };
        eat(self.engine.as_bytes());
        eat(&self.seed.to_le_bytes());
        eat(&self.k.to_le_bytes());
        eat(self.distance.as_bytes());
        eat(self.sched.as_bytes());
        eat(&self.n.to_le_bytes());
        eat(&self.d.to_le_bytes());
        h
    }

    /// Typed mismatch report: `Err(Error::Ckpt)` naming the first
    /// differing field, `Ok` iff every field matches.
    pub fn expect_match(&self, found: &Fingerprint) -> Result<()> {
        let mismatch = |what: &str, want: &dyn std::fmt::Display, got: &dyn std::fmt::Display| {
            Err(Error::Ckpt(format!(
                "fingerprint mismatch on {what}: run has {want}, checkpoint has {got} — \
                 refusing to resume a different run"
            )))
        };
        if self.engine != found.engine {
            return mismatch("engine", &self.engine, &found.engine);
        }
        if self.seed != found.seed {
            return mismatch("seed", &self.seed, &found.seed);
        }
        if self.k != found.k {
            return mismatch("k", &self.k, &found.k);
        }
        if self.distance != found.distance {
            return mismatch("distance", &self.distance, &found.distance);
        }
        if self.sched != found.sched {
            return mismatch("sched", &self.sched, &found.sched);
        }
        if self.n != found.n {
            return mismatch("n", &self.n, &found.n);
        }
        if self.d != found.d {
            return mismatch("d", &self.d, &found.d);
        }
        Ok(())
    }
}

/// Map a [`DistancePolicy`] to its fingerprint string.
pub fn policy_str(p: DistancePolicy) -> &'static str {
    match p {
        DistancePolicy::Exact => "exact",
        DistancePolicy::Dot => "dot",
    }
}

/// Build the fingerprint for a run over an `n × d` dataset.
pub fn fingerprint(
    engine: &str,
    sched: &str,
    cfg: &KmeansConfig,
    n: usize,
    d: usize,
) -> Fingerprint {
    Fingerprint {
        engine: engine.to_string(),
        seed: cfg.seed,
        k: cfg.k as u32,
        distance: policy_str(cfg.distance).to_string(),
        sched: sched.to_string(),
        n: n as u64,
        d: d as u32,
    }
}

/// Triangle-inequality engine state (Elkan: `lower` is n×k; Hamerly:
/// n×1) — everything those engines carry across iterations besides the
/// centroids.
#[derive(Debug, Clone, PartialEq)]
pub struct Bounds {
    pub assign: Vec<i32>,
    pub upper: Vec<f32>,
    pub lower: Vec<f32>,
    /// k×d running sums (f64) maintained incrementally by the replay.
    pub sums: Vec<f64>,
    pub counts: Vec<u64>,
    pub prune_seed_computed: u64,
    pub prune_per_iter: Vec<(u64, u64)>,
}

/// One resumable snapshot: leader state at the end of a committed
/// iteration. `prev_centroids` are the centroids the implied
/// assignment was computed against (for dense engines, the pre-update
/// centroids; for bounds engines, equal to `centroids`).
#[derive(Debug, Clone, PartialEq)]
pub struct CkptState {
    pub fingerprint: Fingerprint,
    /// Completed Lloyd iterations.
    pub iteration: u64,
    pub converged: bool,
    pub centroids: Vec<f32>,
    pub prev_centroids: Vec<f32>,
    /// Per-iteration (sse, shift), aligned with iterations; NaN sse
    /// entries (bounds engines fill sse lazily) round-trip bit-exact.
    pub history: Vec<(f64, f64)>,
    /// Per-iteration empty-cluster counts, aligned with `history`.
    pub empty_events: Vec<u64>,
    /// Present for Elkan/Hamerly, `None` for dense engines.
    pub bounds: Option<Bounds>,
}

impl CkptState {
    /// Validate the invariants every engine relies on after a
    /// fingerprint-checked load (defense in depth: a forged state
    /// section with a valid CRC must still fail typed, not panic).
    pub fn check_dense(&self, k: usize, d: usize) -> Result<()> {
        let kd = k * d;
        if self.centroids.len() != kd || self.prev_centroids.len() != kd {
            return Err(Error::Ckpt(format!(
                "state centroids len {} / {} != k {k} × d {d}",
                self.centroids.len(),
                self.prev_centroids.len()
            )));
        }
        if self.iteration == 0 {
            return Err(Error::Ckpt("state has iteration 0 (nothing to resume)".into()));
        }
        if self.history.len() != self.iteration as usize
            || self.empty_events.len() != self.history.len()
        {
            return Err(Error::Ckpt(format!(
                "state history len {} / empty_events len {} != iteration {}",
                self.history.len(),
                self.empty_events.len(),
                self.iteration
            )));
        }
        Ok(())
    }

    /// [`check_dense`](Self::check_dense) plus the bounds-section
    /// invariants; `lower_per_point` is `k` for Elkan, `1` for Hamerly.
    pub fn check_bounds(&self, k: usize, d: usize, n: usize, lower_per_point: usize) -> Result<&Bounds> {
        self.check_dense(k, d)?;
        let b = self
            .bounds
            .as_ref()
            .ok_or_else(|| Error::Ckpt("state has no bounds section for a bounds engine".into()))?;
        if b.assign.len() != n
            || b.upper.len() != n
            || b.lower.len() != n * lower_per_point
            || b.sums.len() != k * d
            || b.counts.len() != k
        {
            return Err(Error::Ckpt(format!(
                "bounds shapes (assign {}, upper {}, lower {}, sums {}, counts {}) \
                 inconsistent with n {n}, k {k}, d {d}",
                b.assign.len(),
                b.upper.len(),
                b.lower.len(),
                b.sums.len(),
                b.counts.len()
            )));
        }
        if b.assign.iter().any(|&a| a < 0 || a as usize >= k) {
            return Err(Error::Ckpt("bounds assignment out of cluster range".into()));
        }
        if b.prune_per_iter.len() != self.history.len() {
            return Err(Error::Ckpt(format!(
                "bounds prune rows {} != history len {}",
                b.prune_per_iter.len(),
                self.history.len()
            )));
        }
        Ok(b)
    }
}

/// Leader-side checkpoint writer: A/B slot rotation over atomic writes.
/// Shared by reference across a run; interior atomics keep `save`
/// callable from `&self`.
pub struct CkptSink {
    dir: PathBuf,
    every: usize,
    fingerprint: Fingerprint,
    /// Next save goes to slot B?
    next_b: AtomicBool,
}

impl CkptSink {
    /// Open (creating if needed) a checkpoint directory. The first
    /// save targets the slot *opposite* the current best snapshot, so
    /// a resumed run never overwrites the snapshot it came from first.
    pub fn create(dir: &Path, every: usize, fingerprint: Fingerprint) -> Result<CkptSink> {
        if every == 0 {
            return Err(Error::Config("checkpoint-every must be >= 1".into()));
        }
        std::fs::create_dir_all(dir)?;
        let a = read_slot(dir, SLOT_A);
        let b = read_slot(dir, SLOT_B);
        let next_b = match (&a, &b) {
            (Some(sa), Some(sb)) => sb.iteration <= sa.iteration,
            (Some(_), None) => true,
            _ => false,
        };
        Ok(CkptSink {
            dir: dir.to_path_buf(),
            every,
            fingerprint,
            next_b: AtomicBool::new(next_b),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn fingerprint(&self) -> &Fingerprint {
        &self.fingerprint
    }

    /// Is iteration `iteration` (1-based, counted *completed*) due for
    /// a snapshot under `--checkpoint-every`?
    pub fn should(&self, iteration: usize) -> bool {
        iteration % self.every == 0
    }

    /// Persist one snapshot into the next rotation slot. Torn and
    /// failed writes are injectable here via the `atomic-write` chaos
    /// site inside [`dio::atomic_write`] — the A/B rotation plus CRC
    /// trailer is what makes either survivable.
    pub fn save(&self, state: &CkptState) -> Result<()> {
        let to_b = self.next_b.fetch_xor(true, Ordering::Relaxed);
        let path = self.dir.join(if to_b { SLOT_B } else { SLOT_A });
        let bytes = dio::encode_ckpt(state);
        dio::atomic_write(&path, &bytes)
    }
}

fn read_slot(dir: &Path, name: &str) -> Option<CkptState> {
    let path = dir.join(name);
    let mut bytes = std::fs::read(&path).ok()?;
    if let Some(fault) = chaos::hit_path(chaos::Site::ArtifactRead, &path) {
        if chaos::apply_to_bytes(chaos::Site::ArtifactRead, fault, &mut bytes).is_some() {
            return None; // injected read failure = slot unreadable
        }
        // torn / bit-flipped bytes fall through: decode_ckpt's CRC
        // must reject them, which reads as a skipped slot below
    }
    dio::decode_ckpt(&bytes).ok()
}

/// Load the newest decodable snapshot from a checkpoint directory.
/// A slot that is missing, truncated or CRC-corrupt is skipped (that
/// is the A/B rotation working as designed); only when *no* slot
/// loads is the result a typed error.
pub fn load(dir: &Path) -> Result<CkptState> {
    match (read_slot(dir, SLOT_A), read_slot(dir, SLOT_B)) {
        (None, None) => Err(Error::Ckpt(format!(
            "no loadable checkpoint in {} (missing or corrupt slots)",
            dir.display()
        ))),
        (Some(s), None) | (None, Some(s)) => Ok(s),
        (Some(a), Some(b)) => Ok(if b.iteration > a.iteration { b } else { a }),
    }
}

/// [`load`] + fingerprint validation against the resuming run.
pub fn load_validated(dir: &Path, expect: &Fingerprint) -> Result<CkptState> {
    let state = load(dir)?;
    expect.expect_match(&state.fingerprint)?;
    if state.fingerprint.hash() != expect.hash() {
        return Err(Error::Ckpt("fingerprint hash mismatch".into()));
    }
    Ok(state)
}

/// Finish a resumed run whose snapshot is already terminal (converged,
/// or at the iteration budget) for engines holding the dataset in
/// memory: one assignment-only E-pass against `prev_centroids` — a
/// pure per-row function, so the assignment is bit-identical to the
/// uninterrupted run's — and sse/shift replayed from the history.
pub fn complete_resident(
    ds: &Dataset,
    cfg: &KmeansConfig,
    state: &CkptState,
) -> Result<KmeansResult> {
    state.check_dense(cfg.k, ds.dim())?;
    let (k, d, n) = (cfg.k, ds.dim(), ds.len());
    if state.fingerprint.n != n as u64 {
        return Err(Error::Ckpt(format!(
            "state fingerprint n {} != dataset n {n}",
            state.fingerprint.n
        )));
    }
    let mut assign = vec![0i32; n];
    let mut stats = PartialStats::zeros(k, d);
    match cfg.distance {
        DistancePolicy::Exact => {
            step::assign_accumulate(ds.raw(), d, &state.prev_centroids, k, &mut assign, &mut stats)?;
        }
        DistancePolicy::Dot => {
            let c_norms = kernel::row_norms_vec(&state.prev_centroids, d);
            step::assign_accumulate_mode(
                ds.raw(),
                d,
                &state.prev_centroids,
                k,
                &mut assign,
                &mut stats,
                &DistanceMode::Dot { x_norms: ds.norms(), c_norms: &c_norms },
            )?;
        }
    }
    Ok(result_from_state(state, assign, k, d))
}

/// Assemble a [`KmeansResult`] from a terminal snapshot plus a freshly
/// recomputed (or restored) assignment. sse/shift come from the last
/// history entry — the values the original run computed.
pub fn result_from_state(state: &CkptState, assign: Vec<i32>, k: usize, d: usize) -> KmeansResult {
    let (sse, shift) = *state.history.last().unwrap_or(&(f64::NAN, f64::NAN));
    KmeansResult {
        centroids: state.centroids.clone(),
        assign,
        k,
        dim: d,
        iterations: state.iteration as usize,
        sse,
        shift,
        converged: state.converged,
        history: state.history.clone(),
        empty_events: state.empty_events.clone(),
        pruning: None,
    }
}

/// Dense-engine resume gate: validate the snapshot against the live
/// run, and if it is already terminal (converged, or at the iteration
/// budget) finish it in place via [`complete_resident`]. Returns
/// `Ok(None)` when the engine must continue iterating from the state.
pub fn resume_dense(
    ds: &Dataset,
    cfg: &KmeansConfig,
    state: &CkptState,
) -> Result<Option<KmeansResult>> {
    state.check_dense(cfg.k, ds.dim())?;
    if state.fingerprint.n != ds.len() as u64 {
        return Err(Error::Ckpt(format!(
            "state fingerprint n {} != dataset n {}",
            state.fingerprint.n,
            ds.len()
        )));
    }
    if state.converged || state.iteration as usize >= cfg.max_iters {
        return Ok(Some(complete_resident(ds, cfg, state)?));
    }
    Ok(None)
}

/// Snapshot fields a dense engine's leader saves at the end of a
/// committed iteration (borrowed; [`save_dense`] clones into the
/// encoder).
pub struct DenseSnap<'a> {
    pub iteration: usize,
    pub converged: bool,
    /// Post-update centroids.
    pub centroids: &'a [f32],
    /// Centroids the iteration's assignment was computed against.
    pub prev_centroids: &'a [f32],
    pub history: &'a [(f64, f64)],
    pub empty_events: &'a [u64],
}

/// Leader-side hook for dense engines: save if this iteration is due.
pub fn save_dense(sink: &CkptSink, snap: &DenseSnap<'_>) -> Result<()> {
    if !sink.should(snap.iteration) {
        return Ok(());
    }
    sink.save(&CkptState {
        fingerprint: sink.fingerprint.clone(),
        iteration: snap.iteration as u64,
        converged: snap.converged,
        centroids: snap.centroids.to_vec(),
        prev_centroids: snap.prev_centroids.to_vec(),
        history: snap.history.to_vec(),
        empty_events: snap.empty_events.to_vec(),
        bounds: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("parakm_ckpt_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fp() -> Fingerprint {
        Fingerprint {
            engine: "serial".into(),
            seed: 42,
            k: 3,
            distance: "exact".into(),
            sched: "static".into(),
            n: 100,
            d: 2,
        }
    }

    fn state(iter: u64) -> CkptState {
        CkptState {
            fingerprint: fp(),
            iteration: iter,
            converged: false,
            centroids: vec![0.5; 6],
            prev_centroids: vec![0.25; 6],
            history: (0..iter).map(|i| (i as f64, 1.0 / (i + 1) as f64)).collect(),
            empty_events: vec![0; iter as usize],
            bounds: None,
        }
    }

    #[test]
    fn sink_rotates_slots_and_load_picks_newest() {
        let dir = tmpdir("rotate");
        let sink = CkptSink::create(&dir, 1, fp()).unwrap();
        sink.save(&state(1)).unwrap();
        assert!(dir.join(SLOT_A).exists());
        assert!(!dir.join(SLOT_B).exists());
        sink.save(&state(2)).unwrap();
        assert!(dir.join(SLOT_B).exists());
        sink.save(&state(3)).unwrap();
        let s = load(&dir).unwrap();
        assert_eq!(s.iteration, 3);
        // slot B still holds iteration 2 — the last good fallback
        let b = read_slot(&dir, SLOT_B).unwrap();
        assert_eq!(b.iteration, 2);
    }

    #[test]
    fn chaos_torn_write_leaves_last_good_snapshot_loadable() {
        let _g = chaos::test_lock();
        // Sweep seeds: every chaos-generated truncation/corruption of
        // slot A must fall back to the good slot B, and a fresh sink
        // (a restarted process) must repair the damaged slot.
        for seed in 0..16u64 {
            let dir = tmpdir(&format!("torn_{seed}"));
            let sink = CkptSink::create(&dir, 1, fp()).unwrap();
            sink.save(&state(1)).unwrap(); // slot A
            sink.save(&state(2)).unwrap(); // slot B
            let plan = chaos::ChaosPlan::new(seed)
                .with_sites(&[chaos::Site::AtomicWrite])
                .with_period(1)
                .with_scope(&dir);
            chaos::install(&plan);
            let res = sink.save(&state(3)); // slot A, faulted
            chaos::uninstall();
            // Injected Fail is a typed error; Torn/BitFlip "succeed"
            // like a crash mid-publish would. Either way the last good
            // snapshot must load: iteration 3 if slot A survived the
            // CRC check, else the slot-B fallback at iteration 2.
            if let Err(e) = &res {
                assert!(e.to_string().contains("chaos: injected"), "{e}");
            }
            let s = load(&dir).unwrap();
            assert!(s.iteration == 2 || s.iteration == 3, "iteration {}", s.iteration);
            // the next save (fresh sink, as a restarted process would
            // use) repairs the torn slot
            let sink2 = CkptSink::create(&dir, 1, fp()).unwrap();
            sink2.save(&state(4)).unwrap();
            assert_eq!(load(&dir).unwrap().iteration, 4);
        }
    }

    #[test]
    fn resumed_sink_overwrites_the_older_slot_first() {
        let dir = tmpdir("resume_slot");
        let sink = CkptSink::create(&dir, 1, fp()).unwrap();
        sink.save(&state(1)).unwrap(); // A = 1
        sink.save(&state(2)).unwrap(); // B = 2
        drop(sink);
        // a resumed run must not clobber the newest snapshot first
        let sink = CkptSink::create(&dir, 1, fp()).unwrap();
        sink.save(&state(3)).unwrap();
        let b = read_slot(&dir, SLOT_B).unwrap();
        assert_eq!(b.iteration, 2, "slot B (the resume source) must survive");
        assert_eq!(read_slot(&dir, SLOT_A).unwrap().iteration, 3);
    }

    #[test]
    fn load_from_empty_dir_is_typed() {
        let dir = tmpdir("empty");
        let err = load(&dir).unwrap_err();
        assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
        assert!(err.to_string().contains("no loadable checkpoint"), "{err}");
    }

    #[test]
    fn fingerprint_mismatch_is_typed_and_names_the_field() {
        let dir = tmpdir("fpmis");
        let sink = CkptSink::create(&dir, 1, fp()).unwrap();
        sink.save(&state(4)).unwrap();
        let mut other = fp();
        other.seed = 43;
        let err = load_validated(&dir, &other).unwrap_err();
        assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
        assert!(err.to_string().contains("seed"), "{err}");
        let mut other = fp();
        other.engine = "threads".into();
        let err = load_validated(&dir, &other).unwrap_err();
        assert!(err.to_string().contains("engine"), "{err}");
        // matching fingerprint loads
        assert_eq!(load_validated(&dir, &fp()).unwrap().iteration, 4);
    }

    #[test]
    fn fingerprint_hash_separates_fields() {
        let a = fp();
        let mut b = fp();
        b.engine = "serialx".into();
        assert_ne!(a.hash(), b.hash());
        let mut c = fp();
        c.seed ^= 1;
        assert_ne!(a.hash(), c.hash());
    }

    #[test]
    fn should_respects_cadence() {
        let dir = tmpdir("cadence");
        let sink = CkptSink::create(&dir, 3, fp()).unwrap();
        assert!(!sink.should(1));
        assert!(!sink.should(2));
        assert!(sink.should(3));
        assert!(sink.should(6));
        assert!(CkptSink::create(&dir, 0, fp()).is_err());
    }

    #[test]
    fn state_checks_reject_forged_shapes() {
        let mut s = state(2);
        s.centroids.pop();
        assert!(matches!(s.check_dense(3, 2).unwrap_err(), Error::Ckpt(_)));
        let mut s = state(2);
        s.history.pop();
        assert!(s.check_dense(3, 2).is_err());
        let s = state(2);
        assert!(s.check_dense(3, 2).is_ok());
        // bounds missing for a bounds engine
        assert!(matches!(s.check_bounds(3, 2, 100, 3).unwrap_err(), Error::Ckpt(_)));
    }
}
