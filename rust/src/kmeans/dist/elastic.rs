//! Elastic chunk-granular distributed Lloyd — the fault-tolerant
//! leader (DESIGN.md §12).
//!
//! Where the static leader ([`super::Cluster`]) pins one shard to one
//! worker and aborts on any failure, this scheduler takes PR 3's
//! work-stealing idea across the network: every iteration is decomposed
//! into the deterministic [`sched`] chunk grid, each chunk is a
//! self-contained work unit (`ChunkAssign` → `ChunkPartials`), and the
//! leader dispatches units to whichever **full-view** worker is free.
//! A unit whose worker dies or stalls past [`DistOpts::io_timeout`] is
//! returned to the queue and re-dispatched; a failed worker is retried
//! with exponential backoff up to [`DistOpts::retry`] times and
//! readmitted mid-run via the `Rejoin` handshake; idle workers at an
//! iteration's tail *speculate* — re-execute an in-flight chunk — so a
//! straggler can be outrun without waiting for its timeout. The run
//! survives as long as one worker stays reachable.
//!
//! ## Why retries cannot change the answer
//!
//! Every execution of chunk `c` produces the same bits: the worker
//! zero-seeds its accumulator and replays the canonical ascending-row
//! fold over `chunk_range(c, n)` (the chunked-accumulation contract,
//! DESIGN.md §4), and replicated inputs mean every worker folds the
//! same rows. The leader keys partials by **chunk id** — not by worker,
//! not by arrival order — and folds them with [`merge_ordered`] in
//! ascending chunk order. Who computed a chunk, how many times it was
//! computed, and when its result arrived are therefore all invisible to
//! the merge: a run with faults is bit-identical to the fault-free
//! elastic run, to any worker count, and to the in-memory work-stealing
//! engine (`threads --sched steal`) — the grids coincide. (It is *not*
//! bit-identical to the static dist scheduler, which groups the f64
//! fold by shard; assignments and iteration counts still match.)
//!
//! Recovery is observable: [`NetStats`] counts re-dispatched chunks,
//! speculative claims and wins, worker failures and rejoins, and the
//! wall-clock spent recovering.

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{ctx, open_socket, DistOpts, DistRun, IterNet, NetStats};
use crate::cluster::wire::{self, Frame, WIRE_VERSION};
use crate::config::{DistancePolicy, Init};
use crate::error::{ClusterError, Error, Result};
use crate::kmeans::ckpt::{self, CkptSink, CkptState, DenseSnap};
use crate::kmeans::sched;
use crate::kmeans::step::{finalize_counted, merge_ordered, PartialStats};
use crate::kmeans::{KmeansConfig, KmeansResult};
use crate::rng::Pcg64;
use crate::util::trace::{self, WorkerPhase};

/// At most this many workers may hold the same chunk at once (the
/// original claim plus one speculative copy). Duplicated work is
/// bounded and harmless — every execution yields the same bits.
const SPECULATE_CAP: usize = 2;

/// First reconnect backoff; doubles per consecutive failure.
const BACKOFF_BASE_MS: u64 = 100;
/// Backoff ceiling (reached after 4 consecutive failures).
const BACKOFF_CAP_MS: u64 = 1_600;

fn backoff(consecutive_failures: u32) -> Duration {
    let shift = consecutive_failures.min(4);
    Duration::from_millis((BACKOFF_BASE_MS << shift).min(BACKOFF_CAP_MS))
}

/// One dispatch phase (an iteration's E-step, or the final assignment
/// collection). Everything the agents share lives under one mutex so a
/// claim, its release, and its result commit are each atomic.
struct Phase {
    /// Monotonic phase id; 0 = no work published yet. An agent carries
    /// the epoch it claimed under, so a result landing after the phase
    /// already completed (a speculation race) is discarded.
    epoch: u64,
    /// Set once the run is over — agents drain out.
    done: bool,
    /// Collect per-row assignments this phase (the final pass).
    want_assign: bool,
    /// Centroids this phase's E-step runs against.
    centroids: Vec<f32>,
    /// Unclaimed chunk ids.
    pending: VecDeque<usize>,
    /// Per chunk: worker ids currently executing it.
    holders: Vec<Vec<usize>>,
    /// Per chunk: a result has been accepted.
    completed: Vec<bool>,
    /// Chunks not yet completed; 0 = phase over.
    remaining: usize,
    /// Accepted partials, keyed by chunk id — the merge reads these in
    /// ascending order, never in arrival order.
    results: Vec<Option<PartialStats>>,
    /// Accepted per-chunk assignment slices (final pass only).
    assign_parts: Vec<Option<Vec<i32>>>,
}

/// State shared between the coordinator and the worker agents.
struct Shared {
    work: Mutex<Phase>,
    cv: Condvar,
    // byte counters are attributed by whichever agent moved the bytes;
    // the coordinator reads deltas per phase
    handshake_bytes: AtomicU64,
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
    redispatched: AtomicU64,
    speculative: AtomicU64,
    /// Shard-side phase ns piggybacked on accepted `ChunkPartials`
    /// (wire v4), accumulated per agent and drained by the coordinator
    /// at each iteration boundary. Only touched when tracing is
    /// installed — observability, never part of the fold.
    agent_assign_ns: Vec<AtomicU64>,
    agent_ser_ns: Vec<AtomicU64>,
}

/// Agent → coordinator notifications. State changes always happen
/// under [`Shared::work`] *before* the event is sent, so the
/// coordinator can re-check `remaining` on every wakeup.
enum Event {
    /// A chunk result was accepted (first completion wins).
    Done { epoch: u64, speculative: bool },
    /// A previously-connected worker dropped or timed out.
    Down,
    /// A worker (re)connected and handshook.
    Up { rejoined: bool },
    /// An agent gave up (retries exhausted or a non-transient error)
    /// and exited.
    Gone { addr: String, err: String },
}

/// A claimed work unit.
struct Job {
    epoch: u64,
    chunk: usize,
    speculative: bool,
    want_assign: bool,
    centroids: Vec<f32>,
}

/// Per-worker agent context (one thread per `--workers` address).
struct Agent<'a> {
    wid: usize,
    addr: &'a str,
    opts: DistOpts,
    n: usize,
    d: usize,
    k: usize,
    policy: DistancePolicy,
    shared: &'a Shared,
    events: Sender<Event>,
}

/// Connect + elastic run with leader-side seeded-random init — the
/// same [`Pcg64`] stream as [`crate::kmeans::init::random`], gathered
/// from the probe worker (full view: global row = local row), so an
/// elastic run starts from the exact centroids every other engine
/// starts from. Only [`Init::Random`] is distributable.
pub fn run(addrs: &[String], cfg: &KmeansConfig, opts: &DistOpts) -> Result<DistRun> {
    if let Init::KmeansPlusPlus = cfg.init {
        return Err(Error::Config(
            "dist: kmeans++ init needs a resident dataset; \
             precompute centroids (kmeans::init) and call run_from"
                .into(),
        ));
    }
    let mut probe = probe_cluster(addrs, opts)?;
    let centroids0 = gather_init(&mut probe, cfg.k, cfg.seed)?;
    run_inner(addrs, cfg, opts, probe, centroids0, None, None)
}

/// Elastic run from explicit initial centroids.
pub fn run_from(
    addrs: &[String],
    cfg: &KmeansConfig,
    opts: &DistOpts,
    centroids0: &[f32],
) -> Result<DistRun> {
    let probe = probe_cluster(addrs, opts)?;
    run_inner(addrs, cfg, opts, probe, centroids0.to_vec(), None, None)
}

/// [`run`] with checkpoint/resume (DESIGN.md §14). The leader
/// checkpoints committed-phase state — a phase either completes (its
/// merge is deterministic regardless of which workers computed which
/// chunks) or it does not happen, so the snapshot is always at a clean
/// iteration boundary.
pub fn run_ckpt(
    addrs: &[String],
    cfg: &KmeansConfig,
    opts: &DistOpts,
    sink: Option<&CkptSink>,
    resume: Option<CkptState>,
) -> Result<DistRun> {
    match resume {
        Some(state) => {
            let probe = probe_cluster(addrs, opts)?;
            let c0 = state.centroids.clone();
            run_inner(addrs, cfg, opts, probe, c0, sink, Some(state))
        }
        None => {
            if let Init::KmeansPlusPlus = cfg.init {
                return Err(Error::Config(
                    "dist: kmeans++ init needs a resident dataset; \
                     precompute centroids (kmeans::init) and call run_from"
                        .into(),
                ));
            }
            let mut probe = probe_cluster(addrs, opts)?;
            let centroids0 = gather_init(&mut probe, cfg.k, cfg.seed)?;
            run_inner(addrs, cfg, opts, probe, centroids0, sink, None)
        }
    }
}

/// [`super::run_ckpt_spec`] under the elastic scheduler: the probe
/// handshake supplies `(n, d)` for the fingerprint, and the probe link
/// is then reused by the run itself (no extra worker session).
pub(crate) fn run_ckpt_spec(
    addrs: &[String],
    cfg: &KmeansConfig,
    opts: &DistOpts,
    spec: &super::CkptSpec,
) -> Result<DistRun> {
    let mut probe = probe_cluster(addrs, opts)?;
    let fp = ckpt::fingerprint("dist", "elastic", cfg, probe.n, probe.d);
    let sink = match &spec.checkpoint {
        Some(dir) => Some(CkptSink::create(dir, spec.every, fp.clone())?),
        None => None,
    };
    let resume = match &spec.resume {
        Some(dir) => Some(ckpt::load_validated(dir, &fp)?),
        None => None,
    };
    match resume {
        Some(state) => {
            let c0 = state.centroids.clone();
            run_inner(addrs, cfg, opts, probe, c0, sink.as_ref(), Some(state))
        }
        None => {
            if let Init::KmeansPlusPlus = cfg.init {
                return Err(Error::Config(
                    "dist: kmeans++ init needs a resident dataset; \
                     precompute centroids (kmeans::init) and call run_from"
                        .into(),
                ));
            }
            let centroids0 = gather_init(&mut probe, cfg.k, cfg.seed)?;
            run_inner(addrs, cfg, opts, probe, centroids0, sink.as_ref(), None)
        }
    }
}

/// The first reachable worker; its `ShardSpec` defines the canonical
/// dataset shape every other worker must match.
struct Probe {
    /// Index into `addrs` — the probe's agent inherits this link.
    idx: usize,
    stream: TcpStream,
    n: usize,
    d: usize,
    handshake_bytes: u64,
    gather_bytes: u64,
}

/// Try addresses in order until one connects and handshakes. Elastic
/// runs start as long as *one* worker is up — the rest join (or rejoin)
/// whenever they come reachable.
fn probe_cluster(addrs: &[String], opts: &DistOpts) -> Result<Probe> {
    if addrs.is_empty() {
        return Err(Error::Config("dist: need at least one worker address".into()));
    }
    let mut last_err = None;
    for (idx, addr) in addrs.iter().enumerate() {
        match try_probe(addr, opts) {
            Ok((stream, n, d, handshake_bytes)) => {
                return Ok(Probe { idx, stream, n, d, handshake_bytes, gather_bytes: 0 })
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.expect("addrs checked non-empty"))
}

fn try_probe(addr: &str, opts: &DistOpts) -> Result<(TcpStream, usize, usize, u64)> {
    let mut stream = open_socket(addr, opts)?;
    let tx = wire::write_frame(&mut stream, &Frame::Hello { version: WIRE_VERSION })
        .map_err(|e| ctx(e, addr))?;
    let (frame, rx) = recv(&mut stream, addr, "waiting for ShardSpec")?;
    match frame {
        Frame::ShardSpec { rows, dim } => {
            let n = usize::try_from(rows).map_err(|_| {
                Error::Cluster(ClusterError::Shape(format!(
                    "worker {addr}: implausible dataset size {rows}"
                )))
            })?;
            if n == 0 || dim == 0 {
                return Err(Error::Cluster(ClusterError::Shape(format!(
                    "worker {addr}: reports an empty dataset ({n} rows × {dim}D)"
                ))));
            }
            Ok((stream, n, dim as usize, tx + rx))
        }
        other => Err(Error::Cluster(ClusterError::Protocol(format!(
            "worker {addr}: expected ShardSpec, got {}",
            other.name()
        )))),
    }
}

/// Read one frame; a worker `ErrMsg` becomes a typed protocol error.
/// (The elastic agents have no [`super::Link`] — connections churn.)
fn recv(stream: &mut TcpStream, addr: &str, expect: &str) -> Result<(Frame, u64)> {
    let (frame, bytes) = wire::read_frame(stream, expect).map_err(|e| ctx(e, addr))?;
    if let Frame::ErrMsg { message } = frame {
        return Err(Error::Cluster(ClusterError::Protocol(format!("worker {addr}: {message}"))));
    }
    Ok((frame, bytes))
}

/// Sample K distinct rows with the canonical init RNG stream and
/// gather them from the probe worker. Full view ⇒ global index ==
/// local index, so one `Gather` suffices and rows come back in request
/// (= centroid-buffer) order.
fn gather_init(probe: &mut Probe, k: usize, seed: u64) -> Result<Vec<f32>> {
    if k > probe.n {
        return Err(Error::Config(format!("init: k {k} > n {}", probe.n)));
    }
    let mut rng = Pcg64::new(seed, 0x1417);
    let idx = rng.sample_indices(probe.n, k);
    let indices: Vec<u64> = idx.iter().map(|&i| i as u64).collect();
    let addr = format!("probe #{}", probe.idx);
    let d = probe.d;
    probe.gather_bytes += wire::write_frame(&mut probe.stream, &Frame::Gather { indices })
        .map_err(|e| ctx(e, &addr))?;
    let (frame, bytes) = recv(&mut probe.stream, &addr, "waiting for gathered rows")?;
    probe.gather_bytes += bytes;
    match frame {
        Frame::Rows { dim, rows } if dim as usize == d && rows.len() == k * d => Ok(rows),
        Frame::Rows { dim, rows } => Err(Error::Cluster(ClusterError::Shape(format!(
            "worker {addr}: gathered {} values of {dim}D rows, expected {k} × {d}D",
            rows.len()
        )))),
        other => Err(Error::Cluster(ClusterError::Protocol(format!(
            "worker {addr}: expected Rows, got {}",
            other.name()
        )))),
    }
}

/// Everything the coordinator computes inside the agent scope.
struct CoordOut {
    result: KmeansResult,
    per_iter: Vec<IterNet>,
    collect_bytes: u64,
    recovery_secs: f64,
    failures: u64,
    rejoins: u64,
    spec_wins: u64,
}

fn run_inner(
    addrs: &[String],
    cfg: &KmeansConfig,
    opts: &DistOpts,
    probe: Probe,
    centroids0: Vec<f32>,
    sink: Option<&CkptSink>,
    resumed: Option<CkptState>,
) -> Result<DistRun> {
    let (n, d, k) = (probe.n, probe.d, cfg.k);
    if k == 0 {
        return Err(Error::Config("dist: k must be >= 1".into()));
    }
    if centroids0.len() != k * d {
        return Err(Error::Shape(format!(
            "dist: initial centroids len {} != k {k} × dim {d}",
            centroids0.len()
        )));
    }
    if let Some(state) = &resumed {
        state.check_dense(k, d)?;
        if state.fingerprint.n != n as u64 {
            return Err(Error::Ckpt(format!(
                "state fingerprint n {} != cluster n {n}",
                state.fingerprint.n
            )));
        }
    }
    let nchunks = sched::chunk_count(n);

    let shared = Shared {
        work: Mutex::new(Phase {
            epoch: 0,
            done: false,
            want_assign: false,
            centroids: Vec::new(),
            pending: VecDeque::new(),
            holders: Vec::new(),
            completed: Vec::new(),
            remaining: 0,
            results: Vec::new(),
            assign_parts: Vec::new(),
        }),
        cv: Condvar::new(),
        handshake_bytes: AtomicU64::new(probe.handshake_bytes),
        bytes_tx: AtomicU64::new(0),
        bytes_rx: AtomicU64::new(0),
        redispatched: AtomicU64::new(0),
        speculative: AtomicU64::new(0),
        agent_assign_ns: (0..addrs.len()).map(|_| AtomicU64::new(0)).collect(),
        agent_ser_ns: (0..addrs.len()).map(|_| AtomicU64::new(0)).collect(),
    };
    let gather_bytes = probe.gather_bytes;
    let probe_idx = probe.idx;
    let mut probe_stream = Some(probe.stream);

    let (event_tx, events) = std::sync::mpsc::channel::<Event>();
    let mut outcome: Result<CoordOut> =
        Err(Error::Worker("elastic coordinator did not run".into()));
    std::thread::scope(|s| {
        for (wid, addr) in addrs.iter().enumerate() {
            // the probe's agent inherits its already-handshaken link
            let initial = if wid == probe_idx { probe_stream.take() } else { None };
            let agent = Agent {
                wid,
                addr,
                opts: *opts,
                n,
                d,
                k,
                policy: cfg.distance,
                shared: &shared,
                events: event_tx.clone(),
            };
            s.spawn(move || agent_main(&agent, initial));
        }
        // the coordinator's recv() reports Disconnected exactly when
        // every agent has exited — drop our own sender to make that so
        drop(event_tx);
        outcome =
            coordinate(&shared, &events, cfg, n, d, nchunks, centroids0, sink, resumed.as_ref());
        // success or failure, wake every agent so the scope can join
        let mut w = shared.work.lock().unwrap();
        w.done = true;
        shared.cv.notify_all();
    });
    let out = outcome?;

    Ok(DistRun {
        result: out.result,
        net: NetStats {
            workers: addrs.len(),
            handshake_bytes: shared.handshake_bytes.load(Ordering::Relaxed),
            gather_bytes,
            per_iter: out.per_iter,
            collect_bytes: out.collect_bytes,
            redispatched_chunks: shared.redispatched.load(Ordering::Relaxed),
            speculative_chunks: shared.speculative.load(Ordering::Relaxed),
            speculative_wins: out.spec_wins,
            worker_failures: out.failures,
            worker_rejoins: out.rejoins,
            recovery_secs: out.recovery_secs,
        },
    })
}

/// Per-phase outcome the coordinator folds into telemetry.
struct PhaseOut {
    results: Vec<PartialStats>,
    assign_parts: Vec<Vec<i32>>,
    bytes_tx: u64,
    bytes_rx: u64,
    secs: f64,
    recovery_secs: f64,
    failures: u64,
    rejoins: u64,
    spec_wins: u64,
}

/// The main-thread phase loop: publish work, wait for completion (or
/// for every agent to give up), merge, repeat; then one final
/// `want_assign` pass against the centroids the last iteration ran
/// with, so assignments mean the same thing as in every other engine.
#[allow(clippy::too_many_arguments)]
fn coordinate(
    shared: &Shared,
    events: &Receiver<Event>,
    cfg: &KmeansConfig,
    n: usize,
    d: usize,
    nchunks: usize,
    centroids0: Vec<f32>,
    sink: Option<&CkptSink>,
    resumed: Option<&CkptState>,
) -> Result<CoordOut> {
    let mut centroids = centroids0;
    // the centroids the most recent *executed* phase used — the final
    // assignment pass must re-run against these, not the updated ones.
    // On resume this is the snapshot's assignment basis, so a terminal
    // snapshot's final pass reproduces the interrupted run's bits.
    let mut mu_used = match resumed {
        Some(s) => s.prev_centroids.clone(),
        None => centroids.clone(),
    };
    let mut history: Vec<(f64, f64)> = resumed.map(|s| s.history.clone()).unwrap_or_default();
    let mut empty_events: Vec<u64> =
        resumed.map(|s| s.empty_events.clone()).unwrap_or_default();
    let mut per_iter: Vec<IterNet> = Vec::new();
    let mut converged = resumed.map(|s| s.converged).unwrap_or(false);
    let mut iterations = resumed.map(|s| s.iteration as usize).unwrap_or(0);
    let mut epoch = 0u64;
    let mut recovery_secs = 0.0;
    let (mut failures, mut rejoins, mut spec_wins) = (0u64, 0u64, 0u64);

    while !converged && iterations < cfg.max_iters {
        epoch += 1;
        mu_used.copy_from_slice(&centroids);
        let out = {
            let _s = trace::span(trace::Phase::Wire);
            run_phase(shared, events, epoch, nchunks, &centroids, false)?
        };
        let merged = {
            let _s = trace::span(trace::Phase::Merge);
            merge_ordered(out.results.iter())
        };
        let (mu_new, shift, empties) = {
            let _s = trace::span(trace::Phase::Update);
            finalize_counted(&merged, &centroids)
        };
        centroids = mu_new;
        iterations += 1;
        history.push((merged.sse, shift));
        empty_events.push(empties);
        per_iter.push(IterNet { bytes_tx: out.bytes_tx, bytes_rx: out.bytes_rx, secs: out.secs });
        recovery_secs += out.recovery_secs;
        failures += out.failures;
        rejoins += out.rejoins;
        spec_wins += out.spec_wins;
        let converged_now = shift < cfg.tol;
        if let Some(sink) = sink {
            let _s = trace::span(trace::Phase::Ckpt);
            // committed-phase state: the merge above is a function of
            // the chunk grid and mu_used alone, so this snapshot resumes
            // bit-identically however the chunks were scheduled
            ckpt::save_dense(
                sink,
                &DenseSnap {
                    iteration: iterations,
                    converged: converged_now,
                    centroids: &centroids,
                    prev_centroids: &mu_used,
                    history: &history,
                    empty_events: &empty_events,
                },
            )?;
        }
        trace::emit_iter(iterations, merged.sse, empties, &drain_worker_phases(shared));
        if converged_now {
            converged = true;
        }
    }

    // the O(n) assignment vector travels once, after the loop — one
    // extra chunk pass with want_assign set (for zero iterations there
    // is nothing to assign against; match the in-memory engines)
    let mut assign = vec![-1i32; n];
    let mut collect_bytes = 0u64;
    if iterations > 0 {
        epoch += 1;
        let out = run_phase(shared, events, epoch, nchunks, &mu_used, true)?;
        for (ci, part) in out.assign_parts.into_iter().enumerate() {
            let (lo, hi) = sched::chunk_range(ci, n);
            debug_assert_eq!(part.len(), hi - lo);
            assign[lo..hi].copy_from_slice(&part);
        }
        collect_bytes = out.bytes_tx + out.bytes_rx;
        recovery_secs += out.recovery_secs;
        failures += out.failures;
        rejoins += out.rejoins;
        spec_wins += out.spec_wins;
    }

    let (sse, shift) = *history.last().unwrap_or(&(f64::NAN, f64::NAN));
    Ok(CoordOut {
        result: KmeansResult {
            centroids,
            assign,
            k: cfg.k,
            dim: d,
            iterations,
            sse,
            shift,
            converged,
            history,
            empty_events,
            pruning: None,
        },
        per_iter,
        collect_bytes,
        recovery_secs,
        failures,
        rejoins,
        spec_wins,
    })
}

/// Drain the per-agent shard-side timing accumulators into one
/// [`WorkerPhase`] row per agent that reported anything this iteration.
/// Empty (no allocation beyond the Vec header) when tracing is off.
fn drain_worker_phases(shared: &Shared) -> Vec<WorkerPhase> {
    if !trace::enabled() {
        return Vec::new();
    }
    shared
        .agent_assign_ns
        .iter()
        .zip(&shared.agent_ser_ns)
        .enumerate()
        .filter_map(|(wid, (a_ns, s_ns))| {
            let assign_ns = a_ns.swap(0, Ordering::Relaxed);
            let ser_ns = s_ns.swap(0, Ordering::Relaxed);
            (assign_ns > 0 || ser_ns > 0).then_some(WorkerPhase {
                worker: wid as u64,
                assign_ns,
                ser_ns,
            })
        })
        .collect()
}

/// Publish one phase and pump events until every chunk has an accepted
/// result. Errors only when *all* agents have exited with work still
/// outstanding — any weaker failure re-dispatches instead.
fn run_phase(
    shared: &Shared,
    events: &Receiver<Event>,
    epoch: u64,
    nchunks: usize,
    centroids: &[f32],
    want_assign: bool,
) -> Result<PhaseOut> {
    let tx0 = shared.bytes_tx.load(Ordering::Relaxed);
    let rx0 = shared.bytes_rx.load(Ordering::Relaxed);
    {
        let mut w = shared.work.lock().unwrap();
        w.epoch = epoch;
        w.want_assign = want_assign;
        w.centroids = centroids.to_vec();
        w.pending = (0..nchunks).collect();
        w.holders = vec![Vec::new(); nchunks];
        w.completed = vec![false; nchunks];
        w.remaining = nchunks;
        w.results = (0..nchunks).map(|_| None).collect();
        w.assign_parts = (0..nchunks).map(|_| None).collect();
        shared.cv.notify_all();
    }
    let t0 = Instant::now();
    let mut first_fail: Option<Instant> = None;
    let (mut failures, mut rejoins, mut spec_wins) = (0u64, 0u64, 0u64);
    let mut gone: Vec<String> = Vec::new();
    loop {
        // agents commit state before sending events, so checking before
        // a blocking recv cannot miss the last completion
        if shared.work.lock().unwrap().remaining == 0 {
            break;
        }
        match events.recv() {
            Ok(Event::Done { epoch: e, speculative }) => {
                if speculative && e == epoch {
                    spec_wins += 1;
                }
            }
            Ok(Event::Down) => {
                failures += 1;
                first_fail.get_or_insert_with(Instant::now);
            }
            Ok(Event::Up { rejoined }) => {
                if rejoined {
                    rejoins += 1;
                }
            }
            Ok(Event::Gone { addr, err }) => gone.push(format!("worker {addr}: {err}")),
            Err(_) => {
                // every agent has exited; the phase either finished on
                // the agents' way out or it never will
                let w = shared.work.lock().unwrap();
                if w.remaining > 0 {
                    return Err(Error::Cluster(ClusterError::Connection(format!(
                        "elastic: all workers lost with {} of {nchunks} chunks outstanding \
                         after retries; {}",
                        w.remaining,
                        if gone.is_empty() {
                            "no agent reported an error".to_string()
                        } else {
                            format!("last errors: {}", gone.join("; "))
                        }
                    ))));
                }
                break;
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let recovery_secs = first_fail.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
    let mut w = shared.work.lock().unwrap();
    let results: Vec<PartialStats> =
        w.results.iter_mut().map(|r| r.take().expect("completed chunk has partials")).collect();
    let assign_parts: Vec<Vec<i32>> = if want_assign {
        w.assign_parts
            .iter_mut()
            .map(|r| r.take().expect("completed chunk has assignments"))
            .collect()
    } else {
        Vec::new()
    };
    drop(w);
    Ok(PhaseOut {
        results,
        assign_parts,
        bytes_tx: shared.bytes_tx.load(Ordering::Relaxed) - tx0,
        bytes_rx: shared.bytes_rx.load(Ordering::Relaxed) - rx0,
        secs,
        recovery_secs,
        failures,
        rejoins,
        spec_wins,
    })
}

/// Agent thread: claim → (re)connect → execute → commit, retrying
/// transient failures with backoff and exiting on `done`, exhausted
/// retries, or a non-transient (protocol/shape/frame) error.
fn agent_main(a: &Agent<'_>, mut stream: Option<TcpStream>) {
    let mut ever_connected = stream.is_some();
    let mut attempts = 0u32;
    loop {
        let Some(job) = next_job(a) else {
            // run over: politely end the session (best effort — the
            // worker also treats a bare close at a frame boundary as a
            // clean end of session)
            if let Some(mut s) = stream {
                let _ = wire::write_frame(&mut s, &Frame::Shutdown);
            }
            return;
        };
        if stream.is_none() {
            match connect_worker(a, ever_connected) {
                Ok(s) => {
                    stream = Some(s);
                    let _ = a.events.send(Event::Up { rejoined: ever_connected });
                    ever_connected = true;
                }
                Err(e) => {
                    release_claim(a, job.epoch, job.chunk);
                    attempts += 1;
                    if !transient(&e) || attempts > a.opts.retry {
                        let _ =
                            a.events.send(Event::Gone { addr: a.addr.to_string(), err: e.to_string() });
                        return;
                    }
                    std::thread::sleep(backoff(attempts - 1));
                    continue;
                }
            }
        }
        match exchange_chunk(stream.as_mut().expect("connected above"), a, &job) {
            Ok((stats, assign)) => {
                attempts = 0;
                if commit(a, &job, stats, assign) {
                    let _ = a
                        .events
                        .send(Event::Done { epoch: job.epoch, speculative: job.speculative });
                }
            }
            Err(e) => {
                stream = None;
                release_claim(a, job.epoch, job.chunk);
                let _ = a.events.send(Event::Down);
                attempts += 1;
                if !transient(&e) || attempts > a.opts.retry {
                    let _ =
                        a.events.send(Event::Gone { addr: a.addr.to_string(), err: e.to_string() });
                    return;
                }
                std::thread::sleep(backoff(attempts - 1));
            }
        }
    }
}

/// Connection loss and timeouts are retryable; protocol, shape and
/// frame errors (version mismatch, sharded worker, corrupt bytes) are
/// a misconfiguration retrying cannot fix.
fn transient(e: &Error) -> bool {
    matches!(e, Error::Cluster(ClusterError::Connection(_)))
}

/// Block until there is a claimable chunk (or the run ends). Prefers
/// unclaimed work; with the queue empty it speculates on an in-flight
/// chunk (lowest id first, capped at [`SPECULATE_CAP`] holders).
fn next_job(a: &Agent<'_>) -> Option<Job> {
    let mut w = a.shared.work.lock().unwrap();
    loop {
        if w.done {
            return None;
        }
        if w.epoch != 0 && w.remaining > 0 {
            if let Some(chunk) = w.pending.pop_front() {
                w.holders[chunk].push(a.wid);
                return Some(Job {
                    epoch: w.epoch,
                    chunk,
                    speculative: false,
                    want_assign: w.want_assign,
                    centroids: w.centroids.clone(),
                });
            }
            let spec = (0..w.holders.len()).find(|&c| {
                !w.completed[c]
                    && !w.holders[c].is_empty()
                    && w.holders[c].len() < SPECULATE_CAP
                    && !w.holders[c].contains(&a.wid)
            });
            if let Some(chunk) = spec {
                w.holders[chunk].push(a.wid);
                a.shared.speculative.fetch_add(1, Ordering::Relaxed);
                return Some(Job {
                    epoch: w.epoch,
                    chunk,
                    speculative: true,
                    want_assign: w.want_assign,
                    centroids: w.centroids.clone(),
                });
            }
        }
        w = a.shared.cv.wait(w).unwrap();
    }
}

/// Atomically deliver a chunk result. Returns false (result discarded)
/// when the phase moved on or another copy of the chunk landed first —
/// both copies carry identical bits, so first-wins is arbitrary *and*
/// harmless.
fn commit(a: &Agent<'_>, job: &Job, stats: PartialStats, assign: Option<Vec<i32>>) -> bool {
    let mut w = a.shared.work.lock().unwrap();
    if w.epoch != job.epoch || w.done {
        return false;
    }
    if let Some(p) = w.holders[job.chunk].iter().position(|&h| h == a.wid) {
        w.holders[job.chunk].swap_remove(p);
    }
    if w.completed[job.chunk] {
        return false;
    }
    w.completed[job.chunk] = true;
    w.remaining -= 1;
    w.results[job.chunk] = Some(stats);
    if let Some(parts) = assign {
        w.assign_parts[job.chunk] = Some(parts);
    }
    true
}

/// Hand a failed claim back: if nobody else holds the chunk and it has
/// no accepted result, it returns to the queue for re-dispatch.
fn release_claim(a: &Agent<'_>, epoch: u64, chunk: usize) {
    let mut w = a.shared.work.lock().unwrap();
    if w.epoch != epoch || w.done {
        return;
    }
    if let Some(p) = w.holders[chunk].iter().position(|&h| h == a.wid) {
        w.holders[chunk].swap_remove(p);
    }
    if !w.completed[chunk] && w.holders[chunk].is_empty() {
        w.pending.push_back(chunk);
        a.shared.redispatched.fetch_add(1, Ordering::Relaxed);
        trace::counter_add("dist_redispatched_chunks_total", 1);
    }
    a.shared.cv.notify_all();
}

/// Open a socket and handshake — `Hello` on the first-ever connect,
/// `Rejoin` thereafter (the wire-visible marker that this session
/// continues an existing run). The worker must report the canonical
/// full-view shape.
fn connect_worker(a: &Agent<'_>, rejoin: bool) -> Result<TcpStream> {
    let mut stream = open_socket(a.addr, &a.opts)?;
    let hello = if rejoin {
        Frame::Rejoin { version: WIRE_VERSION }
    } else {
        Frame::Hello { version: WIRE_VERSION }
    };
    let tx = wire::write_frame(&mut stream, &hello).map_err(|e| ctx(e, a.addr))?;
    let (frame, rx) = recv(&mut stream, a.addr, "waiting for ShardSpec")?;
    a.shared.handshake_bytes.fetch_add(tx + rx, Ordering::Relaxed);
    match frame {
        Frame::ShardSpec { rows, dim }
            if rows == a.n as u64 && dim as usize == a.d =>
        {
            Ok(stream)
        }
        Frame::ShardSpec { rows, dim } => Err(Error::Cluster(ClusterError::Shape(format!(
            "worker {}: serves {rows} rows × {dim}D but the cluster's full view is {} × {}D \
             (elastic workers must replicate the whole input — drop --shard)",
            a.addr, a.n, a.d
        )))),
        other => Err(Error::Cluster(ClusterError::Protocol(format!(
            "worker {}: expected ShardSpec, got {}",
            a.addr,
            other.name()
        )))),
    }
}

/// One `ChunkAssign` → `ChunkPartials` round trip, fully validated.
fn exchange_chunk(
    stream: &mut TcpStream,
    a: &Agent<'_>,
    job: &Job,
) -> Result<(PartialStats, Option<Vec<i32>>)> {
    let (lo, hi) = sched::chunk_range(job.chunk, a.n);
    let req = Frame::ChunkAssign {
        chunk: job.chunk as u64,
        lo: lo as u64,
        hi: hi as u64,
        k: a.k as u32,
        dim: a.d as u32,
        policy: a.policy,
        want_assign: job.want_assign,
        centroids: job.centroids.clone(),
    };
    let tx = wire::write_frame(stream, &req).map_err(|e| ctx(e, a.addr))?;
    a.shared.bytes_tx.fetch_add(tx, Ordering::Relaxed);
    let (frame, rx) = recv(stream, a.addr, "waiting for ChunkPartials")?;
    a.shared.bytes_rx.fetch_add(rx, Ordering::Relaxed);
    match frame {
        Frame::ChunkPartials { chunk, k, dim, counts, sums, sse, assign, phase }
            if chunk == job.chunk as u64
                && k as usize == a.k
                && dim as usize == a.d
                && counts.len() == a.k
                && sums.len() == a.k * a.d
                && assign.len() == if job.want_assign { hi - lo } else { 0 } =>
        {
            if trace::enabled() {
                if let Some(p) = phase {
                    a.shared.agent_assign_ns[a.wid].fetch_add(p.assign_ns, Ordering::Relaxed);
                    a.shared.agent_ser_ns[a.wid].fetch_add(p.ser_ns, Ordering::Relaxed);
                }
            }
            let stats = PartialStats { k: a.k, dim: a.d, sums, counts, sse };
            Ok((stats, job.want_assign.then_some(assign)))
        }
        Frame::ChunkPartials { chunk, k, dim, counts, assign, .. } => {
            Err(Error::Cluster(ClusterError::Shape(format!(
                "worker {}: chunk {chunk} partials shaped {k}×{dim} ({} counts, {} assigns) \
                 do not answer chunk {} ({}×{}, want_assign={})",
                a.addr,
                counts.len(),
                assign.len(),
                job.chunk,
                a.k,
                a.d,
                job.want_assign
            ))))
        }
        other => Err(Error::Cluster(ClusterError::Protocol(format!(
            "worker {}: expected ChunkPartials, got {}",
            a.addr,
            other.name()
        )))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::loopback::LoopbackCluster;
    use crate::cluster::worker::ShardWorker;
    use crate::config::{DistSched, SchedMode};
    use crate::data::source::OwnedMemorySource;
    use crate::data::MixtureSpec;
    use crate::kmeans::init;
    use crate::kmeans::parallel::{self, MergeMode};
    use crate::testutil::assert_bit_identical;

    fn elastic_opts() -> DistOpts {
        DistOpts {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(10),
            sched: DistSched::Elastic,
            retry: 2,
        }
    }

    #[test]
    fn elastic_matches_threads_steal_for_any_worker_count() {
        let ds = MixtureSpec::paper_2d(8).generate(3301, 11);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let reference =
            parallel::run_from_sched(&ds, &cfg, 3, MergeMode::Leader, SchedMode::Steal, &mu0);
        for workers in [1, 2, 3] {
            let cluster = LoopbackCluster::spawn_replicated(&ds, workers, 256).unwrap();
            let run = super::run_from(&cluster.addrs, &cfg, &elastic_opts(), &mu0).unwrap();
            cluster.join().unwrap();
            assert_bit_identical(
                &run.result,
                &reference,
                &format!("elastic({workers}) vs threads-steal"),
            );
            assert_eq!(run.net.per_iter.len(), run.result.iterations);
            assert_eq!(run.net.workers, workers);
            assert!(run.net.collect_bytes > 0);
            // a fault-free loopback run loses nobody
            assert_eq!(run.net.worker_failures, 0);
            assert_eq!(run.net.worker_rejoins, 0);
        }
    }

    #[test]
    fn elastic_seeded_init_matches_the_in_memory_engines() {
        let ds = MixtureSpec::paper_3d(4).generate(2100, 6);
        let cfg = KmeansConfig::new(6).with_seed(42);
        let reference =
            parallel::run_sched(&ds, &cfg, 2, MergeMode::Leader, SchedMode::Steal);
        let cluster = LoopbackCluster::spawn_replicated(&ds, 2, 128).unwrap();
        let run = super::run(&cluster.addrs, &cfg, &elastic_opts()).unwrap();
        cluster.join().unwrap();
        assert_bit_identical(&run.result, &reference, "elastic seeded init vs threads-steal");
        assert!(run.net.gather_bytes > 0, "init gather must be accounted");
    }

    #[test]
    fn sharded_worker_is_a_typed_misconfiguration() {
        // a worker serving rows [0, 60) of a 100-row source refuses
        // ChunkAssign; with no other worker the run must fail typed,
        // naming the fix
        let ds = MixtureSpec::paper_2d(4).generate(100, 9);
        let w = ShardWorker::with_range(
            Box::new(OwnedMemorySource::new(ds)),
            0,
            60,
            32,
        )
        .unwrap();
        let cluster = LoopbackCluster::spawn(vec![w]).unwrap();
        let cfg = KmeansConfig::new(3).with_seed(1);
        let err = super::run(&cluster.addrs, &cfg, &elastic_opts()).unwrap_err();
        let _ = cluster.join(); // drilled nothing: session ended by our error path
        assert!(
            matches!(err, Error::Cluster(ClusterError::Connection(_))),
            "all-workers-lost wraps the cause: {err}"
        );
        assert!(err.to_string().contains("full-view"), "{err}");
    }

    #[test]
    fn zero_iteration_run_matches_threads() {
        let ds = MixtureSpec::paper_2d(4).generate(500, 3);
        let cfg = KmeansConfig::new(4).with_seed(2).with_max_iters(0);
        let reference =
            parallel::run_sched(&ds, &cfg, 2, MergeMode::Leader, SchedMode::Steal);
        // one worker: with zero phases the other workers would never be
        // contacted, and the loopback harness would wait out its accept
        // deadline before joining
        let cluster = LoopbackCluster::spawn_replicated(&ds, 1, 64).unwrap();
        let run = super::run(&cluster.addrs, &cfg, &elastic_opts()).unwrap();
        cluster.join().unwrap();
        assert_eq!(run.result.iterations, 0);
        assert_eq!(run.result.assign, reference.assign); // all -1
        assert_eq!(run.net.collect_bytes, 0);
    }

    #[test]
    fn unreachable_cluster_is_a_typed_connection_error() {
        let opts = DistOpts {
            connect_timeout: Duration::from_millis(200),
            ..elastic_opts()
        };
        let err =
            super::run(&["127.0.0.1:1".to_string()], &KmeansConfig::new(2), &opts).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Connection(_))), "{err}");
        // elastic errors carry the worker address too, same contract as
        // the static scheduler
        assert!(err.to_string().contains("127.0.0.1:1"), "address missing: {err}");
    }

    #[test]
    fn dataset_helper_for_empty_addrs_errors() {
        let err = super::run(&[], &KmeansConfig::new(2), &elastic_opts()).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }
}
