//! Hamerly's algorithm — exact Lloyd acceleration via one lower bound
//! per point (Hamerly 2010; the paper's reference [4] hybridizes this
//! family with MPI/OpenMP).
//!
//! Per point we keep `upper[i]` ≥ dist(x, μ_{a(i)}) and `lower[i]` ≤
//! dist(x, second-nearest μ). A point can skip the full K-distance scan
//! when `upper ≤ max(lower, s(a))`, where `s(c)` is half the distance
//! from centroid c to its nearest other centroid. Produces the exact
//! same sequence of clusterings as Lloyd from the same init.
//!
//! ## Parallel structure (DESIGN.md §9)
//!
//! Same chunk-granular decomposition as [`crate::kmeans::elkan`]:
//! fixed [`sched::CHUNK_ROWS`]-row chunks through the
//! [`sched::ChunkQueue`] work-stealing scheduler, batched bound refresh
//! through [`kernel::sqdist_pruned`] (tighten pass masks each point's
//! own centroid; the full-scan pass masks the complement), and
//! reassignments deferred as events the leader replays in ascending
//! row order. Results are bit-identical to the single-threaded run for
//! every worker count, both scheduler modes, and any steal schedule.
//! Distance-pruning effectiveness is recorded per iteration in
//! [`KmeansResult::pruning`] — first-class, not a bench-side estimate.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex, RwLock};

use crate::config::{DistancePolicy, SchedMode};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::ckpt::{Bounds, CkptSink, CkptState};
use crate::kmeans::sched::{self, ChunkQueue};
use crate::kmeans::step::{finalize_counted, PartialStats};
use crate::kmeans::{init, KmeansConfig, KmeansResult, PruneStats};
use crate::linalg;
use crate::linalg::kernel::{self, KernelTier, POINTS_BLOCK};
use crate::util::trace;

/// Run Hamerly-accelerated Lloyd (single worker).
pub fn run(ds: &Dataset, cfg: &KmeansConfig) -> KmeansResult {
    run_threads(ds, cfg, 1, SchedMode::Steal)
}

/// Run from explicit initial centroids (single worker).
pub fn run_from(ds: &Dataset, cfg: &KmeansConfig, centroids0: &[f32]) -> KmeansResult {
    run_from_threads(ds, cfg, 1, SchedMode::Steal, centroids0)
}

/// Run with `threads` workers over the chunk scheduler. Bit-identical
/// to `threads = 1` for every worker count and scheduler mode.
pub fn run_threads(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
) -> KmeansResult {
    let centroids0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from_threads(ds, cfg, threads, sched_mode, &centroids0)
}

/// [`run_threads`] with checkpoint/resume (DESIGN.md §14). Same
/// contract as [`crate::kmeans::elkan::run_ckpt`]: the snapshot carries
/// the bound arrays (one lower bound per point here) and the f64
/// running sums; the tol-break precedes the reassignment round, so a
/// converged snapshot is never written.
pub fn run_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
    sink: Option<&CkptSink>,
    resume: Option<CkptState>,
) -> Result<KmeansResult> {
    match resume {
        Some(state) => {
            let c0 = state.centroids.clone();
            run_from_threads_ckpt(ds, cfg, threads, sched_mode, &c0, sink, Some(&state))
        }
        None => {
            let c0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
            run_from_threads_ckpt(ds, cfg, threads, sched_mode, &c0, sink, None)
        }
    }
}

/// A deferred reassignment, replayed by the leader in ascending row
/// order — the serial engine's exact f64 update chain.
#[derive(Debug, Clone, Copy)]
struct Reassign {
    row: u32,
    from: u32,
    to: u32,
}

/// One chunk's share of the row-indexed state.
struct ChunkSlot<'a> {
    lo: usize,
    assign: &'a mut [i32],
    upper: &'a mut [f32],
    lower: &'a mut [f32],
    events: Vec<Reassign>,
    computed: u64,
}

/// Read-only per-iteration context the leader publishes to workers.
struct Ctx {
    mu: Vec<f32>,
    moved: Vec<f32>,
    s_half: Vec<f32>,
    max_move: f32,
    second_move: f32,
    /// Per-centroid `‖μ‖²` for the `dot` distance policy, recomputed
    /// once per iteration by the leader (empty under `exact`).
    c_norms: Vec<f32>,
}

/// Per-worker scratch: chunk-sized distance buffer, the two per-block
/// masks (tighten pass / full-scan complement), and the scan-row list.
struct Scratch {
    dist: Vec<f32>,
    mask_a: Vec<bool>,
    mask_b: Vec<bool>,
    scan_rows: Vec<u32>,
}

impl Scratch {
    fn new(k: usize) -> Scratch {
        let blocks = sched::CHUNK_ROWS / POINTS_BLOCK;
        Scratch {
            dist: vec![0.0; sched::CHUNK_ROWS * k],
            mask_a: vec![false; blocks * k],
            mask_b: vec![false; blocks * k],
            scan_rows: Vec::new(),
        }
    }
}

/// Run from explicit initial centroids with `threads` workers.
pub fn run_from_threads(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
    centroids0: &[f32],
) -> KmeansResult {
    run_from_threads_ckpt(ds, cfg, threads, sched_mode, centroids0, None, None)
        .expect("no checkpoint io configured")
}

/// The core loop behind every Hamerly entry point. On resume,
/// `centroids0` must be the snapshot's centroids; the bound arrays are
/// restored before the per-chunk slot split and the two-nearest seeding
/// round is skipped (its result is already baked into the restored
/// state).
fn run_from_threads_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    sched_mode: SchedMode,
    centroids0: &[f32],
    sink: Option<&CkptSink>,
    resumed: Option<&CkptState>,
) -> Result<KmeansResult> {
    let n = ds.len();
    let d = ds.dim();
    let k = cfg.k;
    let policy = cfg.distance;
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d);
    let tier = kernel::active_tier();
    if policy == DistancePolicy::Dot {
        // materialize the point-norm cache before the workers race
        let _ = ds.norms();
    }

    let nchunks = sched::chunk_count(n);
    let p = threads.max(1).min(nchunks);

    let mut assign = vec![0i32; n];
    let mut upper = vec![f32::INFINITY; n];
    let mut lower = vec![0.0f32; n];
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut stats = PartialStats::zeros(k, d);
    if let Some(state) = resumed {
        // Hamerly: one lower bound per point
        let b = state.check_bounds(k, d, n, 1)?;
        assign.copy_from_slice(&b.assign);
        upper.copy_from_slice(&b.upper);
        lower.copy_from_slice(&b.lower);
        sums.copy_from_slice(&b.sums);
        counts.copy_from_slice(&b.counts);
    }

    let mut slots: Vec<Mutex<ChunkSlot>> = Vec::with_capacity(nchunks);
    {
        let mut ra: &mut [i32] = &mut assign;
        let mut ru: &mut [f32] = &mut upper;
        let mut rl: &mut [f32] = &mut lower;
        for ci in 0..nchunks {
            let (lo, hi) = sched::chunk_range(ci, n);
            let rows = hi - lo;
            let (a, ta) = ra.split_at_mut(rows);
            let (u, tu) = ru.split_at_mut(rows);
            let (l, tl) = rl.split_at_mut(rows);
            ra = ta;
            ru = tu;
            rl = tl;
            slots.push(Mutex::new(ChunkSlot {
                lo,
                assign: a,
                upper: u,
                lower: l,
                events: Vec::new(),
                computed: 0,
            }));
        }
    }

    let queue = ChunkQueue::new(p, sched_mode);
    let ctx = RwLock::new(Ctx {
        mu: centroids0.to_vec(),
        moved: vec![0.0f32; k],
        s_half: vec![0.0f32; k],
        max_move: 0.0,
        second_move: 0.0,
        c_norms: match policy {
            DistancePolicy::Dot => kernel::row_norms_vec(centroids0, d),
            DistancePolicy::Exact => Vec::new(),
        },
    });
    let barrier = Barrier::new(p + 1);
    let done = AtomicBool::new(false);
    let seeding = AtomicBool::new(resumed.is_none());

    let mut mu = centroids0.to_vec();
    let mut history: Vec<(f64, f64)> = resumed.map(|s| s.history.clone()).unwrap_or_default();
    let mut empty_events: Vec<u64> = resumed.map(|s| s.empty_events.clone()).unwrap_or_default();
    let mut prune = match resumed.and_then(|s| s.bounds.as_ref()) {
        Some(b) => PruneStats {
            seed_computed: b.prune_seed_computed,
            per_iter: b.prune_per_iter.clone(),
        },
        None => PruneStats { seed_computed: n as u64 * k as u64, per_iter: Vec::new() },
    };
    let mut converged = false;
    let mut iterations = resumed.map(|s| s.iteration as usize).unwrap_or(0);
    let mut ckpt_err: Option<Error> = None;

    std::thread::scope(|scope| {
        // ---- workers: spawned once, live across all rounds ------------
        for wid in 0..p {
            let queue = &queue;
            let ctx = &ctx;
            let slots = &slots;
            let barrier = &barrier;
            let done = &done;
            let seeding = &seeding;
            scope.spawn(move || {
                let mut scratch = Scratch::new(k);
                loop {
                    barrier.wait(); // (A) leader published ctx/done
                    if done.load(Ordering::Acquire) {
                        break;
                    }
                    let c = ctx.read().unwrap();
                    if seeding.load(Ordering::Acquire) {
                        while let Some(ci) = queue.pop(wid) {
                            seed_chunk(ds, k, &c, policy, tier, &mut slots[ci].lock().unwrap());
                        }
                    } else {
                        while let Some(ci) = queue.pop(wid) {
                            let mut slot = slots[ci].lock().unwrap();
                            iterate_chunk(ds, k, &c, policy, tier, &mut slot, &mut scratch);
                        }
                    }
                    drop(c);
                    barrier.wait(); // (B) round complete
                }
            });
        }

        // ---- leader ----------------------------------------------------
        if resumed.is_none() {
            // seeding round: two-nearest scan through the SIMD kernel
            queue.fill(nchunks);
            barrier.wait(); // (A)
            barrier.wait(); // (B)
            seeding.store(false, Ordering::Release);
            for slot in &slots {
                let s = slot.lock().unwrap();
                for (r, &a) in s.assign.iter().enumerate() {
                    let best = a as usize;
                    counts[best] += 1;
                    let pt = ds.point(s.lo + r);
                    for j in 0..d {
                        sums[best * d + j] += pt[j] as f64;
                    }
                }
            }
        }

        for _ in iterations..cfg.max_iters {
            // means from running sums
            stats.reset();
            stats.sums.copy_from_slice(&sums);
            stats.counts.copy_from_slice(&counts);
            let (mu_new, shift, empties) = {
                let _s = trace::span(trace::Phase::Update);
                finalize_counted(&stats, &mu)
            };

            // per-centroid movement; the two largest drive the bounds
            let mut c = ctx.write().unwrap();
            let mut max_move = 0.0f32;
            let mut second_move = 0.0f32;
            for ci in 0..k {
                let (new, old) = (&mu_new[ci * d..(ci + 1) * d], &mu[ci * d..(ci + 1) * d]);
                let m = linalg::sqdist(new, old).sqrt();
                c.moved[ci] = m;
                if m > max_move {
                    second_move = max_move;
                    max_move = m;
                } else if m > second_move {
                    second_move = m;
                }
            }
            c.max_move = max_move;
            c.second_move = second_move;
            mu = mu_new;
            c.mu.copy_from_slice(&mu);
            if policy == DistancePolicy::Dot {
                // centroid norms: recomputed once per iteration
                c.c_norms = kernel::row_norms_vec(&mu, d);
            }
            iterations += 1;

            // SSE bookkeeping for parity with other engines: the final
            // exact pass below fills the last entry.
            history.push((f64::NAN, shift));
            empty_events.push(empties);
            if shift < cfg.tol {
                converged = true;
                prune.per_iter.push((0, 0)); // no reassignment phase ran
                trace::emit_iter(iterations, f64::NAN, empties, &[]);
                break;
            }

            // update s(c): half min distance between centroids
            let bounds_span = trace::span(trace::Phase::Bounds);
            for ci in 0..k {
                let mut best = f32::INFINITY;
                for o in 0..k {
                    if o != ci {
                        let dist =
                            linalg::sqdist(&mu[ci * d..(ci + 1) * d], &mu[o * d..(o + 1) * d]);
                        best = best.min(dist);
                    }
                }
                c.s_half[ci] = best.sqrt() * 0.5;
            }
            drop(c);
            drop(bounds_span);

            queue.fill(nchunks);
            {
                let _s = trace::span(trace::Phase::Assign);
                barrier.wait(); // (A)
                barrier.wait(); // (B)
            }

            // replay reassignment events in ascending row order
            let merge_span = trace::span(trace::Phase::Merge);
            let mut computed = 0u64;
            for slot in &slots {
                let mut s = slot.lock().unwrap();
                computed += s.computed;
                s.computed = 0;
                for ev in s.events.drain(..) {
                    let (from, to) = (ev.from as usize, ev.to as usize);
                    counts[from] -= 1;
                    counts[to] += 1;
                    let pt = ds.point(ev.row as usize);
                    for j in 0..d {
                        sums[from * d + j] -= pt[j] as f64;
                        sums[to * d + j] += pt[j] as f64;
                    }
                }
            }
            prune.per_iter.push((computed, (n as u64 * k as u64).saturating_sub(computed)));
            drop(merge_span);

            if let Some(sink) = sink {
                let _s = trace::span(trace::Phase::Ckpt);
                if sink.should(iterations) {
                    // gather the chunk-sliced arrays back into row order
                    let mut b_assign = Vec::with_capacity(n);
                    let mut b_upper = Vec::with_capacity(n);
                    let mut b_lower = Vec::with_capacity(n);
                    for slot in &slots {
                        let s = slot.lock().unwrap();
                        b_assign.extend_from_slice(s.assign);
                        b_upper.extend_from_slice(s.upper);
                        b_lower.extend_from_slice(s.lower);
                    }
                    let res = sink.save(&CkptState {
                        fingerprint: sink.fingerprint().clone(),
                        iteration: iterations as u64,
                        converged: false,
                        centroids: mu.clone(),
                        prev_centroids: mu.clone(),
                        history: history.clone(),
                        empty_events: empty_events.clone(),
                        bounds: Some(Bounds {
                            assign: b_assign,
                            upper: b_upper,
                            lower: b_lower,
                            sums: sums.clone(),
                            counts: counts.clone(),
                            prune_seed_computed: prune.seed_computed,
                            prune_per_iter: prune.per_iter.clone(),
                        }),
                    });
                    if let Err(e) = res {
                        ckpt_err = Some(e);
                        break;
                    }
                }
            }
            trace::emit_iter(iterations, f64::NAN, empties, &[]);
        }
        done.store(true, Ordering::Release);
        barrier.wait(); // release workers into the exit branch
    });
    drop(slots); // release the per-chunk borrows of assign/upper/lower

    if let Some(e) = ckpt_err {
        return Err(e);
    }

    // final exact SSE pass (the objective the paper reports)
    let sse = crate::metrics::sse(ds, &mu, k, &assign);
    if let Some(last) = history.last_mut() {
        last.0 = sse;
    }
    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    Ok(KmeansResult {
        centroids: mu,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
        empty_events,
        pruning: Some(prune),
    })
}

/// Seeding pass over one chunk: the two-nearest scan runs on the SIMD
/// kernel subsystem (per the distance policy), then the (row-local)
/// sqrt bound seeding.
fn seed_chunk(
    ds: &Dataset,
    k: usize,
    ctx: &Ctx,
    policy: DistancePolicy,
    tier: KernelTier,
    slot: &mut ChunkSlot,
) {
    let d = ds.dim();
    let rows = slot.assign.len();
    if rows == 0 {
        return;
    }
    match policy {
        DistancePolicy::Exact => kernel::assign_two_nearest(
            ds.rows(slot.lo, slot.lo + rows),
            d,
            &ctx.mu,
            k,
            slot.assign,
            slot.upper,
            slot.lower,
            tier,
        ),
        DistancePolicy::Dot => kernel::assign_two_nearest_dot(
            ds.rows(slot.lo, slot.lo + rows),
            d,
            &ctx.mu,
            k,
            ds.norms_range(slot.lo, slot.lo + rows),
            &ctx.c_norms,
            slot.assign,
            slot.upper,
            slot.lower,
            tier,
        ),
    }
    for r in 0..rows {
        slot.upper[r] = slot.upper[r].sqrt();
        slot.lower[r] = slot.lower[r].sqrt();
    }
}

/// One iteration's work on one chunk: bound maintenance, batched upper
/// tightening, batched full-scan refresh (per the distance policy),
/// and the exact serial replay.
#[allow(clippy::too_many_arguments)]
fn iterate_chunk(
    ds: &Dataset,
    k: usize,
    ctx: &Ctx,
    policy: DistancePolicy,
    tier: KernelTier,
    slot: &mut ChunkSlot,
    scratch: &mut Scratch,
) {
    let d = ds.dim();
    let rows = slot.assign.len();
    if rows == 0 {
        return;
    }
    let lo = slot.lo;
    let nblocks = rows.div_ceil(POINTS_BLOCK);
    let mask_a = &mut scratch.mask_a[..nblocks * k];
    let mask_b = &mut scratch.mask_b[..nblocks * k];
    mask_a.fill(false);
    mask_b.fill(false);
    let dist = &mut scratch.dist[..rows * k];
    let scan_rows = &mut scratch.scan_rows;
    scan_rows.clear();

    // pass 1: bound maintenance; unpruned points mask their own
    // centroid's column for the batched upper-tightening refresh
    for r in 0..rows {
        let a = slot.assign[r] as usize;
        slot.upper[r] += ctx.moved[a];
        slot.lower[r] -= if ctx.moved[a] == ctx.max_move {
            ctx.second_move
        } else {
            ctx.max_move
        };
        let bound = slot.lower[r].max(ctx.s_half[a]);
        if slot.upper[r] > bound {
            mask_a[(r / POINTS_BLOCK) * k + a] = true;
        }
    }
    let mut computed = match policy {
        DistancePolicy::Exact => {
            kernel::sqdist_pruned(ds.rows(lo, lo + rows), d, &ctx.mu, k, mask_a, dist, tier)
        }
        DistancePolicy::Dot => kernel::sqdist_pruned_dot(
            ds.rows(lo, lo + rows),
            d,
            &ctx.mu,
            k,
            ds.norms_range(lo, lo + rows),
            &ctx.c_norms,
            mask_a,
            dist,
            tier,
        ),
    };

    // pass 2: tighten upper with the exact distance; points still past
    // their bound need the full scan — mask the complement columns so
    // the buffer holds the whole dense row for those blocks
    for r in 0..rows {
        let a = slot.assign[r] as usize;
        let bound = slot.lower[r].max(ctx.s_half[a]);
        if slot.upper[r] <= bound {
            continue; // pruned: assignment provably unchanged
        }
        slot.upper[r] = dist[r * k + a].sqrt();
        if slot.upper[r] <= bound {
            continue;
        }
        scan_rows.push(r as u32);
        let b = r / POINTS_BLOCK;
        for c in 0..k {
            if !mask_a[b * k + c] {
                mask_b[b * k + c] = true;
            }
        }
    }
    computed += match policy {
        DistancePolicy::Exact => {
            kernel::sqdist_pruned(ds.rows(lo, lo + rows), d, &ctx.mu, k, mask_b, dist, tier)
        }
        DistancePolicy::Dot => kernel::sqdist_pruned_dot(
            ds.rows(lo, lo + rows),
            d,
            &ctx.mu,
            k,
            ds.norms_range(lo, lo + rows),
            &ctx.c_norms,
            mask_b,
            dist,
            tier,
        ),
    };

    // pass 3: full scan replay from the (now dense) buffer rows — the
    // serial `two_nearest` comparison sequence, verbatim
    for &r32 in scan_rows.iter() {
        let r = r32 as usize;
        let a = slot.assign[r] as usize;
        let mut best = 0usize;
        let mut d1 = f32::INFINITY;
        let mut d2 = f32::INFINITY;
        for c in 0..k {
            let dc = dist[r * k + c];
            if dc < d1 {
                d2 = d1;
                d1 = dc;
                best = c;
            } else if dc < d2 {
                d2 = dc;
            }
        }
        if best != a {
            slot.events.push(Reassign {
                row: (lo + r) as u32,
                from: a as u32,
                to: best as u32,
            });
            slot.assign[r] = best as i32;
        }
        slot.upper[r] = d1.sqrt();
        slot.lower[r] = d2.sqrt();
    }
    slot.computed += computed;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::serial;
    use crate::testutil::assert_bit_identical;

    #[test]
    fn matches_lloyd_clustering() {
        let ds = MixtureSpec::paper_2d(8).generate(3000, 3);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let ham = run_from(&ds, &cfg, &mu0);
        assert_eq!(ham.iterations, lloyd.iterations);
        let ari = crate::metrics::adjusted_rand_index(&ham.assign, &lloyd.assign);
        assert!(ari > 0.9999, "ari {ari}");
        assert!((ham.sse - lloyd.sse).abs() / lloyd.sse < 1e-5);
    }

    #[test]
    fn matches_lloyd_3d() {
        let ds = MixtureSpec::paper_3d(4).generate(2000, 9);
        let cfg = KmeansConfig::new(4).with_seed(11);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let ham = run_from(&ds, &cfg, &mu0);
        assert_eq!(ham.assign, lloyd.assign);
    }

    #[test]
    fn converges() {
        // kmeans++ init — see elkan::tests::converges for why.
        let ds = MixtureSpec::random(2, 4, 70.0, 0.4, 2).generate(2000, 4);
        let cfg = KmeansConfig::new(4)
            .with_seed(6)
            .with_init(crate::config::Init::KmeansPlusPlus);
        let r = run(&ds, &cfg);
        assert!(r.converged);
        let ari = crate::metrics::adjusted_rand_index(&r.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.99);
    }

    #[test]
    fn threads_bit_identical_to_single_worker_both_modes() {
        let ds = MixtureSpec::paper_3d(4).generate(5001, 7); // ragged tail chunk
        let cfg = KmeansConfig::new(4).with_seed(2);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let one = run_from_threads(&ds, &cfg, 1, SchedMode::Steal, &mu0);
        for p in [2usize, 3, 4, 8] {
            for mode in [SchedMode::Static, SchedMode::Steal] {
                let r = run_from_threads(&ds, &cfg, p, mode, &mu0);
                assert_bit_identical(&r, &one, &format!("hamerly p={p} {mode}"));
                assert_eq!(r.pruning, one.pruning, "p={p} {mode}: prune counters");
            }
        }
    }

    #[test]
    fn dot_policy_matches_lloyd_and_stays_p_independent() {
        use crate::config::DistancePolicy;
        let ds = MixtureSpec::paper_3d(4).generate(2000, 9);
        let cfg = KmeansConfig::new(4).with_seed(11);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let dcfg = cfg.clone().with_distance(DistancePolicy::Dot);
        let one = run_from_threads(&ds, &dcfg, 1, SchedMode::Steal, &mu0);
        assert_eq!(one.iterations, lloyd.iterations);
        let ari = crate::metrics::adjusted_rand_index(&one.assign, &lloyd.assign);
        assert!(ari > 0.9999, "ari {ari}");
        assert!((one.sse - lloyd.sse).abs() / lloyd.sse < 1e-5);
        for p in [2usize, 4] {
            for mode in [SchedMode::Static, SchedMode::Steal] {
                let r = run_from_threads(&ds, &dcfg, p, mode, &mu0);
                assert_bit_identical(&r, &one, &format!("hamerly dot p={p} {mode:?}"));
            }
        }
    }

    #[test]
    fn k1_degenerate_prunes_everything() {
        // k = 1: s(c) is infinite, every point group-prunes forever
        let ds = MixtureSpec::paper_2d(4).generate(500, 3);
        let cfg = KmeansConfig::new(1).with_seed(1);
        let r = run(&ds, &cfg);
        assert!(r.converged);
        assert!(r.assign.iter().all(|&a| a == 0));
        let prune = r.pruning.unwrap();
        assert!(prune.per_iter.iter().skip(1).all(|&(c, _)| c == 0), "{:?}", prune.per_iter);
    }

    #[test]
    fn pruning_counters_recorded() {
        let ds = MixtureSpec::paper_2d(8).generate(2500, 5);
        let cfg = KmeansConfig::new(8).with_seed(9);
        let r = run(&ds, &cfg);
        let prune = r.pruning.as_ref().expect("hamerly records pruning");
        assert_eq!(prune.seed_computed, 2500 * 8);
        assert_eq!(prune.per_iter.len(), r.iterations);
        assert!(prune.skip_rate() > 0.3, "skip rate {}", prune.skip_rate());
    }
}
