//! Hamerly's algorithm — exact Lloyd acceleration via one lower bound
//! per point (Hamerly 2010; the paper's reference [4] hybridizes this
//! family with MPI/OpenMP).
//!
//! Per point we keep `upper[i]` ≥ dist(x, μ_{a(i)}) and `lower[i]` ≤
//! dist(x, second-nearest μ). A point can skip the full K-distance scan
//! when `upper ≤ max(lower, s(a))`, where `s(c)` is half the distance
//! from centroid c to its nearest other centroid. Produces the exact
//! same sequence of clusterings as Lloyd from the same init.

use crate::data::Dataset;
use crate::kmeans::step::{finalize, PartialStats};
use crate::kmeans::{init, KmeansConfig, KmeansResult};
use crate::linalg;

/// Run Hamerly-accelerated Lloyd.
pub fn run(ds: &Dataset, cfg: &KmeansConfig) -> KmeansResult {
    let centroids0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from(ds, cfg, &centroids0)
}

/// Run from explicit initial centroids. Also returns statistics about
/// skipped distance computations through [`KmeansResult::history`]
/// (full scans are counted by the bench harness separately).
pub fn run_from(ds: &Dataset, cfg: &KmeansConfig, centroids0: &[f32]) -> KmeansResult {
    let n = ds.len();
    let d = ds.dim();
    let k = cfg.k;
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d);
    let mut mu = centroids0.to_vec();

    let mut assign = vec![0i32; n];
    let mut upper = vec![f32::INFINITY; n];
    let mut lower = vec![0.0f32; n];
    let mut stats = PartialStats::zeros(k, d);
    let mut sums = vec![0.0f64; k * d]; // running per-cluster sums
    let mut counts = vec![0u64; k];

    // initial full assignment pass, seeding bounds and running sums —
    // the two-nearest scan runs on the SIMD kernel subsystem
    linalg::kernel::assign_two_nearest(
        ds.raw(),
        d,
        &mu,
        k,
        &mut assign,
        &mut upper,
        &mut lower,
        linalg::kernel::active_tier(),
    );
    for i in 0..n {
        let p = ds.point(i);
        let best = assign[i] as usize;
        upper[i] = upper[i].sqrt();
        lower[i] = lower[i].sqrt();
        counts[best] += 1;
        for j in 0..d {
            sums[best * d + j] += p[j] as f64;
        }
    }

    let mut history = Vec::new();
    let mut converged = false;
    let mut iterations = 0usize;
    let mut s_half = vec![0.0f32; k];

    for _ in 0..cfg.max_iters {
        // means from running sums
        stats.reset();
        stats.sums.copy_from_slice(&sums);
        stats.counts.copy_from_slice(&counts);
        let (mu_new, shift) = finalize(&stats, &mu);

        // per-centroid movement; adjust bounds
        let mut moved = vec![0.0f32; k];
        let mut max_move = 0.0f32;
        let mut second_move = 0.0f32;
        for c in 0..k {
            let m = linalg::sqdist(&mu_new[c * d..(c + 1) * d], &mu[c * d..(c + 1) * d]).sqrt();
            moved[c] = m;
            if m > max_move {
                second_move = max_move;
                max_move = m;
            } else if m > second_move {
                second_move = m;
            }
        }
        mu = mu_new;
        iterations += 1;

        // SSE bookkeeping for parity with other engines: compute from
        // upper bounds only when exact (skipped otherwise — the bench
        // reports SSE from a final exact pass below).
        history.push((f64::NAN, shift));
        if shift < cfg.tol {
            converged = true;
            break;
        }

        // update s(c): half min distance between centroids
        for c in 0..k {
            let mut best = f32::INFINITY;
            for o in 0..k {
                if o != c {
                    let dist = linalg::sqdist(&mu[c * d..(c + 1) * d], &mu[o * d..(o + 1) * d]);
                    best = best.min(dist);
                }
            }
            s_half[c] = best.sqrt() * 0.5;
        }

        // bound maintenance + conditional reassignment
        for i in 0..n {
            let a = assign[i] as usize;
            upper[i] += moved[a];
            lower[i] -= if moved[a] == max_move { second_move } else { max_move };
            let bound = lower[i].max(s_half[a]);
            if upper[i] <= bound {
                continue; // pruned: assignment provably unchanged
            }
            // tighten upper with one exact distance
            let p = ds.point(i);
            upper[i] = linalg::sqdist(p, &mu[a * d..(a + 1) * d]).sqrt();
            if upper[i] <= bound {
                continue;
            }
            // full scan
            let (best, d1, d2) = two_nearest(p, &mu, k, d);
            if best != a {
                counts[a] -= 1;
                counts[best] += 1;
                for j in 0..d {
                    sums[a * d + j] -= p[j] as f64;
                    sums[best * d + j] += p[j] as f64;
                }
                assign[i] = best as i32;
            }
            upper[i] = d1.sqrt();
            lower[i] = d2.sqrt();
        }
    }

    // final exact SSE pass (the objective the paper reports)
    let sse = crate::metrics::sse(ds, &mu, k, &assign);
    if let Some(last) = history.last_mut() {
        last.0 = sse;
    }
    let shift = history.last().map(|h| h.1).unwrap_or(f64::NAN);
    KmeansResult {
        centroids: mu,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
    }
}

/// Nearest and second-nearest centroid of `p`; returns (argmin, d²₁, d²₂).
fn two_nearest(p: &[f32], mu: &[f32], k: usize, d: usize) -> (usize, f32, f32) {
    let mut best = 0usize;
    let mut d1 = f32::INFINITY;
    let mut d2 = f32::INFINITY;
    for c in 0..k {
        let dist = linalg::sqdist(p, &mu[c * d..(c + 1) * d]);
        if dist < d1 {
            d2 = d1;
            d1 = dist;
            best = c;
        } else if dist < d2 {
            d2 = dist;
        }
    }
    (best, d1, d2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::serial;

    #[test]
    fn matches_lloyd_clustering() {
        let ds = MixtureSpec::paper_2d(8).generate(3000, 3);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let ham = run_from(&ds, &cfg, &mu0);
        assert_eq!(ham.iterations, lloyd.iterations);
        let ari = crate::metrics::adjusted_rand_index(&ham.assign, &lloyd.assign);
        assert!(ari > 0.9999, "ari {ari}");
        assert!((ham.sse - lloyd.sse).abs() / lloyd.sse < 1e-5);
    }

    #[test]
    fn matches_lloyd_3d() {
        let ds = MixtureSpec::paper_3d(4).generate(2000, 9);
        let cfg = KmeansConfig::new(4).with_seed(11);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let lloyd = serial::run_from(&ds, &cfg, &mu0);
        let ham = run_from(&ds, &cfg, &mu0);
        assert_eq!(ham.assign, lloyd.assign);
    }

    #[test]
    fn two_nearest_basic() {
        let mu = vec![0.0, 0.0, 10.0, 0.0, 5.0, 0.0];
        let (b, d1, d2) = two_nearest(&[1.0, 0.0], &mu, 3, 2);
        assert_eq!(b, 0);
        assert_eq!(d1, 1.0);
        assert_eq!(d2, 16.0);
    }

    #[test]
    fn converges() {
        // kmeans++ init — see elkan::tests::converges for why.
        let ds = MixtureSpec::random(2, 4, 70.0, 0.4, 2).generate(2000, 4);
        let cfg = KmeansConfig::new(4)
            .with_seed(6)
            .with_init(crate::config::Init::KmeansPlusPlus);
        let r = run(&ds, &cfg);
        assert!(r.converged);
        let ari = crate::metrics::adjusted_rand_index(&r.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.99);
    }
}
