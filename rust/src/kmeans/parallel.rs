//! Shared-memory parallel Lloyd — the paper's OpenMP program (Tables
//! 2/3, Figures 7–10), re-expressed with rust threads.
//!
//! Faithful to the paper's structure:
//! - threads are spawned **once** before the iteration loop (the paper
//!   prefers `parallel` over `parallel for` for exactly this reason —
//!   the iteration count is unknown);
//! - the dataset is sharded contiguously across `p` threads;
//! - each thread reassigns its shard and accumulates *local* stats;
//! - locals reach the leader either per-thread-slot (leader merges via
//!   the canonical [`merge_ordered`] fold of the chunked-accumulation
//!   contract — the default, lock-free, shared bit-for-bit with the
//!   out-of-core engine [`crate::kmeans::streaming`]) or through a
//!   single mutex the workers serialize on (the paper's `critical`
//!   directive — kept as [`MergeMode::Critical`] for the A2 ablation);
//! - two barriers per iteration mirror the paper's `barrier`: one
//!   after centroid publication, one after stat accumulation.
//!
//! ## Scheduler modes (DESIGN.md §9)
//!
//! [`run_sched`] selects how rows reach workers. `Static` is the
//! paper-faithful path above — contiguous shards, per-shard continuing
//! accumulators, the decomposition the chunked-accumulation contract's
//! `oocore(S) ≡ threads(p = S)` guarantee is defined against. `Steal`
//! re-keys accumulation by fixed [`sched::CHUNK_ROWS`]-row chunk (a
//! pure function of `n`) and lets idle workers steal chunks; per-chunk
//! [`PartialStats`] fold through [`merge_ordered`] in ascending chunk
//! index, so steal-mode results are deterministic for any steal
//! schedule *and identical for every worker count* — a different (but
//! fixed) f64 grouping than static mode, with bit-identical
//! assignments either way.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex, RwLock};

use crate::config::{DistancePolicy, SchedMode};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::ckpt::{self, CkptSink, CkptState, DenseSnap};
use crate::kmeans::sched::{self, ChunkQueue};
use crate::kmeans::step::{
    assign_accumulate, assign_accumulate_into_mode, assign_accumulate_mode, finalize_counted,
    merge_ordered, DistanceMode, PartialStats,
};
use crate::kmeans::{init, KmeansConfig, KmeansResult};
use crate::linalg::kernel;
use crate::util::trace;

/// How worker-local statistics reach the leader (DESIGN.md A2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeMode {
    /// Each worker owns a slot; the leader folds all slots. No lock
    /// contention; the rust-native translation of the paper's intent.
    Leader,
    /// Workers merge into one shared accumulator under a mutex — the
    /// literal translation of the paper's OpenMP `critical` section.
    Critical,
}

/// Run threaded Lloyd with `threads` workers.
pub fn run(ds: &Dataset, cfg: &KmeansConfig, threads: usize) -> KmeansResult {
    run_opts(ds, cfg, threads, MergeMode::Leader)
}

/// Run with an explicit scheduler mode (the `--sched` CLI surface).
pub fn run_sched(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
    sched_mode: SchedMode,
) -> KmeansResult {
    let centroids0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from_sched(ds, cfg, threads, merge, sched_mode, &centroids0)
}

/// [`run_from`] with an explicit scheduler mode. `Static` is the
/// historical contiguous-shard path (all its bitwise contracts
/// preserved); `Steal` is the chunk-granular work-stealing path.
pub fn run_from_sched(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
    sched_mode: SchedMode,
    centroids0: &[f32],
) -> KmeansResult {
    match sched_mode {
        SchedMode::Static => run_from(ds, cfg, threads, merge, centroids0),
        SchedMode::Steal => run_from_steal(ds, cfg, threads, merge, centroids0),
    }
}

/// [`run_sched`] with checkpoint/resume (DESIGN.md §14). Snapshots are
/// leader-side only — workers are stateless across iterations, so the
/// leader's (centroids, history) at an iteration boundary is a complete
/// resume point for either scheduler mode.
pub fn run_sched_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
    sched_mode: SchedMode,
    sink: Option<&CkptSink>,
    resume: Option<CkptState>,
) -> Result<KmeansResult> {
    let (centroids0, state) = match resume {
        Some(state) => {
            if let Some(done) = ckpt::resume_dense(ds, cfg, &state)? {
                return Ok(done);
            }
            (state.centroids.clone(), Some(state))
        }
        None => (init::initialize(ds, cfg.k, cfg.init, cfg.seed), None),
    };
    match sched_mode {
        SchedMode::Static => {
            run_from_ckpt(ds, cfg, threads, merge, &centroids0, sink, state.as_ref())
        }
        SchedMode::Steal => {
            run_from_steal_ckpt(ds, cfg, threads, merge, &centroids0, sink, state.as_ref())
        }
    }
}

/// Run with an explicit merge mode (ablation entry point).
pub fn run_opts(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
) -> KmeansResult {
    let centroids0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from(ds, cfg, threads, merge, &centroids0)
}

/// Run from explicit initial centroids.
pub fn run_from(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
    centroids0: &[f32],
) -> KmeansResult {
    run_from_ckpt(ds, cfg, threads, merge, centroids0, None, None)
        .expect("no checkpoint io configured")
}

/// The static-shard core behind [`run_from`]. `resumed` (if any)
/// supplies the committed iteration counter and telemetry;
/// `centroids0` must then be that snapshot's centroids.
pub fn run_from_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
    centroids0: &[f32],
    sink: Option<&CkptSink>,
    resumed: Option<&CkptState>,
) -> Result<KmeansResult> {
    let p = threads.max(1).min(ds.len().max(1));
    let k = cfg.k;
    let d = ds.dim();
    let policy = cfg.distance;
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d, "bad initial centroids");
    if policy == DistancePolicy::Dot {
        // materialize the point-norm cache once, before the workers race
        let _ = ds.norms();
    }

    let ranges = ds.shard_ranges(p);
    let mut assign = vec![-1i32; ds.len()];

    // split the global assignment buffer into per-shard &mut slices
    let mut assign_shards: Vec<&mut [i32]> = Vec::with_capacity(p);
    {
        let mut rest: &mut [i32] = &mut assign;
        for (lo, hi) in &ranges {
            let (head, tail) = rest.split_at_mut(hi - lo);
            assign_shards.push(head);
            rest = tail;
        }
    }

    let centroids = RwLock::new(centroids0.to_vec());
    let slots: Vec<Mutex<PartialStats>> =
        (0..p).map(|_| Mutex::new(PartialStats::zeros(k, d))).collect();
    let global = Mutex::new(PartialStats::zeros(k, d)); // Critical mode
    let barrier = Barrier::new(p + 1); // workers + leader
    let done = AtomicBool::new(false);

    let (mut iterations, mut history, mut empty_events) = match resumed {
        Some(s) => (s.iteration as usize, s.history.clone(), s.empty_events.clone()),
        None => (0usize, Vec::new(), Vec::new()),
    };
    let mut converged = false;
    let mut ckpt_err: Option<Error> = None;

    std::thread::scope(|scope| {
        // ---- workers: spawned once, live across all iterations --------
        for (wid, shard) in assign_shards.into_iter().enumerate() {
            let (lo, hi) = ranges[wid];
            let rows = ds.rows(lo, hi);
            let x_norms: &[f32] =
                if policy == DistancePolicy::Dot { ds.norms_range(lo, hi) } else { &[] };
            let centroids = &centroids;
            let slots = &slots;
            let global = &global;
            let barrier = &barrier;
            let done = &done;
            scope.spawn(move || {
                let mut local = PartialStats::zeros(k, d);
                loop {
                    barrier.wait(); // (A) leader published centroids/done
                    if done.load(Ordering::Acquire) {
                        break;
                    }
                    let mu = centroids.read().unwrap().clone();
                    match policy {
                        DistancePolicy::Exact => {
                            assign_accumulate(rows, d, &mu, k, shard, &mut local)
                                .expect("shapes validated at run_from entry");
                        }
                        DistancePolicy::Dot => {
                            // centroid norms: once per iteration. Each
                            // worker recomputes its own k·d vector —
                            // the same size as the mu clone above, so
                            // leader-side sharing would save nothing
                            let c_norms = kernel::row_norms_vec(&mu, d);
                            assign_accumulate_mode(
                                rows,
                                d,
                                &mu,
                                k,
                                shard,
                                &mut local,
                                &DistanceMode::Dot { x_norms, c_norms: &c_norms },
                            )
                            .expect("shapes validated at run_from entry");
                        }
                    }
                    match merge {
                        MergeMode::Leader => {
                            slots[wid].lock().unwrap().copy_from(&local);
                        }
                        MergeMode::Critical => {
                            // the paper's critical section
                            global.lock().unwrap().merge(&local);
                        }
                    }
                    barrier.wait(); // (B) stats complete
                }
            });
        }

        // ---- leader ----------------------------------------------------
        for _ in iterations..cfg.max_iters {
            if merge == MergeMode::Critical {
                global.lock().unwrap().reset();
            }
            {
                let _s = trace::span(trace::Phase::Assign);
                barrier.wait(); // (A)
                barrier.wait(); // (B) workers finished this iteration
            }

            let merged = {
                let _s = trace::span(trace::Phase::Merge);
                match merge {
                    // canonical ascending-shard fold (step.rs contract),
                    // straight from the lock guards: identical merged f64
                    // stats as the out-of-core engine at the same shard
                    // count, no per-iteration copies
                    MergeMode::Leader => merge_ordered(slots.iter().map(|s| s.lock().unwrap())),
                    MergeMode::Critical => {
                        let mut m = PartialStats::zeros(k, d);
                        m.merge(&global.lock().unwrap());
                        m
                    }
                }
            };
            let mu_old = centroids.read().unwrap().clone();
            let (mu_new, shift, empties) = {
                let _s = trace::span(trace::Phase::Update);
                finalize_counted(&merged, &mu_old)
            };
            *centroids.write().unwrap() = mu_new;
            iterations += 1;
            history.push((merged.sse, shift));
            empty_events.push(empties);
            let converged_now = shift < cfg.tol;
            if let Some(sink) = sink {
                let _s = trace::span(trace::Phase::Ckpt);
                let snap_err = ckpt::save_dense(
                    sink,
                    &DenseSnap {
                        iteration: iterations,
                        converged: converged_now,
                        centroids: &centroids.read().unwrap(),
                        prev_centroids: &mu_old,
                        history: &history,
                        empty_events: &empty_events,
                    },
                );
                if let Err(e) = snap_err {
                    ckpt_err = Some(e);
                    break;
                }
            }
            trace::emit_iter(iterations, merged.sse, empties, &[]);
            if converged_now {
                converged = true;
                break;
            }
        }
        done.store(true, Ordering::Release);
        barrier.wait(); // release workers into the exit branch
    });

    if let Some(e) = ckpt_err {
        return Err(e);
    }
    let final_centroids = centroids.into_inner().unwrap();
    let (sse, shift) = *history.last().unwrap_or(&(f64::NAN, f64::NAN));
    Ok(KmeansResult {
        centroids: final_centroids,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
        empty_events,
        pruning: None,
    })
}

/// The work-stealing dense engine: statistics keyed by chunk (never by
/// worker), folded through [`merge_ordered`] in ascending chunk index.
/// Deterministic for any steal schedule and any worker count; the
/// `Critical` merge stays arrival-ordered (outside the determinism
/// contract, as in static mode).
fn run_from_steal(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
    centroids0: &[f32],
) -> KmeansResult {
    run_from_steal_ckpt(ds, cfg, threads, merge, centroids0, None, None)
        .expect("no checkpoint io configured")
}

/// The work-stealing core with checkpoint/resume — same leader-side
/// snapshot shape as the static path (chunk ownership is re-derived
/// every iteration, so none of it needs to persist).
fn run_from_steal_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    threads: usize,
    merge: MergeMode,
    centroids0: &[f32],
    sink: Option<&CkptSink>,
    resumed: Option<&CkptState>,
) -> Result<KmeansResult> {
    let n = ds.len();
    let k = cfg.k;
    let d = ds.dim();
    let policy = cfg.distance;
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d, "bad initial centroids");
    if policy == DistancePolicy::Dot {
        let _ = ds.norms();
    }

    let nchunks = sched::chunk_count(n);
    let p = threads.max(1).min(nchunks);
    let mut assign = vec![-1i32; n];

    // per-chunk assignment slices + stats slots
    let mut chunk_assign: Vec<Mutex<&mut [i32]>> = Vec::with_capacity(nchunks);
    {
        let mut rest: &mut [i32] = &mut assign;
        for ci in 0..nchunks {
            let (lo, hi) = sched::chunk_range(ci, n);
            let (head, tail) = rest.split_at_mut(hi - lo);
            chunk_assign.push(Mutex::new(head));
            rest = tail;
        }
    }
    let chunk_stats: Vec<Mutex<PartialStats>> =
        (0..nchunks).map(|_| Mutex::new(PartialStats::zeros(k, d))).collect();

    let queue = ChunkQueue::new(p, SchedMode::Steal);
    let centroids = RwLock::new(centroids0.to_vec());
    let global = Mutex::new(PartialStats::zeros(k, d)); // Critical mode
    let barrier = Barrier::new(p + 1);
    let done = AtomicBool::new(false);

    let (mut iterations, mut history, mut empty_events) = match resumed {
        Some(s) => (s.iteration as usize, s.history.clone(), s.empty_events.clone()),
        None => (0usize, Vec::new(), Vec::new()),
    };
    let mut converged = false;
    let mut ckpt_err: Option<Error> = None;

    std::thread::scope(|scope| {
        // ---- workers: spawned once, live across all iterations --------
        for wid in 0..p {
            let queue = &queue;
            let chunk_assign = &chunk_assign;
            let chunk_stats = &chunk_stats;
            let centroids = &centroids;
            let global = &global;
            let barrier = &barrier;
            let done = &done;
            scope.spawn(move || {
                let mut local = PartialStats::zeros(k, d); // Critical mode
                loop {
                    barrier.wait(); // (A) leader published centroids/done
                    if done.load(Ordering::Acquire) {
                        break;
                    }
                    let mu = centroids.read().unwrap().clone();
                    // centroid norms: once per iteration, shared by
                    // every chunk this worker processes
                    let c_norms = match policy {
                        DistancePolicy::Dot => kernel::row_norms_vec(&mu, d),
                        DistancePolicy::Exact => Vec::new(),
                    };
                    if merge == MergeMode::Critical {
                        local.reset();
                    }
                    while let Some(ci) = queue.pop(wid) {
                        let (lo, hi) = sched::chunk_range(ci, n);
                        let rows = ds.rows(lo, hi);
                        let mode = match policy {
                            DistancePolicy::Exact => DistanceMode::Exact,
                            DistancePolicy::Dot => DistanceMode::Dot {
                                x_norms: ds.norms_range(lo, hi),
                                c_norms: &c_norms,
                            },
                        };
                        let mut out = chunk_assign[ci].lock().unwrap();
                        match merge {
                            MergeMode::Leader => {
                                let mut st = chunk_stats[ci].lock().unwrap();
                                assign_accumulate_mode(rows, d, &mu, k, &mut **out, &mut st, &mode)
                                    .expect("shapes validated at entry");
                            }
                            MergeMode::Critical => {
                                assign_accumulate_into_mode(
                                    rows, d, &mu, k, &mut **out, &mut local, &mode,
                                )
                                .expect("shapes validated at entry");
                            }
                        }
                    }
                    if merge == MergeMode::Critical {
                        // the paper's critical section
                        global.lock().unwrap().merge(&local);
                    }
                    barrier.wait(); // (B) stats complete
                }
            });
        }

        // ---- leader ----------------------------------------------------
        for _ in iterations..cfg.max_iters {
            if merge == MergeMode::Critical {
                global.lock().unwrap().reset();
            }
            queue.fill(nchunks);
            {
                let _s = trace::span(trace::Phase::Assign);
                barrier.wait(); // (A)
                barrier.wait(); // (B) workers finished this iteration
            }

            let merged = {
                let _s = trace::span(trace::Phase::Merge);
                match merge {
                    // canonical zeros-seeded ascending-chunk fold: the
                    // chunk grid depends only on n, so merged f64 stats are
                    // identical for every p and steal schedule
                    MergeMode::Leader => {
                        merge_ordered(chunk_stats.iter().map(|s| s.lock().unwrap()))
                    }
                    MergeMode::Critical => {
                        let mut m = PartialStats::zeros(k, d);
                        m.merge(&global.lock().unwrap());
                        m
                    }
                }
            };
            let mu_old = centroids.read().unwrap().clone();
            let (mu_new, shift, empties) = {
                let _s = trace::span(trace::Phase::Update);
                finalize_counted(&merged, &mu_old)
            };
            *centroids.write().unwrap() = mu_new;
            iterations += 1;
            history.push((merged.sse, shift));
            empty_events.push(empties);
            let converged_now = shift < cfg.tol;
            if let Some(sink) = sink {
                let _s = trace::span(trace::Phase::Ckpt);
                let snap_err = ckpt::save_dense(
                    sink,
                    &DenseSnap {
                        iteration: iterations,
                        converged: converged_now,
                        centroids: &centroids.read().unwrap(),
                        prev_centroids: &mu_old,
                        history: &history,
                        empty_events: &empty_events,
                    },
                );
                if let Err(e) = snap_err {
                    ckpt_err = Some(e);
                    break;
                }
            }
            trace::emit_iter(iterations, merged.sse, empties, &[]);
            if converged_now {
                converged = true;
                break;
            }
        }
        done.store(true, Ordering::Release);
        barrier.wait(); // release workers into the exit branch
    });
    drop(chunk_assign); // release the per-chunk borrows of assign

    if let Some(e) = ckpt_err {
        return Err(e);
    }
    let final_centroids = centroids.into_inner().unwrap();
    let (sse, shift) = *history.last().unwrap_or(&(f64::NAN, f64::NAN));
    Ok(KmeansResult {
        centroids: final_centroids,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
        empty_events,
        pruning: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::serial;
    use crate::testutil::prop;

    /// Threaded must equal serial bit-for-bit from the same init:
    /// the decomposition changes *who* computes, not *what*.
    #[test]
    fn matches_serial_exactly_all_thread_counts() {
        let ds = MixtureSpec::paper_2d(8).generate(5003, 3); // odd n: ragged shards
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let s = serial::run_from(&ds, &cfg, &mu0);
        for p in [1, 2, 3, 4, 8, 16] {
            let r = run_from(&ds, &cfg, p, MergeMode::Leader, &mu0);
            assert_eq!(r.iterations, s.iterations, "p={p}");
            assert_eq!(r.assign, s.assign, "p={p}");
            // centroids: f64 merge order differs (per-shard partials),
            // so allow f32-level slack rather than bit equality
            for (a, b) in r.centroids.iter().zip(&s.centroids) {
                assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "p={p}: {a} vs {b}");
            }
            assert!((r.sse - s.sse).abs() / s.sse.max(1.0) < 1e-6, "p={p}");
        }
    }

    #[test]
    fn critical_mode_matches_leader_mode() {
        let ds = MixtureSpec::paper_3d(4).generate(4001, 7);
        let cfg = KmeansConfig::new(4).with_seed(2);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let a = run_from(&ds, &cfg, 4, MergeMode::Leader, &mu0);
        let b = run_from(&ds, &cfg, 4, MergeMode::Critical, &mu0);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.iterations, b.iterations);
        for (x, y) in a.centroids.iter().zip(&b.centroids) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0));
        }
    }

    #[test]
    fn converges() {
        let ds = MixtureSpec::random(3, 4, 80.0, 0.5, 9).generate(3000, 1);
        let r = run(&ds, &KmeansConfig::new(4).with_seed(4), 8);
        assert!(r.converged);
        let ari = crate::metrics::adjusted_rand_index(&r.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.99, "ari {ari}");
    }

    #[test]
    fn more_threads_than_points() {
        let ds = MixtureSpec::paper_2d(4).generate(10, 1);
        let r = run(&ds, &KmeansConfig::new(2).with_seed(1), 64);
        assert_eq!(r.assign.len(), 10);
        assert!(r.assign.iter().all(|&a| a >= 0));
    }

    #[test]
    fn steal_mode_results_independent_of_worker_count() {
        // chunk-granular stats: the merged f64 grouping is a pure
        // function of n, so ANY p (and any steal schedule) lands on the
        // same bits — stronger than static mode can promise
        let ds = MixtureSpec::paper_2d(8).generate(5003, 3);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let one = run_from_sched(&ds, &cfg, 1, MergeMode::Leader, SchedMode::Steal, &mu0);
        for p in [2usize, 3, 4, 8] {
            let r = run_from_sched(&ds, &cfg, p, MergeMode::Leader, SchedMode::Steal, &mu0);
            crate::testutil::assert_bit_identical(&r, &one, &format!("steal p={p}"));
        }
        // and the assignments agree with the static path exactly
        // (argmin is a pure per-row function of the centroids)
        let stat = run_from(&ds, &cfg, 4, MergeMode::Leader, &mu0);
        assert_eq!(one.assign, stat.assign);
        assert_eq!(one.iterations, stat.iterations);
    }

    #[test]
    fn steal_critical_matches_steal_leader_clustering() {
        let ds = MixtureSpec::paper_3d(4).generate(4001, 7);
        let cfg = KmeansConfig::new(4).with_seed(2);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let a = run_from_sched(&ds, &cfg, 4, MergeMode::Leader, SchedMode::Steal, &mu0);
        let b = run_from_sched(&ds, &cfg, 4, MergeMode::Critical, SchedMode::Steal, &mu0);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.iterations, b.iterations);
        for (x, y) in a.centroids.iter().zip(&b.centroids) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0));
        }
    }

    #[test]
    fn dot_policy_matches_exact_both_sched_modes() {
        let ds = MixtureSpec::paper_2d(8).generate(3001, 5);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let exact = run_from(&ds, &cfg, 4, MergeMode::Leader, &mu0);
        let dcfg = cfg.clone().with_distance(DistancePolicy::Dot);
        for mode in [SchedMode::Static, SchedMode::Steal] {
            let dot = run_from_sched(&ds, &dcfg, 4, MergeMode::Leader, mode, &mu0);
            assert_eq!(dot.assign, exact.assign, "{mode:?}");
            assert_eq!(dot.iterations, exact.iterations, "{mode:?}");
            assert!(
                (dot.sse - exact.sse).abs() / exact.sse.max(1.0) < 1e-5,
                "{mode:?}: {} vs {}",
                dot.sse,
                exact.sse
            );
        }
    }

    #[test]
    fn run_sched_static_is_the_historical_path() {
        let ds = MixtureSpec::paper_2d(8).generate(3001, 11);
        let cfg = KmeansConfig::new(8).with_seed(4);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let via_sched = run_from_sched(&ds, &cfg, 3, MergeMode::Leader, SchedMode::Static, &mu0);
        let direct = run_from(&ds, &cfg, 3, MergeMode::Leader, &mu0);
        crate::testutil::assert_bit_identical(&via_sched, &direct, "static == run_from");
    }

    #[test]
    fn property_partition_complete_any_p() {
        prop::check("threaded partition complete", 8, |g| {
            let n = g.usize_in(50, 500);
            let p = g.usize_in(1, 9);
            let k = g.usize_in(1, 6);
            let data = g.points(n, 2, 10.0);
            let ds = crate::data::Dataset::from_vec(data, 2).unwrap();
            let cfg = KmeansConfig::new(k).with_seed(g.u64()).with_max_iters(5);
            let r = run(&ds, &cfg, p);
            prop::ensure(r.assign.len() == n, "assign length")?;
            prop::ensure(
                r.assign.iter().all(|&a| a >= 0 && (a as usize) < k),
                "assignment out of range",
            )?;
            let total: usize = r.cluster_sizes().iter().sum();
            prop::ensure(total == n, format!("sizes sum {total} != n {n}"))
        });
    }
}
