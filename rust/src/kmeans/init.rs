//! Centroid initialization.
//!
//! [`random`] is the paper's scheme — K distinct points sampled
//! uniformly from the dataset. [`kmeans_plus_plus`] is the D² seeding
//! extension (DESIGN.md A3): it typically cuts iterations-to-converge,
//! which the ablation bench quantifies against the paper's scheme.

use crate::config::Init;
use crate::data::Dataset;
use crate::linalg;
use crate::rng::Pcg64;

/// Dispatch on the configured strategy.
pub fn initialize(ds: &Dataset, k: usize, init: Init, seed: u64) -> Vec<f32> {
    match init {
        Init::Random => random(ds, k, seed),
        Init::KmeansPlusPlus => kmeans_plus_plus(ds, k, seed),
    }
}

/// K distinct data points, uniformly at random (the paper's init).
pub fn random(ds: &Dataset, k: usize, seed: u64) -> Vec<f32> {
    assert!(k <= ds.len(), "k {} > n {}", k, ds.len());
    let mut rng = Pcg64::new(seed, 0x1417);
    let idx = rng.sample_indices(ds.len(), k);
    let mut out = Vec::with_capacity(k * ds.dim());
    for i in idx {
        out.extend_from_slice(ds.point(i));
    }
    out
}

/// k-means++ (Arthur & Vassilvitskii 2007): first centroid uniform,
/// each next centroid sampled ∝ D²(x) = squared distance to the
/// nearest already-chosen centroid.
pub fn kmeans_plus_plus(ds: &Dataset, k: usize, seed: u64) -> Vec<f32> {
    assert!(k <= ds.len(), "k {} > n {}", k, ds.len());
    let n = ds.len();
    let d = ds.dim();
    let mut rng = Pcg64::new(seed, 0x1418);
    let mut centroids = Vec::with_capacity(k * d);

    let first = rng.next_below(n as u64) as usize;
    centroids.extend_from_slice(ds.point(first));

    // running D² to nearest chosen centroid
    let mut d2: Vec<f64> = (0..n)
        .map(|i| linalg::sqdist_f64(ds.point(i), ds.point(first)))
        .collect();

    for _ in 1..k {
        let next = rng.next_weighted(&d2);
        let np = ds.point(next).to_vec();
        centroids.extend_from_slice(&np);
        for i in 0..n {
            let dist = linalg::sqdist_f64(ds.point(i), &np);
            if dist < d2[i] {
                d2[i] = dist;
            }
        }
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::testutil::prop;

    #[test]
    fn random_picks_k_distinct_data_points() {
        let ds = MixtureSpec::paper_2d(4).generate(1000, 1);
        let mu = random(&ds, 8, 5);
        assert_eq!(mu.len(), 16);
        // each centroid is an actual data point
        for c in 0..8 {
            let cent = &mu[c * 2..(c + 1) * 2];
            assert!(
                (0..ds.len()).any(|i| ds.point(i) == cent),
                "centroid {c} not a data point"
            );
        }
        // distinct
        for a in 0..8 {
            for b in (a + 1)..8 {
                assert_ne!(&mu[a * 2..a * 2 + 2], &mu[b * 2..b * 2 + 2]);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = MixtureSpec::paper_3d(4).generate(500, 2);
        assert_eq!(random(&ds, 4, 9), random(&ds, 4, 9));
        assert_ne!(random(&ds, 4, 9), random(&ds, 4, 10));
        assert_eq!(kmeans_plus_plus(&ds, 4, 9), kmeans_plus_plus(&ds, 4, 9));
    }

    #[test]
    fn kpp_spreads_over_components() {
        // 4 tight far-apart blobs: k-means++ must pick one seed in each
        let spec = MixtureSpec::random(2, 4, 100.0, 0.1, 3);
        let ds = spec.generate(2000, 4);
        let mu = kmeans_plus_plus(&ds, 4, 11);
        // nearest true component of each chosen centroid must be unique
        let mut used = std::collections::HashSet::new();
        for c in 0..4 {
            let cent = &mu[c * 2..(c + 1) * 2];
            let (mut best, mut best_d) = (0, f64::INFINITY);
            for (ci, comp) in spec.components.iter().enumerate() {
                let m: Vec<f32> = comp.mean.iter().map(|&v| v as f32).collect();
                let dist = linalg::sqdist_f64(cent, &m);
                if dist < best_d {
                    best_d = dist;
                    best = ci;
                }
            }
            used.insert(best);
        }
        assert_eq!(used.len(), 4, "k-means++ collapsed onto {} components", used.len());
    }

    #[test]
    fn kpp_property_centroids_are_data_points() {
        prop::check("kpp centroids ⊆ data", 16, |g| {
            let n = g.usize_in(10, 200);
            let k = g.usize_in(1, 9).min(n);
            let data = g.points(n, 2, 20.0);
            let ds = crate::data::Dataset::from_vec(data, 2).unwrap();
            let mu = kmeans_plus_plus(&ds, k, g.u64());
            for c in 0..k {
                let cent = &mu[c * 2..(c + 1) * 2];
                let found = (0..n).any(|i| ds.point(i) == cent);
                prop::ensure(found, format!("centroid {c} not in data"))?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic]
    fn k_larger_than_n_panics() {
        let ds = MixtureSpec::paper_2d(4).generate(3, 1);
        random(&ds, 4, 1);
    }
}
