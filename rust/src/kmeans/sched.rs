//! Chunk-granular work-stealing scheduler for the multi-threaded
//! engines (DESIGN.md §9).
//!
//! The paper shards the dataset contiguously — the right decomposition
//! when every point costs the same, and exactly the wrong one once
//! triangle-inequality pruning makes per-point cost irregular: a
//! worker whose shard sits on a cluster boundary scans far more
//! centroids than one whose shard is deep inside a blob, and the
//! iteration barrier waits for the slowest. This module keeps the
//! spawn-once worker structure but makes the unit of distribution a
//! [`POINTS_BLOCK`]-aligned row chunk: each worker owns a deque of
//! chunk indices (seeded contiguously, so the static decomposition is
//! the starting layout) and, in [`SchedMode::Steal`] mode, an idle
//! worker pops from the *tail* of the fullest-looking victim.
//!
//! ## Why determinism survives stealing
//!
//! The scheduler never owns statistics. Engines key every mutable
//! per-row output (assignments, bounds) and every f64 accumulator or
//! reassignment-event list by **chunk**, not by worker; a chunk is
//! popped exactly once per round (deques are mutex-protected), its
//! results depend only on `(rows, centroids, bounds)` — never on which
//! worker ran it — and the leader folds per-chunk results in ascending
//! chunk index ([`crate::kmeans::step::merge_ordered`]'s canonical
//! order). Any steal schedule therefore produces the same bits, and
//! because the chunk grid depends only on `n` (not the worker count),
//! results are also independent of `p`.
//!
//! The **elastic distributed scheduler**
//! ([`crate::kmeans::dist::elastic`], DESIGN.md §12) keys its network
//! work units off the *same* grid — [`chunk_count`]/[`chunk_range`]
//! over the same [`CHUNK_ROWS`] — which is why its results are
//! bit-identical to `threads --sched steal` and survive chunk
//! re-dispatch, retry and speculation unchanged: the grid, and
//! therefore the fold, is a pure function of `n`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub use crate::config::SchedMode;
use crate::data::dataset::shard_ranges;
use crate::linalg::kernel::POINTS_BLOCK;

/// Rows per scheduled chunk: 16 kernel tiles. Small enough that a 4-way
/// run on the paper's 100k-row smoke workloads has ~100 steals' worth
/// of slack to balance with, large enough that deque locking is noise
/// against the O(chunk · k · d) distance work a chunk carries.
pub const CHUNK_ROWS: usize = 16 * POINTS_BLOCK;

/// Number of [`CHUNK_ROWS`]-sized chunks covering `n` rows (the last
/// chunk may be short). Depends only on `n` — the p-independence of the
/// chunk-granular engines rests on this.
pub fn chunk_count(n: usize) -> usize {
    n.div_ceil(CHUNK_ROWS).max(1)
}

/// Row range `[lo, hi)` of chunk `index` within `n` rows.
pub fn chunk_range(index: usize, n: usize) -> (usize, usize) {
    let lo = index * CHUNK_ROWS;
    (lo.min(n), ((index + 1) * CHUNK_ROWS).min(n))
}

/// Per-worker deques of chunk indices with optional tail stealing.
///
/// One fill per iteration round (the leader calls [`ChunkQueue::fill`]
/// between barriers), then workers drain via [`ChunkQueue::pop`] until
/// it returns `None`. A chunk index is handed out exactly once per
/// round.
pub struct ChunkQueue {
    deques: Vec<Mutex<VecDeque<usize>>>,
    mode: SchedMode,
    steals: AtomicU64,
}

impl ChunkQueue {
    pub fn new(workers: usize, mode: SchedMode) -> ChunkQueue {
        assert!(workers >= 1, "ChunkQueue: workers must be >= 1");
        ChunkQueue {
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            mode,
            steals: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.deques.len()
    }

    /// Distribute chunk indices `0..chunks` contiguously across the
    /// worker deques (near-equal counts — the static decomposition).
    /// Any chunks left from a previous round are discarded.
    pub fn fill(&self, chunks: usize) {
        for (w, (lo, hi)) in shard_ranges(chunks, self.deques.len()).into_iter().enumerate() {
            let mut dq = self.deques[w].lock().unwrap();
            dq.clear();
            dq.extend(lo..hi);
        }
    }

    /// Next chunk for worker `wid`: front of its own deque, else (in
    /// [`SchedMode::Steal`] mode) the tail of the first non-empty
    /// victim, scanning round-robin from `wid + 1`. `None` once every
    /// deque is empty — the worker's signal to park at the barrier.
    pub fn pop(&self, wid: usize) -> Option<usize> {
        if let Some(c) = self.deques[wid].lock().unwrap().pop_front() {
            return Some(c);
        }
        if self.mode == SchedMode::Static {
            return None;
        }
        let p = self.deques.len();
        for off in 1..p {
            let victim = (wid + off) % p;
            if let Some(c) = self.deques[victim].lock().unwrap().pop_back() {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(c);
            }
        }
        None
    }

    /// Total successful steals since construction (telemetry for the
    /// bench harness; results never depend on it).
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn chunk_grid_covers_exactly() {
        for n in [1usize, 63, 64, 1023, 1024, 1025, 100_003] {
            let chunks = chunk_count(n);
            let mut covered = 0usize;
            for i in 0..chunks {
                let (lo, hi) = chunk_range(i, n);
                assert_eq!(lo, covered, "n={n} chunk {i}");
                assert!(hi > lo, "n={n} chunk {i} empty");
                assert!(lo % CHUNK_ROWS == 0);
                covered = hi;
            }
            assert_eq!(covered, n, "n={n}");
        }
        assert_eq!(chunk_count(0), 1); // degenerate grid still drains
    }

    #[test]
    fn every_chunk_handed_out_exactly_once_static_and_steal() {
        for mode in [SchedMode::Static, SchedMode::Steal] {
            for workers in [1usize, 2, 3, 8] {
                let q = ChunkQueue::new(workers, mode);
                q.fill(37);
                let mut seen = BTreeSet::new();
                // single-threaded drain through every worker id round-
                // robin exercises both own-pop and (steal mode) theft
                'outer: loop {
                    let mut any = false;
                    for w in 0..workers {
                        if let Some(c) = q.pop(w) {
                            assert!(seen.insert(c), "{mode} w{w}: chunk {c} twice");
                            any = true;
                        }
                    }
                    if !any {
                        break 'outer;
                    }
                }
                assert_eq!(seen.len(), 37, "{mode} p={workers}");
                assert_eq!(seen.iter().next_back(), Some(&36));
            }
        }
    }

    #[test]
    fn static_mode_never_steals() {
        let q = ChunkQueue::new(4, SchedMode::Static);
        q.fill(16);
        // worker 3 drains its own 4 chunks, then gets nothing even
        // though other deques are full
        for _ in 0..4 {
            assert!(q.pop(3).is_some());
        }
        assert_eq!(q.pop(3), None);
        assert_eq!(q.steals(), 0);
        // the others still own their chunks
        assert!(q.pop(0).is_some());
    }

    #[test]
    fn steal_mode_balances_from_the_tail() {
        let q = ChunkQueue::new(2, SchedMode::Steal);
        q.fill(8); // worker 0 owns 0..4, worker 1 owns 4..8
        // worker 0 drains its own front-to-back
        assert_eq!(q.pop(0), Some(0));
        // exhaust own, then steal from worker 1's tail
        assert_eq!(q.pop(0), Some(1));
        assert_eq!(q.pop(0), Some(2));
        assert_eq!(q.pop(0), Some(3));
        assert_eq!(q.pop(0), Some(7), "steal takes the victim's tail");
        assert_eq!(q.pop(1), Some(4), "victim keeps its front");
        assert!(q.steals() >= 1);
    }

    #[test]
    fn concurrent_drain_is_exactly_once() {
        // hammer the queue from real threads: every chunk exactly once
        let q = ChunkQueue::new(4, SchedMode::Steal);
        q.fill(1000);
        let got: Vec<Mutex<Vec<usize>>> = (0..4).map(|_| Mutex::new(Vec::new())).collect();
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let got = &got;
                s.spawn(move || {
                    while let Some(c) = q.pop(w) {
                        got[w].lock().unwrap().push(c);
                    }
                });
            }
        });
        let mut all: Vec<usize> = got.iter().flat_map(|g| g.lock().unwrap().clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn refill_discards_leftovers() {
        let q = ChunkQueue::new(2, SchedMode::Steal);
        q.fill(10);
        let _ = q.pop(0);
        q.fill(3);
        let mut seen = BTreeSet::new();
        while let Some(c) = q.pop(0) {
            seen.insert(c);
        }
        assert_eq!(seen, (0..3).collect::<BTreeSet<_>>());
    }
}
