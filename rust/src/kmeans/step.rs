//! The Lloyd-iteration primitives shared by every pure-rust engine —
//! and the L3 performance hot path (EXPERIMENTS.md §Perf).
//!
//! [`assign_accumulate`] fuses the reassignment step with local
//! statistic accumulation (one pass over the rows), exactly the loop
//! each of the paper's OpenMP threads runs on its shard. The inner loop
//! is monomorphized per dimension (`D = 2, 3`) so the distance
//! computation fully unrolls; other dims fall back to a generic loop.
//! Sums accumulate in f64: at N = 1M, f32 accumulation loses enough
//! precision to perturb centroids between engines.

use crate::data::Dataset;

/// Per-shard accumulation buffers (one per thread — the paper's "local
/// cluster means" — merged by the leader).
#[derive(Debug, Clone)]
pub struct PartialStats {
    pub k: usize,
    pub dim: usize,
    /// k×d running sums (f64 — see module docs).
    pub sums: Vec<f64>,
    pub counts: Vec<u64>,
    pub sse: f64,
}

impl PartialStats {
    pub fn zeros(k: usize, dim: usize) -> PartialStats {
        PartialStats { k, dim, sums: vec![0.0; k * dim], counts: vec![0; k], sse: 0.0 }
    }

    pub fn reset(&mut self) {
        self.sums.iter_mut().for_each(|v| *v = 0.0);
        self.counts.iter_mut().for_each(|v| *v = 0);
        self.sse = 0.0;
    }

    /// Merge another shard's stats into this one (the paper's critical
    /// section; in rust the leader owns the merge so no lock is needed).
    pub fn merge(&mut self, other: &PartialStats) {
        debug_assert_eq!(self.k, other.k);
        debug_assert_eq!(self.dim, other.dim);
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sse += other.sse;
    }
}

/// Assign every row in `rows` (row-major, `dim` wide) to its nearest
/// centroid, writing assignments into `assign_out` and accumulating
/// sums/counts/SSE into `stats` (which is reset first).
///
/// `row_offset` is the global index of `rows[0]` — only used to address
/// `assign_out`, which is the *global* assignment buffer.
pub fn assign_accumulate(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) {
    debug_assert_eq!(rows.len() % dim, 0);
    debug_assert_eq!(centroids.len(), k * dim);
    debug_assert_eq!(assign_out.len() * dim, rows.len());
    stats.reset();
    match dim {
        2 => assign_rows::<2>(rows, centroids, k, assign_out, stats),
        3 => assign_rows::<3>(rows, centroids, k, assign_out, stats),
        _ => assign_rows_generic(rows, dim, centroids, k, assign_out, stats),
    }
}

/// Monomorphized hot loop: D known at compile time, distance unrolled.
fn assign_rows<const D: usize>(
    rows: &[f32],
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) {
    let n = rows.len() / D;
    for i in 0..n {
        let p: &[f32; D] = rows[i * D..(i + 1) * D].try_into().unwrap();
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let mu = &centroids[c * D..(c + 1) * D];
            let mut d2 = 0.0f32;
            for j in 0..D {
                let diff = p[j] - mu[j];
                d2 += diff * diff;
            }
            if d2 < best_d {
                best_d = d2;
                best = c;
            }
        }
        assign_out[i] = best as i32;
        stats.counts[best] += 1;
        stats.sse += best_d as f64;
        let s = &mut stats.sums[best * D..(best + 1) * D];
        for j in 0..D {
            s[j] += p[j] as f64;
        }
    }
}

fn assign_rows_generic(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) {
    let n = rows.len() / dim;
    for i in 0..n {
        let p = &rows[i * dim..(i + 1) * dim];
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let d2 = crate::linalg::sqdist(p, &centroids[c * dim..(c + 1) * dim]);
            if d2 < best_d {
                best_d = d2;
                best = c;
            }
        }
        assign_out[i] = best as i32;
        stats.counts[best] += 1;
        stats.sse += best_d as f64;
        crate::linalg::add_assign(&mut stats.sums[best * dim..(best + 1) * dim], p);
    }
}

/// Mean-recomputation + convergence error: consumes merged stats,
/// produces new centroids and E = Σ‖μ_new − μ_old‖². Empty clusters
/// keep their previous centroid (see python `model.make_finalize`).
pub fn finalize(stats: &PartialStats, centroids_old: &[f32]) -> (Vec<f32>, f64) {
    let (k, d) = (stats.k, stats.dim);
    debug_assert_eq!(centroids_old.len(), k * d);
    let mut mu_new = vec![0.0f32; k * d];
    let mut shift = 0.0f64;
    for c in 0..k {
        let cnt = stats.counts[c];
        for j in 0..d {
            let idx = c * d + j;
            let v = if cnt > 0 {
                (stats.sums[idx] / cnt as f64) as f32
            } else {
                centroids_old[idx]
            };
            mu_new[idx] = v;
            let diff = (v - centroids_old[idx]) as f64;
            shift += diff * diff;
        }
    }
    (mu_new, shift)
}

/// Single-threaded full Lloyd iteration over a dataset (assignment +
/// accumulate + finalize). Returns (new_centroids, shift, sse).
pub fn lloyd_iteration(
    ds: &Dataset,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) -> (Vec<f32>, f64, f64) {
    assign_accumulate(ds.raw(), ds.dim(), centroids, k, assign_out, stats);
    let (mu_new, shift) = finalize(stats, centroids);
    (mu_new, shift, stats.sse)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::testutil::prop;

    fn toy() -> (Dataset, Vec<f32>) {
        // two obvious clusters on the x axis
        let ds = Dataset::from_vec(
            vec![0.0, 0.0, 0.2, 0.0, 10.0, 0.0, 10.2, 0.0],
            2,
        )
        .unwrap();
        let centroids = vec![0.0, 0.0, 10.0, 0.0];
        (ds, centroids)
    }

    #[test]
    fn assigns_to_nearest() {
        let (ds, mu) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats);
        assert_eq!(assign, vec![0, 0, 1, 1]);
        assert_eq!(stats.counts, vec![2, 2]);
        assert!((stats.sums[0] - 0.2).abs() < 1e-6);
        assert!((stats.sums[2] - 20.2).abs() < 1e-5);
        assert!((stats.sse - 0.08).abs() < 1e-5);
    }

    #[test]
    fn finalize_means_and_shift() {
        let (ds, mu) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats);
        let (mu_new, shift) = finalize(&stats, &mu);
        assert!((mu_new[0] - 0.1).abs() < 1e-6);
        assert!((mu_new[2] - 10.1).abs() < 1e-5);
        // shift = 2 * 0.1^2
        assert!((shift - 0.02).abs() < 1e-5);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let ds = Dataset::from_vec(vec![0.0, 0.0], 2).unwrap();
        let mu = vec![0.0, 0.0, 99.0, 99.0];
        let mut assign = vec![0i32; 1];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats);
        let (mu_new, _) = finalize(&stats, &mu);
        assert_eq!(&mu_new[2..4], &[99.0, 99.0]);
    }

    #[test]
    fn merge_is_sum() {
        let mut a = PartialStats::zeros(2, 2);
        a.sums = vec![1.0, 2.0, 3.0, 4.0];
        a.counts = vec![1, 2];
        a.sse = 0.5;
        let mut b = PartialStats::zeros(2, 2);
        b.sums = vec![10.0, 20.0, 30.0, 40.0];
        b.counts = vec![3, 4];
        b.sse = 1.5;
        a.merge(&b);
        assert_eq!(a.sums, vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(a.counts, vec![4, 6]);
        assert_eq!(a.sse, 2.0);
    }

    #[test]
    fn specialized_matches_generic() {
        // property: the D=2/3 monomorphized loops agree with the
        // generic loop on identical inputs
        prop::check("specialized == generic", 32, |g| {
            let d = *g.choice(&[2usize, 3]);
            let n = g.usize_in(1, 200);
            let k = g.usize_in(1, 12);
            let rows = g.points(n, d, 10.0);
            let mu = g.points(k, d, 10.0);
            let mut a1 = vec![0i32; n];
            let mut a2 = vec![0i32; n];
            let mut s1 = PartialStats::zeros(k, d);
            let mut s2 = PartialStats::zeros(k, d);
            match d {
                2 => assign_rows::<2>(&rows, &mu, k, &mut a1, &mut s1),
                3 => assign_rows::<3>(&rows, &mu, k, &mut a1, &mut s1),
                _ => unreachable!(),
            }
            assign_rows_generic(&rows, d, &mu, k, &mut a2, &mut s2);
            prop::ensure(a1 == a2, "assignments differ")?;
            prop::ensure(s1.counts == s2.counts, "counts differ")?;
            let close = s1
                .sums
                .iter()
                .zip(&s2.sums)
                .all(|(x, y)| (x - y).abs() < 1e-9);
            prop::ensure(close, "sums differ")?;
            prop::ensure((s1.sse - s2.sse).abs() < 1e-6, "sse differs")
        });
    }

    #[test]
    fn stats_invariants_property() {
        // counts sum to n; sums-of-sums equals the column sums of data
        prop::check("partition invariants", 32, |g| {
            let d = *g.choice(&[2usize, 3]);
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 8);
            let rows = g.points(n, d, 5.0);
            let mu = g.points(k, d, 5.0);
            let mut assign = vec![0i32; n];
            let mut stats = PartialStats::zeros(k, d);
            assign_accumulate(&rows, d, &mu, k, &mut assign, &mut stats);
            let total: u64 = stats.counts.iter().sum();
            prop::ensure(total == n as u64, format!("counts {total} != n {n}"))?;
            for j in 0..d {
                let col: f64 = (0..n).map(|i| rows[i * d + j] as f64).sum();
                let via: f64 = (0..k).map(|c| stats.sums[c * d + j]).sum();
                prop::ensure((col - via).abs() < 1e-6 * n as f64 + 1e-9, "column sum mismatch")?;
            }
            prop::ensure(assign.iter().all(|&a| (a as usize) < k), "assignment out of range")
        });
    }

    #[test]
    fn lloyd_iteration_reduces_sse() {
        // Lloyd invariant: SSE non-increasing across iterations
        let mut g = prop::Gen::new(77);
        let n = 400;
        let d = 2;
        let k = 5;
        let data = g.points(n, d, 10.0);
        let ds = Dataset::from_vec(data, d).unwrap();
        let mut mu: Vec<f32> = ds.rows(0, k).to_vec();
        let mut assign = vec![0i32; n];
        let mut stats = PartialStats::zeros(k, d);
        let mut last_sse = f64::INFINITY;
        for _ in 0..10 {
            let (mu_new, _, sse) = lloyd_iteration(&ds, &mu, k, &mut assign, &mut stats);
            assert!(sse <= last_sse * (1.0 + 1e-9), "sse increased: {sse} > {last_sse}");
            last_sse = sse;
            mu = mu_new;
        }
    }
}
