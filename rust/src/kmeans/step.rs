//! The Lloyd-iteration primitives shared by every pure-rust engine —
//! and the L3 performance hot path (EXPERIMENTS.md §Perf).
//!
//! [`assign_accumulate`] fuses the reassignment step with local
//! statistic accumulation (one pass over the rows), exactly the loop
//! each of the paper's OpenMP threads runs on its shard. Since the
//! kernel-subsystem rework it is a thin facade over
//! [`crate::linalg::kernel`]: a blocked, SIMD-accelerated (AVX2/NEON
//! with scalar fallback) implementation selected once per process —
//! every engine, pure-rust or coordinator-driven, shares that one hot
//! path. Sums accumulate in f64: at N = 1M, f32 accumulation loses
//! enough precision to perturb centroids between engines.

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::kernel;

/// Per-shard accumulation buffers (one per thread — the paper's "local
/// cluster means" — merged by the leader).
#[derive(Debug, Clone)]
pub struct PartialStats {
    pub k: usize,
    pub dim: usize,
    /// k×d running sums (f64 — see module docs).
    pub sums: Vec<f64>,
    pub counts: Vec<u64>,
    pub sse: f64,
}

impl PartialStats {
    pub fn zeros(k: usize, dim: usize) -> PartialStats {
        PartialStats { k, dim, sums: vec![0.0; k * dim], counts: vec![0; k], sse: 0.0 }
    }

    pub fn reset(&mut self) {
        self.sums.iter_mut().for_each(|v| *v = 0.0);
        self.counts.iter_mut().for_each(|v| *v = 0);
        self.sse = 0.0;
    }

    /// Merge another shard's stats into this one (the paper's critical
    /// section; in rust the leader owns the merge so no lock is needed).
    pub fn merge(&mut self, other: &PartialStats) {
        debug_assert_eq!(self.k, other.k);
        debug_assert_eq!(self.dim, other.dim);
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sse += other.sse;
    }
}

/// Assign every row in `rows` (row-major, `dim` wide) to its nearest
/// centroid, writing assignments into `assign_out` and accumulating
/// sums/counts/SSE into `stats` (which is reset first).
///
/// Thin facade over [`crate::linalg::kernel::assign_accumulate`] on the
/// process-global tier ([`crate::linalg::kernel::active_tier`]).
///
/// Errors with [`Error::Config`] when `k == 0` (there is no nearest
/// centroid to index) and [`Error::Shape`] on dimension mismatches.
pub fn assign_accumulate(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) -> Result<()> {
    if k == 0 {
        return Err(Error::Config("assign_accumulate: k must be >= 1".into()));
    }
    if dim == 0 || rows.len() % dim != 0 {
        return Err(Error::Shape(format!(
            "assign_accumulate: rows len {} not divisible by dim {dim}",
            rows.len()
        )));
    }
    if centroids.len() != k * dim {
        return Err(Error::Shape(format!(
            "assign_accumulate: centroids len {} != k {k} × dim {dim}",
            centroids.len()
        )));
    }
    if assign_out.len() * dim != rows.len() {
        return Err(Error::Shape(format!(
            "assign_accumulate: assign buffer {} != rows {}",
            assign_out.len(),
            rows.len() / dim
        )));
    }
    if stats.k != k || stats.dim != dim {
        return Err(Error::Shape(format!(
            "assign_accumulate: stats shaped {}×{}, expected {k}×{dim}",
            stats.k, stats.dim
        )));
    }
    stats.reset();
    kernel::assign_accumulate(
        rows,
        dim,
        centroids,
        k,
        assign_out,
        &mut stats.sums,
        &mut stats.counts,
        &mut stats.sse,
        kernel::active_tier(),
    );
    Ok(())
}

/// Mean-recomputation + convergence error: consumes merged stats,
/// produces new centroids and E = Σ‖μ_new − μ_old‖². Empty clusters
/// keep their previous centroid (see python `model.make_finalize`).
pub fn finalize(stats: &PartialStats, centroids_old: &[f32]) -> (Vec<f32>, f64) {
    let (k, d) = (stats.k, stats.dim);
    debug_assert_eq!(centroids_old.len(), k * d);
    let mut mu_new = vec![0.0f32; k * d];
    let mut shift = 0.0f64;
    for c in 0..k {
        let cnt = stats.counts[c];
        for j in 0..d {
            let idx = c * d + j;
            let v = if cnt > 0 {
                (stats.sums[idx] / cnt as f64) as f32
            } else {
                centroids_old[idx]
            };
            mu_new[idx] = v;
            let diff = (v - centroids_old[idx]) as f64;
            shift += diff * diff;
        }
    }
    (mu_new, shift)
}

/// Single-threaded full Lloyd iteration over a dataset (assignment +
/// accumulate + finalize). Returns (new_centroids, shift, sse).
pub fn lloyd_iteration(
    ds: &Dataset,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) -> Result<(Vec<f32>, f64, f64)> {
    assign_accumulate(ds.raw(), ds.dim(), centroids, k, assign_out, stats)?;
    let (mu_new, shift) = finalize(stats, centroids);
    Ok((mu_new, shift, stats.sse))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::testutil::prop;

    fn toy() -> (Dataset, Vec<f32>) {
        // two obvious clusters on the x axis
        let ds = Dataset::from_vec(
            vec![0.0, 0.0, 0.2, 0.0, 10.0, 0.0, 10.2, 0.0],
            2,
        )
        .unwrap();
        let centroids = vec![0.0, 0.0, 10.0, 0.0];
        (ds, centroids)
    }

    #[test]
    fn assigns_to_nearest() {
        let (ds, mu) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats).unwrap();
        assert_eq!(assign, vec![0, 0, 1, 1]);
        assert_eq!(stats.counts, vec![2, 2]);
        assert!((stats.sums[0] - 0.2).abs() < 1e-6);
        assert!((stats.sums[2] - 20.2).abs() < 1e-5);
        assert!((stats.sse - 0.08).abs() < 1e-5);
    }

    #[test]
    fn zero_k_is_config_error_not_panic() {
        let (ds, _) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(0, 2);
        let err = assign_accumulate(ds.raw(), 2, &[], 0, &mut assign, &mut stats).unwrap_err();
        assert!(matches!(err, crate::Error::Config(_)), "{err}");
    }

    #[test]
    fn shape_mismatches_are_errors() {
        let (ds, mu) = toy();
        let mut stats = PartialStats::zeros(2, 2);
        // short assignment buffer
        let mut short = vec![0i32; 3];
        assert!(assign_accumulate(ds.raw(), 2, &mu, 2, &mut short, &mut stats).is_err());
        // wrong centroid length
        let mut assign = vec![0i32; 4];
        assert!(assign_accumulate(ds.raw(), 2, &mu[..3], 2, &mut assign, &mut stats).is_err());
        // mismatched stats buffer (must error in release builds too)
        let mut wrong = PartialStats::zeros(1, 2);
        assert!(assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut wrong).is_err());
    }

    #[test]
    fn finalize_means_and_shift() {
        let (ds, mu) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats).unwrap();
        let (mu_new, shift) = finalize(&stats, &mu);
        assert!((mu_new[0] - 0.1).abs() < 1e-6);
        assert!((mu_new[2] - 10.1).abs() < 1e-5);
        // shift = 2 * 0.1^2
        assert!((shift - 0.02).abs() < 1e-5);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let ds = Dataset::from_vec(vec![0.0, 0.0], 2).unwrap();
        let mu = vec![0.0, 0.0, 99.0, 99.0];
        let mut assign = vec![0i32; 1];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats).unwrap();
        let (mu_new, _) = finalize(&stats, &mu);
        assert_eq!(&mu_new[2..4], &[99.0, 99.0]);
    }

    #[test]
    fn merge_is_sum() {
        let mut a = PartialStats::zeros(2, 2);
        a.sums = vec![1.0, 2.0, 3.0, 4.0];
        a.counts = vec![1, 2];
        a.sse = 0.5;
        let mut b = PartialStats::zeros(2, 2);
        b.sums = vec![10.0, 20.0, 30.0, 40.0];
        b.counts = vec![3, 4];
        b.sse = 1.5;
        a.merge(&b);
        assert_eq!(a.sums, vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(a.counts, vec![4, 6]);
        assert_eq!(a.sse, 2.0);
    }

    #[test]
    fn facade_matches_reference_scan() {
        // the facade (whatever tier is active) must agree with a plain
        // per-point nearest-centroid scan
        prop::check("facade == reference", 32, |g| {
            let d = *g.choice(&[2usize, 3, 7]);
            let n = g.usize_in(1, 200);
            let k = g.usize_in(1, 12);
            let rows = g.points(n, d, 10.0);
            let mu = g.points(k, d, 10.0);
            let mut assign = vec![0i32; n];
            let mut stats = PartialStats::zeros(k, d);
            assign_accumulate(&rows, d, &mu, k, &mut assign, &mut stats).unwrap();
            for i in 0..n {
                let p = &rows[i * d..(i + 1) * d];
                let mut best = 0i32;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let dist = crate::linalg::sqdist(p, &mu[c * d..(c + 1) * d]);
                    if dist < best_d {
                        best_d = dist;
                        best = c as i32;
                    }
                }
                prop::ensure(assign[i] == best, format!("point {i} misassigned"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn stats_invariants_property() {
        // counts sum to n; sums-of-sums equals the column sums of data
        prop::check("partition invariants", 32, |g| {
            let d = *g.choice(&[2usize, 3, 17]);
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 8);
            let rows = g.points(n, d, 5.0);
            let mu = g.points(k, d, 5.0);
            let mut assign = vec![0i32; n];
            let mut stats = PartialStats::zeros(k, d);
            assign_accumulate(&rows, d, &mu, k, &mut assign, &mut stats).unwrap();
            let total: u64 = stats.counts.iter().sum();
            prop::ensure(total == n as u64, format!("counts {total} != n {n}"))?;
            for j in 0..d {
                let col: f64 = (0..n).map(|i| rows[i * d + j] as f64).sum();
                let via: f64 = (0..k).map(|c| stats.sums[c * d + j]).sum();
                prop::ensure((col - via).abs() < 1e-6 * n as f64 + 1e-9, "column sum mismatch")?;
            }
            prop::ensure(assign.iter().all(|&a| (a as usize) < k), "assignment out of range")
        });
    }

    #[test]
    fn lloyd_iteration_reduces_sse() {
        // Lloyd invariant: SSE non-increasing across iterations
        let mut g = prop::Gen::new(77);
        let n = 400;
        let d = 2;
        let k = 5;
        let data = g.points(n, d, 10.0);
        let ds = Dataset::from_vec(data, d).unwrap();
        let mut mu: Vec<f32> = ds.rows(0, k).to_vec();
        let mut assign = vec![0i32; n];
        let mut stats = PartialStats::zeros(k, d);
        let mut last_sse = f64::INFINITY;
        for _ in 0..10 {
            let (mu_new, _, sse) = lloyd_iteration(&ds, &mu, k, &mut assign, &mut stats).unwrap();
            assert!(sse <= last_sse * (1.0 + 1e-9), "sse increased: {sse} > {last_sse}");
            last_sse = sse;
            mu = mu_new;
        }
    }
}
