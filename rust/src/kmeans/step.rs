//! The Lloyd-iteration primitives shared by every pure-rust engine —
//! and the L3 performance hot path (EXPERIMENTS.md §Perf).
//!
//! [`assign_accumulate`] fuses the reassignment step with local
//! statistic accumulation (one pass over the rows), exactly the loop
//! each of the paper's OpenMP threads runs on its shard. Since the
//! kernel-subsystem rework it is a thin facade over
//! [`crate::linalg::kernel`]: a blocked, SIMD-accelerated (AVX2/NEON
//! with scalar fallback) implementation selected once per process —
//! every engine, pure-rust or coordinator-driven, shares that one hot
//! path. Sums accumulate in f64: at N = 1M, f32 accumulation loses
//! enough precision to perturb centroids between engines.
//!
//! ## The chunked-accumulation contract (DESIGN.md §4)
//!
//! The kernel folds sums/counts/SSE in strict ascending-row order and
//! *continues* from whatever values its accumulators hold — resetting
//! is the caller's job. Two facades expose that split:
//!
//! - [`assign_accumulate`] resets `stats` first (whole-buffer call);
//! - [`assign_accumulate_into`] does not — streaming a shard's chunks
//!   through it in ascending row order replays the exact `+=` chain a
//!   single whole-shard call would execute, so **per-shard partials
//!   are bit-identical for every chunk size** (including "one chunk =
//!   the whole shard").
//!
//! Per-shard partials then combine through [`merge_ordered`] — the
//! zeros-seeded ascending-shard fold (the threaded engine's historical
//! order), independent of worker timing. Consequently results depend
//! only on the shard *count*, never on chunk size, memory budget or
//! scheduling; one shard reproduces the serial engine bit-for-bit;
//! and the threaded and out-of-core engines coincide bit-for-bit at
//! equal shard counts. The tests here and
//! `rust/tests/integration_streaming.rs` pin each guarantee.

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::kernel;
use crate::linalg::kernel::DistancePolicy;

/// Distance formulation plus the norm caches it needs — the facade-
/// level view of [`DistancePolicy`] (DESIGN.md §11). `Dot` cannot be
/// requested without its norms by construction: `x_norms[i] = ‖rowᵢ‖²`
/// aligned with the `rows` slice (cached once per dataset/chunk), and
/// `c_norms[c] = ‖μ_c‖²` (recomputed once per iteration).
#[derive(Debug, Clone, Copy)]
pub enum DistanceMode<'a> {
    /// Subtract-square reference — every bit-identity contract.
    Exact,
    /// Norm-trick FMA path over caller-cached norms.
    Dot { x_norms: &'a [f32], c_norms: &'a [f32] },
}

impl DistanceMode<'_> {
    pub fn policy(&self) -> DistancePolicy {
        match self {
            DistanceMode::Exact => DistancePolicy::Exact,
            DistanceMode::Dot { .. } => DistancePolicy::Dot,
        }
    }
}

/// Per-shard accumulation buffers (one per thread — the paper's "local
/// cluster means" — merged by the leader).
#[derive(Debug, Clone)]
pub struct PartialStats {
    pub k: usize,
    pub dim: usize,
    /// k×d running sums (f64 — see module docs).
    pub sums: Vec<f64>,
    pub counts: Vec<u64>,
    pub sse: f64,
}

impl PartialStats {
    pub fn zeros(k: usize, dim: usize) -> PartialStats {
        PartialStats { k, dim, sums: vec![0.0; k * dim], counts: vec![0; k], sse: 0.0 }
    }

    pub fn reset(&mut self) {
        self.sums.iter_mut().for_each(|v| *v = 0.0);
        self.counts.iter_mut().for_each(|v| *v = 0);
        self.sse = 0.0;
    }

    /// Overwrite with another stats set of the same shape, reusing
    /// this one's buffers (workers publishing into their slot each
    /// iteration — no per-iteration allocation).
    pub fn copy_from(&mut self, other: &PartialStats) {
        debug_assert_eq!(self.k, other.k);
        debug_assert_eq!(self.dim, other.dim);
        self.sums.copy_from_slice(&other.sums);
        self.counts.copy_from_slice(&other.counts);
        self.sse = other.sse;
    }

    /// Merge another shard's stats into this one (the paper's critical
    /// section; in rust the leader owns the merge so no lock is needed).
    pub fn merge(&mut self, other: &PartialStats) {
        debug_assert_eq!(self.k, other.k);
        debug_assert_eq!(self.dim, other.dim);
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sse += other.sse;
    }
}

/// Assign every row in `rows` (row-major, `dim` wide) to its nearest
/// centroid, writing assignments into `assign_out` and accumulating
/// sums/counts/SSE into `stats` (which is reset first).
///
/// Thin facade over [`crate::linalg::kernel::assign_accumulate`] on the
/// process-global tier ([`crate::linalg::kernel::active_tier`]).
///
/// Errors with [`Error::Config`] when `k == 0` (there is no nearest
/// centroid to index) and [`Error::Shape`] on dimension mismatches.
pub fn assign_accumulate(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) -> Result<()> {
    stats.reset();
    assign_accumulate_into(rows, dim, centroids, k, assign_out, stats)
}

/// [`assign_accumulate`] with an explicit [`DistanceMode`] — the
/// policy-aware engine entry point (resets `stats` first).
pub fn assign_accumulate_mode(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
    mode: &DistanceMode<'_>,
) -> Result<()> {
    stats.reset();
    assign_accumulate_into_mode(rows, dim, centroids, k, assign_out, stats, mode)
}

/// [`assign_accumulate`] without the reset: accumulation *continues*
/// into `stats`. This is the chunked-accumulation entry point (module
/// docs) — streaming a shard's chunks through it in ascending row
/// order is bit-identical to one call over the whole shard, because
/// the kernel's f64 `+=` chain simply resumes. Same validation and
/// error taxonomy as [`assign_accumulate`].
pub fn assign_accumulate_into(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) -> Result<()> {
    assign_accumulate_into_mode(rows, dim, centroids, k, assign_out, stats, &DistanceMode::Exact)
}

/// [`assign_accumulate_into`] with an explicit [`DistanceMode`]. Under
/// `Dot` the same chunked-accumulation guarantee holds *within the
/// policy*: per-point distances are independent of chunk boundaries
/// and the f64 fold is the same ascending-row `+=` chain, so chunked
/// `Dot` folds are bit-identical to whole-shard `Dot` calls.
pub fn assign_accumulate_into_mode(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
    mode: &DistanceMode<'_>,
) -> Result<()> {
    if k == 0 {
        return Err(Error::Config("assign_accumulate: k must be >= 1".into()));
    }
    if dim == 0 || rows.len() % dim != 0 {
        return Err(Error::Shape(format!(
            "assign_accumulate: rows len {} not divisible by dim {dim}",
            rows.len()
        )));
    }
    if centroids.len() != k * dim {
        return Err(Error::Shape(format!(
            "assign_accumulate: centroids len {} != k {k} × dim {dim}",
            centroids.len()
        )));
    }
    if assign_out.len() * dim != rows.len() {
        return Err(Error::Shape(format!(
            "assign_accumulate: assign buffer {} != rows {}",
            assign_out.len(),
            rows.len() / dim
        )));
    }
    if stats.k != k || stats.dim != dim {
        return Err(Error::Shape(format!(
            "assign_accumulate: stats shaped {}×{}, expected {k}×{dim}",
            stats.k, stats.dim
        )));
    }
    match mode {
        DistanceMode::Exact => kernel::assign_accumulate(
            rows,
            dim,
            centroids,
            k,
            assign_out,
            &mut stats.sums,
            &mut stats.counts,
            &mut stats.sse,
            kernel::active_tier(),
        ),
        DistanceMode::Dot { x_norms, c_norms } => {
            if x_norms.len() * dim != rows.len() {
                return Err(Error::Shape(format!(
                    "assign_accumulate: x_norms len {} != rows {}",
                    x_norms.len(),
                    rows.len() / dim
                )));
            }
            if c_norms.len() != k {
                return Err(Error::Shape(format!(
                    "assign_accumulate: c_norms len {} != k {k}",
                    c_norms.len()
                )));
            }
            kernel::assign_accumulate_dot(
                rows,
                dim,
                centroids,
                k,
                x_norms,
                c_norms,
                assign_out,
                &mut stats.sums,
                &mut stats.counts,
                &mut stats.sse,
                kernel::active_tier(),
            )
        }
    }
    Ok(())
}

/// The canonical reduction over per-shard partials — the merge order
/// of the chunked-accumulation contract (module docs): a zeros-seeded
/// sequential fold in ascending shard index.
///
/// The order is a pure function of `parts.len()`, so merged f64 stats
/// are reproducible regardless of which worker finished first. This
/// is deliberately the threaded engine's historical order (preserved
/// bit-for-bit): a balanced allreduce tree would be equally
/// deterministic but would change the f64 grouping for p ≥ 4 and
/// re-roll every established threads-vs-serial result, buying nothing
/// at K·d-sized accumulators where merge depth is irrelevant.
///
/// Accepts anything that derefs to [`PartialStats`] — `&PartialStats`
/// or a `MutexGuard` — so leaders fold straight from their worker
/// slots without cloning. Panics when `parts` is empty (there is
/// nothing to merge).
pub fn merge_ordered<I>(parts: I) -> PartialStats
where
    I: IntoIterator,
    I::Item: std::ops::Deref<Target = PartialStats>,
{
    let mut it = parts.into_iter();
    let first = it.next().expect("merge_ordered: no partials");
    let mut merged = PartialStats::zeros(first.k, first.dim);
    merged.merge(&first);
    for p in it {
        merged.merge(&p);
    }
    merged
}

/// Mean-recomputation + convergence error: consumes merged stats,
/// produces new centroids and E = Σ‖μ_new − μ_old‖². Empty clusters
/// keep their previous centroid (see python `model.make_finalize`).
pub fn finalize(stats: &PartialStats, centroids_old: &[f32]) -> (Vec<f32>, f64) {
    let (mu_new, shift, _) = finalize_counted(stats, centroids_old);
    (mu_new, shift)
}

/// [`finalize`] that also reports how many clusters were empty this
/// iteration (count == 0 → centroid kept). The count feeds
/// [`crate::kmeans::KmeansResult::empty_events`] so degenerate data
/// (k > distinct points, identical points) is visible in the run
/// summary instead of silently absorbed by the keep-centroid policy.
pub fn finalize_counted(stats: &PartialStats, centroids_old: &[f32]) -> (Vec<f32>, f64, u64) {
    let (k, d) = (stats.k, stats.dim);
    debug_assert_eq!(centroids_old.len(), k * d);
    let mut mu_new = vec![0.0f32; k * d];
    let mut shift = 0.0f64;
    let mut empties = 0u64;
    for c in 0..k {
        let cnt = stats.counts[c];
        if cnt == 0 {
            empties += 1;
        }
        for j in 0..d {
            let idx = c * d + j;
            let v = if cnt > 0 {
                (stats.sums[idx] / cnt as f64) as f32
            } else {
                centroids_old[idx]
            };
            mu_new[idx] = v;
            let diff = (v - centroids_old[idx]) as f64;
            shift += diff * diff;
        }
    }
    (mu_new, shift, empties)
}

/// Single-threaded full Lloyd iteration over a dataset (assignment +
/// accumulate + finalize). Returns (new_centroids, shift, sse).
pub fn lloyd_iteration(
    ds: &Dataset,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
) -> Result<(Vec<f32>, f64, f64)> {
    lloyd_iteration_policy(ds, centroids, k, assign_out, stats, DistancePolicy::Exact)
}

/// [`lloyd_iteration`] under an explicit [`DistancePolicy`]: `Dot`
/// reads the dataset's cached point norms ([`Dataset::norms`]) and
/// recomputes the centroid norms once for this iteration.
pub fn lloyd_iteration_policy(
    ds: &Dataset,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
    policy: DistancePolicy,
) -> Result<(Vec<f32>, f64, f64)> {
    let (mu_new, shift, sse, _) =
        lloyd_iteration_policy_counted(ds, centroids, k, assign_out, stats, policy)?;
    Ok((mu_new, shift, sse))
}

/// [`lloyd_iteration_policy`] that also reports the iteration's
/// empty-cluster count (see [`finalize_counted`]). Returns
/// (new_centroids, shift, sse, empty_clusters).
pub fn lloyd_iteration_policy_counted(
    ds: &Dataset,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    stats: &mut PartialStats,
    policy: DistancePolicy,
) -> Result<(Vec<f32>, f64, f64, u64)> {
    match policy {
        DistancePolicy::Exact => {
            assign_accumulate(ds.raw(), ds.dim(), centroids, k, assign_out, stats)?;
        }
        DistancePolicy::Dot => {
            let c_norms = kernel::row_norms_vec(centroids, ds.dim());
            assign_accumulate_mode(
                ds.raw(),
                ds.dim(),
                centroids,
                k,
                assign_out,
                stats,
                &DistanceMode::Dot { x_norms: ds.norms(), c_norms: &c_norms },
            )?;
        }
    }
    let (mu_new, shift, empties) = finalize_counted(stats, centroids);
    Ok((mu_new, shift, stats.sse, empties))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::testutil::prop;

    fn toy() -> (Dataset, Vec<f32>) {
        // two obvious clusters on the x axis
        let ds = Dataset::from_vec(
            vec![0.0, 0.0, 0.2, 0.0, 10.0, 0.0, 10.2, 0.0],
            2,
        )
        .unwrap();
        let centroids = vec![0.0, 0.0, 10.0, 0.0];
        (ds, centroids)
    }

    #[test]
    fn assigns_to_nearest() {
        let (ds, mu) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats).unwrap();
        assert_eq!(assign, vec![0, 0, 1, 1]);
        assert_eq!(stats.counts, vec![2, 2]);
        assert!((stats.sums[0] - 0.2).abs() < 1e-6);
        assert!((stats.sums[2] - 20.2).abs() < 1e-5);
        assert!((stats.sse - 0.08).abs() < 1e-5);
    }

    #[test]
    fn zero_k_is_config_error_not_panic() {
        let (ds, _) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(0, 2);
        let err = assign_accumulate(ds.raw(), 2, &[], 0, &mut assign, &mut stats).unwrap_err();
        assert!(matches!(err, crate::Error::Config(_)), "{err}");
    }

    #[test]
    fn shape_mismatches_are_errors() {
        let (ds, mu) = toy();
        let mut stats = PartialStats::zeros(2, 2);
        // short assignment buffer
        let mut short = vec![0i32; 3];
        assert!(assign_accumulate(ds.raw(), 2, &mu, 2, &mut short, &mut stats).is_err());
        // wrong centroid length
        let mut assign = vec![0i32; 4];
        assert!(assign_accumulate(ds.raw(), 2, &mu[..3], 2, &mut assign, &mut stats).is_err());
        // mismatched stats buffer (must error in release builds too)
        let mut wrong = PartialStats::zeros(1, 2);
        assert!(assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut wrong).is_err());
    }

    #[test]
    fn finalize_means_and_shift() {
        let (ds, mu) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats).unwrap();
        let (mu_new, shift) = finalize(&stats, &mu);
        assert!((mu_new[0] - 0.1).abs() < 1e-6);
        assert!((mu_new[2] - 10.1).abs() < 1e-5);
        // shift = 2 * 0.1^2
        assert!((shift - 0.02).abs() < 1e-5);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let ds = Dataset::from_vec(vec![0.0, 0.0], 2).unwrap();
        let mu = vec![0.0, 0.0, 99.0, 99.0];
        let mut assign = vec![0i32; 1];
        let mut stats = PartialStats::zeros(2, 2);
        assign_accumulate(ds.raw(), 2, &mu, 2, &mut assign, &mut stats).unwrap();
        let (mu_new, _) = finalize(&stats, &mu);
        assert_eq!(&mu_new[2..4], &[99.0, 99.0]);
        // the counted variant reports the event, bit-identically
        let (mu_counted, _, empties) = finalize_counted(&stats, &mu);
        assert_eq!(empties, 1);
        assert_eq!(
            mu_counted.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            mu_new.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn chunked_fold_is_bit_identical_to_whole_call() {
        // the contract the out-of-core engine is built on: streaming
        // chunks through assign_accumulate_into == one whole-range call,
        // bit for bit, for ANY chunk boundaries (aligned or not)
        prop::check("chunked fold == whole fold", 24, |g| {
            let d = *g.choice(&[2usize, 3, 17]);
            let n = g.usize_in(1, 500);
            let k = g.usize_in(1, 9);
            let rows = g.points(n, d, 12.0);
            let mu = g.points(k, d, 12.0);

            let mut whole_assign = vec![0i32; n];
            let mut whole = PartialStats::zeros(k, d);
            assign_accumulate(&rows, d, &mu, k, &mut whole_assign, &mut whole).unwrap();

            let chunk = g.usize_in(1, n.max(2));
            let mut part_assign = vec![0i32; n];
            let mut part = PartialStats::zeros(k, d);
            let mut lo = 0;
            while lo < n {
                let hi = (lo + chunk).min(n);
                assign_accumulate_into(
                    &rows[lo * d..hi * d],
                    d,
                    &mu,
                    k,
                    &mut part_assign[lo..hi],
                    &mut part,
                )
                .unwrap();
                lo = hi;
            }
            prop::ensure(part_assign == whole_assign, "assignments differ")?;
            prop::ensure(part.counts == whole.counts, "counts differ")?;
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            prop::ensure(bits(&part.sums) == bits(&whole.sums), "sums differ in bits")?;
            prop::ensure(part.sse.to_bits() == whole.sse.to_bits(), "sse differs in bits")
        });
    }

    #[test]
    fn dot_chunked_fold_is_bit_identical_to_whole_call() {
        // the chunked-accumulation contract holds within the dot
        // policy too: per-point distances are chunk-boundary-blind and
        // the f64 fold is the same ascending-row chain
        prop::check("dot chunked fold == whole fold", 16, |g| {
            let d = *g.choice(&[2usize, 3, 17]);
            let n = g.usize_in(1, 400);
            let k = g.usize_in(1, 7);
            let rows = g.points(n, d, 9.0);
            let mu = g.points(k, d, 9.0);
            let x_norms = crate::linalg::kernel::row_norms_vec(&rows, d);
            let c_norms = crate::linalg::kernel::row_norms_vec(&mu, d);

            let mut whole_assign = vec![0i32; n];
            let mut whole = PartialStats::zeros(k, d);
            let mode = DistanceMode::Dot { x_norms: &x_norms, c_norms: &c_norms };
            assign_accumulate_mode(&rows, d, &mu, k, &mut whole_assign, &mut whole, &mode)
                .unwrap();

            let chunk = g.usize_in(1, n.max(2));
            let mut part_assign = vec![0i32; n];
            let mut part = PartialStats::zeros(k, d);
            let mut lo = 0;
            while lo < n {
                let hi = (lo + chunk).min(n);
                let mode = DistanceMode::Dot { x_norms: &x_norms[lo..hi], c_norms: &c_norms };
                assign_accumulate_into_mode(
                    &rows[lo * d..hi * d],
                    d,
                    &mu,
                    k,
                    &mut part_assign[lo..hi],
                    &mut part,
                    &mode,
                )
                .unwrap();
                lo = hi;
            }
            prop::ensure(part_assign == whole_assign, "assignments differ")?;
            prop::ensure(part.counts == whole.counts, "counts differ")?;
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            prop::ensure(bits(&part.sums) == bits(&whole.sums), "sums differ in bits")?;
            prop::ensure(part.sse.to_bits() == whole.sse.to_bits(), "sse differs in bits")
        });
    }

    #[test]
    fn dot_mode_norm_shape_mismatches_are_errors() {
        let (ds, mu) = toy();
        let mut assign = vec![0i32; 4];
        let mut stats = PartialStats::zeros(2, 2);
        let x_norms = crate::linalg::kernel::row_norms_vec(ds.raw(), 2);
        let c_norms = crate::linalg::kernel::row_norms_vec(&mu, 2);
        // short point-norm cache
        let bad = DistanceMode::Dot { x_norms: &x_norms[..3], c_norms: &c_norms };
        let err = assign_accumulate_mode(ds.raw(), 2, &mu, 2, &mut assign, &mut stats, &bad)
            .unwrap_err();
        assert!(matches!(err, crate::Error::Shape(_)), "{err}");
        // short centroid-norm cache
        let bad = DistanceMode::Dot { x_norms: &x_norms, c_norms: &c_norms[..1] };
        let err = assign_accumulate_mode(ds.raw(), 2, &mu, 2, &mut assign, &mut stats, &bad)
            .unwrap_err();
        assert!(matches!(err, crate::Error::Shape(_)), "{err}");
        // well-shaped dot call matches the exact assignments on
        // well-separated data
        let ok = DistanceMode::Dot { x_norms: &x_norms, c_norms: &c_norms };
        assign_accumulate_mode(ds.raw(), 2, &mu, 2, &mut assign, &mut stats, &ok).unwrap();
        assert_eq!(assign, vec![0, 0, 1, 1]);
        assert_eq!(ok.policy(), crate::linalg::kernel::DistancePolicy::Dot);
    }

    fn stats_with(seed: u64, k: usize, d: usize) -> PartialStats {
        let mut g = prop::Gen::new(seed);
        let mut s = PartialStats::zeros(k, d);
        for v in s.sums.iter_mut() {
            *v = g.points(1, 1, 100.0)[0] as f64;
        }
        for c in s.counts.iter_mut() {
            *c = g.usize_in(0, 50) as u64;
        }
        s.sse = g.points(1, 1, 10.0)[0].abs() as f64;
        s
    }

    #[test]
    fn merge_ordered_is_the_zeros_seeded_left_fold() {
        // bitwise the historical leader-merge order: zeros, then each
        // shard ascending — pinned so refactors cannot re-roll
        // established threads-vs-serial results
        for p in [1usize, 2, 3, 4, 5, 8, 16] {
            let parts: Vec<PartialStats> = (0..p).map(|i| stats_with(i as u64, 2, 3)).collect();
            let mut seq = PartialStats::zeros(2, 3);
            for s in &parts {
                seq.merge(s);
            }
            let merged = merge_ordered(&parts);
            assert_eq!(merged.counts, seq.counts, "p={p}");
            assert_eq!(
                merged.sums.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                seq.sums.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "p={p}"
            );
            assert_eq!(merged.sse.to_bits(), seq.sse.to_bits(), "p={p}");
        }
    }

    #[test]
    fn merge_ordered_totals_conserved_any_p() {
        for p in [1usize, 2, 3, 4, 5, 8, 13, 16] {
            let parts: Vec<PartialStats> =
                (0..p).map(|i| stats_with(100 + i as u64, 3, 2)).collect();
            let want_counts: Vec<u64> = (0..3)
                .map(|c| parts.iter().map(|s| s.counts[c]).sum())
                .collect();
            let want_sums: Vec<f64> = (0..6)
                .map(|j| parts.iter().map(|s| s.sums[j]).sum::<f64>())
                .collect();
            let merged = merge_ordered(&parts);
            assert_eq!(merged.counts, want_counts, "p={p}");
            for (a, b) in merged.sums.iter().zip(&want_sums) {
                assert!((a - b).abs() <= 1e-9 * b.abs().max(1.0), "p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn merge_ordered_is_deterministic() {
        let mk = || -> Vec<PartialStats> { (0..7).map(|i| stats_with(7 + i, 2, 2)).collect() };
        let a = merge_ordered(&mk());
        let b = merge_ordered(&mk());
        assert_eq!(
            a.sums.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.sums.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.sse.to_bits(), b.sse.to_bits());
    }

    #[test]
    fn copy_from_overwrites_reusing_buffers() {
        let a = stats_with(1, 2, 2);
        let mut b = PartialStats::zeros(2, 2);
        let sums_ptr = b.sums.as_ptr();
        let counts_ptr = b.counts.as_ptr();
        b.copy_from(&a);
        assert_eq!(b.sums, a.sums);
        assert_eq!(b.counts, a.counts);
        assert_eq!(b.sse, a.sse);
        assert_eq!(b.sums.as_ptr(), sums_ptr);
        assert_eq!(b.counts.as_ptr(), counts_ptr);
    }

    #[test]
    fn merge_is_sum() {
        let mut a = PartialStats::zeros(2, 2);
        a.sums = vec![1.0, 2.0, 3.0, 4.0];
        a.counts = vec![1, 2];
        a.sse = 0.5;
        let mut b = PartialStats::zeros(2, 2);
        b.sums = vec![10.0, 20.0, 30.0, 40.0];
        b.counts = vec![3, 4];
        b.sse = 1.5;
        a.merge(&b);
        assert_eq!(a.sums, vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(a.counts, vec![4, 6]);
        assert_eq!(a.sse, 2.0);
    }

    #[test]
    fn facade_matches_reference_scan() {
        // the facade (whatever tier is active) must agree with a plain
        // per-point nearest-centroid scan
        prop::check("facade == reference", 32, |g| {
            let d = *g.choice(&[2usize, 3, 7]);
            let n = g.usize_in(1, 200);
            let k = g.usize_in(1, 12);
            let rows = g.points(n, d, 10.0);
            let mu = g.points(k, d, 10.0);
            let mut assign = vec![0i32; n];
            let mut stats = PartialStats::zeros(k, d);
            assign_accumulate(&rows, d, &mu, k, &mut assign, &mut stats).unwrap();
            for i in 0..n {
                let p = &rows[i * d..(i + 1) * d];
                let mut best = 0i32;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let dist = crate::linalg::sqdist(p, &mu[c * d..(c + 1) * d]);
                    if dist < best_d {
                        best_d = dist;
                        best = c as i32;
                    }
                }
                prop::ensure(assign[i] == best, format!("point {i} misassigned"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn stats_invariants_property() {
        // counts sum to n; sums-of-sums equals the column sums of data
        prop::check("partition invariants", 32, |g| {
            let d = *g.choice(&[2usize, 3, 17]);
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 8);
            let rows = g.points(n, d, 5.0);
            let mu = g.points(k, d, 5.0);
            let mut assign = vec![0i32; n];
            let mut stats = PartialStats::zeros(k, d);
            assign_accumulate(&rows, d, &mu, k, &mut assign, &mut stats).unwrap();
            let total: u64 = stats.counts.iter().sum();
            prop::ensure(total == n as u64, format!("counts {total} != n {n}"))?;
            for j in 0..d {
                let col: f64 = (0..n).map(|i| rows[i * d + j] as f64).sum();
                let via: f64 = (0..k).map(|c| stats.sums[c * d + j]).sum();
                prop::ensure((col - via).abs() < 1e-6 * n as f64 + 1e-9, "column sum mismatch")?;
            }
            prop::ensure(assign.iter().all(|&a| (a as usize) < k), "assignment out of range")
        });
    }

    #[test]
    fn lloyd_iteration_reduces_sse() {
        // Lloyd invariant: SSE non-increasing across iterations
        let mut g = prop::Gen::new(77);
        let n = 400;
        let d = 2;
        let k = 5;
        let data = g.points(n, d, 10.0);
        let ds = Dataset::from_vec(data, d).unwrap();
        let mut mu: Vec<f32> = ds.rows(0, k).to_vec();
        let mut assign = vec![0i32; n];
        let mut stats = PartialStats::zeros(k, d);
        let mut last_sse = f64::INFINITY;
        for _ in 0..10 {
            let (mu_new, _, sse) = lloyd_iteration(&ds, &mu, k, &mut assign, &mut stats).unwrap();
            assert!(sse <= last_sse * (1.0 + 1e-9), "sse increased: {sse} > {last_sse}");
            last_sse = sse;
            mu = mu_new;
        }
    }
}
