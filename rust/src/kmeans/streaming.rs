//! Sharded out-of-core Lloyd — clustering data that never fits in RAM
//! (DESIGN.md §4), over any [`DataSource`].
//!
//! Structurally this is [`crate::kmeans::parallel`] with the resident
//! dataset replaced by chunked streams: `shards` worker threads each
//! own a contiguous row range; every iteration each worker opens a
//! fresh reader over its range and pulls `chunk_rows`-sized chunks
//! through the fused [`step::assign_accumulate_into`] facade into one
//! *continuing* per-shard f64 accumulator; at the iteration barrier
//! the leader combines shard partials with the canonical
//! [`step::merge_ordered`] fold and finalizes centroids. Resident
//! memory is `shards × chunk_rows × dim × 4` bytes of row buffers
//! (plus the `n × 4`-byte assignment output every engine returns).
//!
//! ## Determinism and bit-identity (the contract, proven by tests)
//!
//! Because the kernel folds f64 statistics in ascending row order and
//! chunked folds simply resume that chain (see
//! [`crate::kmeans::step`] module docs):
//!
//! - **chunk size and memory budget never affect results** — any
//!   `chunk_rows`, and therefore any `--memory-budget`, produces
//!   bit-identical assignments, centroids, SSE and iteration history;
//! - **one shard reproduces the serial engine bit-for-bit** — the
//!   single worker replays exactly the serial fold;
//! - **`S` shards reproduce the threaded engine at `p = S`
//!   bit-for-bit** — identical per-shard partials, identical
//!   canonical merge order.
//!
//! `rust/tests/integration_streaming.rs` pins all three on the paper's
//! 2D/3D GMM datasets with file- and generator-backed sources whose
//! memory budget is far below the dataset size.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex, RwLock};

use crate::config::{DistancePolicy, Init};
use crate::data::dataset::shard_ranges;
use crate::data::source::{ChunkReader as _, DataSource};
use crate::error::{Error, Result};
use crate::kmeans::ckpt::{self, CkptSink, CkptState, DenseSnap};
use crate::kmeans::step::{self, finalize_counted, merge_ordered, DistanceMode, PartialStats};
use crate::kmeans::{KmeansConfig, KmeansResult};
use crate::linalg::kernel;
use crate::rng::Pcg64;
use crate::util::trace;

/// Execution shape of an out-of-core run: how many shard workers, and
/// how many rows each buffers at a time. Neither affects results
/// beyond the shard count (module docs) — they trade memory for
/// parallelism and IO efficiency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamOpts {
    /// Worker thread count; the source is split into this many
    /// contiguous row ranges.
    pub shards: usize,
    /// Rows per chunk buffer each worker streams.
    pub chunk_rows: usize,
}

impl Default for StreamOpts {
    fn default() -> Self {
        StreamOpts { shards: 4, chunk_rows: StreamOpts::DEFAULT_CHUNK_ROWS }
    }
}

impl StreamOpts {
    /// Default chunk when neither `--chunk` nor `--memory-budget`
    /// constrains it (64Ki rows ≈ 768 KiB/shard at d = 3).
    pub const DEFAULT_CHUNK_ROWS: usize = 65_536;

    /// Per-row budget multiplier: the file-backed reader holds up to
    /// ~3× a chunk's payload (IO buffer + raw bytes + decoded f32
    /// rows), so a memory budget is divided by this worst case —
    /// memory and generator sources simply run further under budget.
    pub const ROW_BUDGET_FACTOR: usize = 3;

    /// Resolve the CLI surface: an explicit `chunk` (rows) wins, else a
    /// `memory_budget` (bytes, 0 = unbounded) is divided across shard
    /// buffers at the worst-case [`StreamOpts::ROW_BUDGET_FACTOR`],
    /// else the default. Errors when the two contradict or the budget
    /// cannot fit one row per shard.
    pub fn resolve(
        dim: usize,
        shards: usize,
        chunk: usize,
        memory_budget: usize,
    ) -> Result<StreamOpts> {
        if dim == 0 {
            return Err(Error::Config("streaming: dim must be >= 1".into()));
        }
        if shards == 0 {
            return Err(Error::Config("streaming: shards must be >= 1".into()));
        }
        let row_bytes = dim * 4 * StreamOpts::ROW_BUDGET_FACTOR;
        let chunk_rows = if chunk > 0 {
            // checked: a hostile --chunk must be a typed error, not an
            // overflow (same convention as io::probe_binary)
            let total = shards
                .checked_mul(chunk)
                .and_then(|v| v.checked_mul(row_bytes))
                .ok_or_else(|| {
                    Error::Config(format!(
                        "--chunk {chunk} × {shards} shards overflows a byte count"
                    ))
                })?;
            if memory_budget > 0 && total > memory_budget {
                return Err(Error::Config(format!(
                    "--chunk {chunk} × {shards} shards × {row_bytes} B/row = {total} B \
                     exceeds --memory-budget {memory_budget} B"
                )));
            }
            chunk
        } else if memory_budget > 0 {
            let per_shard = shards.checked_mul(row_bytes).ok_or_else(|| {
                Error::Config(format!("{shards} shards × {row_bytes} B/row overflows"))
            })?;
            let rows = memory_budget / per_shard;
            if rows == 0 {
                return Err(Error::Config(format!(
                    "--memory-budget {memory_budget} B too small: {shards} shards × \
                     {row_bytes} B/row needs at least {} B",
                    shards * row_bytes
                )));
            }
            rows
        } else {
            StreamOpts::DEFAULT_CHUNK_ROWS
        };
        Ok(StreamOpts { shards, chunk_rows })
    }

    /// Bytes of chunk buffers a run with these options keeps resident.
    pub fn buffer_bytes(&self, dim: usize) -> usize {
        self.shards * self.chunk_rows * dim * 4
    }

    /// Resolve from a [`crate::config::RunConfig`]: `threads` is the
    /// shard count; `chunk` and `memory_budget` feed
    /// [`StreamOpts::resolve`].
    pub fn from_run_config(cfg: &crate::config::RunConfig, dim: usize) -> Result<StreamOpts> {
        StreamOpts::resolve(dim, cfg.threads, cfg.chunk, cfg.memory_budget)
    }
}

/// Sample K distinct rows uniformly — the *same* index sequence as
/// [`crate::kmeans::init::random`] (identical RNG stream), gathered
/// from the source in one bounded-memory pass. Streaming runs
/// therefore start from the exact centroids an in-memory run with the
/// same seed starts from.
pub fn init_random(src: &dyn DataSource, k: usize, seed: u64) -> Result<Vec<f32>> {
    let n = src.len();
    if k > n {
        return Err(Error::Config(format!("init: k {k} > n {n}")));
    }
    let mut rng = Pcg64::new(seed, 0x1417);
    let idx = rng.sample_indices(n, k);
    src.gather(&idx)
}

/// Run out-of-core Lloyd on `src`, initializing per `cfg.init`.
///
/// Only [`Init::Random`] is streamable (k-means++ D² seeding needs
/// every point resident per round); requesting k-means++ is a
/// [`Error::Config`] — precompute centroids and use [`run_from`].
pub fn run(src: &dyn DataSource, cfg: &KmeansConfig, opts: &StreamOpts) -> Result<KmeansResult> {
    let centroids0 = match cfg.init {
        Init::Random => init_random(src, cfg.k, cfg.seed)?,
        Init::KmeansPlusPlus => {
            return Err(Error::Config(
                "streaming: kmeans++ init needs a resident dataset; \
                 precompute centroids (kmeans::init) and call run_from"
                    .into(),
            ))
        }
    };
    run_from(src, cfg, opts, &centroids0)
}

/// [`run`] with checkpoint/resume (DESIGN.md §14): the leader snapshots
/// dense state at each committed iteration boundary. Resume is
/// bit-identical because each streamed iteration is a pure function of
/// the centroids it starts from (the chunked-accumulation contract).
pub fn run_ckpt(
    src: &dyn DataSource,
    cfg: &KmeansConfig,
    opts: &StreamOpts,
    sink: Option<&CkptSink>,
    resume: Option<CkptState>,
) -> Result<KmeansResult> {
    match resume {
        Some(state) => {
            let c0 = state.centroids.clone();
            run_from_ckpt(src, cfg, opts, &c0, sink, Some(&state))
        }
        None => {
            let centroids0 = match cfg.init {
                Init::Random => init_random(src, cfg.k, cfg.seed)?,
                Init::KmeansPlusPlus => {
                    return Err(Error::Config(
                        "streaming: kmeans++ init needs a resident dataset; \
                         precompute centroids (kmeans::init) and call run_from"
                            .into(),
                    ))
                }
            };
            run_from_ckpt(src, cfg, opts, &centroids0, sink, None)
        }
    }
}

/// Run out-of-core Lloyd from explicit initial centroids.
pub fn run_from(
    src: &dyn DataSource,
    cfg: &KmeansConfig,
    opts: &StreamOpts,
    centroids0: &[f32],
) -> Result<KmeansResult> {
    run_from_ckpt(src, cfg, opts, centroids0, None, None)
}

/// The core loop behind every streaming entry point. On resume,
/// `centroids0` must be the snapshot's centroids; a snapshot that is
/// already terminal is finished with a single assignment-only streamed
/// pass against its `prev_centroids` (per-row pure, so chunking and
/// sharding cannot change the bits).
fn run_from_ckpt(
    src: &dyn DataSource,
    cfg: &KmeansConfig,
    opts: &StreamOpts,
    centroids0: &[f32],
    sink: Option<&CkptSink>,
    resumed: Option<&CkptState>,
) -> Result<KmeansResult> {
    let n = src.len();
    let d = src.dim();
    let k = cfg.k;
    if k == 0 {
        return Err(Error::Config("streaming: k must be >= 1".into()));
    }
    if n == 0 {
        return Err(Error::Shape(format!("streaming: empty data source ({})", src.describe())));
    }
    if d == 0 {
        return Err(Error::Shape("streaming: source dim must be >= 1".into()));
    }
    if centroids0.len() != k * d {
        return Err(Error::Shape(format!(
            "streaming: initial centroids len {} != k {k} × dim {d}",
            centroids0.len()
        )));
    }
    if opts.shards == 0 || opts.chunk_rows == 0 {
        return Err(Error::Config("streaming: shards and chunk_rows must be >= 1".into()));
    }
    // resolve the hot-path tier on the main thread so a bad
    // PARAKM_KERNEL aborts here, not inside a worker
    let _ = kernel::active_tier();
    let policy = cfg.distance;

    if let Some(state) = resumed {
        state.check_dense(k, d)?;
        if state.fingerprint.n != n as u64 {
            return Err(Error::Ckpt(format!(
                "state fingerprint n {} != source n {n}",
                state.fingerprint.n
            )));
        }
        if state.converged || state.iteration as usize >= cfg.max_iters {
            // terminal snapshot: one assignment-only streamed pass
            let mut assign = vec![-1i32; n];
            let mut stats = PartialStats::zeros(k, d);
            stream_shard(
                src,
                0,
                n,
                opts.chunk_rows,
                d,
                &state.prev_centroids,
                k,
                &mut assign,
                &mut stats,
                policy,
                None,
            )?;
            return Ok(ckpt::result_from_state(state, assign, k, d));
        }
    }

    let p = opts.shards.min(n);
    let chunk_rows = opts.chunk_rows;
    let ranges = shard_ranges(n, p);
    let mut assign = vec![-1i32; n];

    // split the global assignment buffer into per-shard &mut slices
    let mut assign_shards: Vec<&mut [i32]> = Vec::with_capacity(p);
    {
        let mut rest: &mut [i32] = &mut assign;
        for (lo, hi) in &ranges {
            let (head, tail) = rest.split_at_mut(hi - lo);
            assign_shards.push(head);
            rest = tail;
        }
    }

    let centroids = RwLock::new(centroids0.to_vec());
    let slots: Vec<Mutex<PartialStats>> =
        (0..p).map(|_| Mutex::new(PartialStats::zeros(k, d))).collect();
    let fail: Mutex<Option<Error>> = Mutex::new(None);
    let barrier = Barrier::new(p + 1); // workers + leader
    let done = AtomicBool::new(false);

    let mut history: Vec<(f64, f64)> = resumed.map(|s| s.history.clone()).unwrap_or_default();
    let mut empty_events: Vec<u64> =
        resumed.map(|s| s.empty_events.clone()).unwrap_or_default();
    let mut converged = false;
    let mut iterations = resumed.map(|s| s.iteration as usize).unwrap_or(0);
    let mut worker_err: Option<Error> = None;

    std::thread::scope(|scope| {
        // ---- workers: spawned once, one reader pass per iteration -----
        for (wid, shard) in assign_shards.into_iter().enumerate() {
            let (lo, hi) = ranges[wid];
            let centroids = &centroids;
            let slots = &slots;
            let fail = &fail;
            let barrier = &barrier;
            let done = &done;
            scope.spawn(move || {
                let mut local = PartialStats::zeros(k, d);
                loop {
                    barrier.wait(); // (A) leader published centroids/done
                    if done.load(Ordering::Acquire) {
                        break;
                    }
                    let mu = centroids.read().unwrap().clone();
                    local.reset();
                    // a fresh reader per iteration: a new pass needs a
                    // seek anyway, and the per-iteration cost (one
                    // open + O(chunk) buffer allocs per shard) is
                    // negligible against the O(n·k·d) scan it feeds
                    match stream_shard(
                        src, lo, hi, chunk_rows, d, &mu, k, shard, &mut local, policy, None,
                    ) {
                        Ok(()) => {
                            slots[wid].lock().unwrap().copy_from(&local);
                        }
                        Err(e) => {
                            let mut slot = fail.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                        }
                    }
                    barrier.wait(); // (B) stats complete
                }
            });
        }

        // ---- leader ---------------------------------------------------
        for _ in iterations..cfg.max_iters {
            {
                let _s = trace::span(trace::Phase::Assign);
                barrier.wait(); // (A)
                barrier.wait(); // (B) workers finished this iteration
            }
            if let Some(e) = fail.lock().unwrap().take() {
                worker_err = Some(e);
                break;
            }
            let merged = {
                let _s = trace::span(trace::Phase::Merge);
                merge_ordered(slots.iter().map(|s| s.lock().unwrap()))
            };
            let mu_old = centroids.read().unwrap().clone();
            let (mu_new, shift, empties) = {
                let _s = trace::span(trace::Phase::Update);
                finalize_counted(&merged, &mu_old)
            };
            *centroids.write().unwrap() = mu_new;
            iterations += 1;
            history.push((merged.sse, shift));
            empty_events.push(empties);
            let converged_now = shift < cfg.tol;
            if let Some(sink) = sink {
                let _s = trace::span(trace::Phase::Ckpt);
                let res = ckpt::save_dense(
                    sink,
                    &DenseSnap {
                        iteration: iterations,
                        converged: converged_now,
                        centroids: &centroids.read().unwrap(),
                        prev_centroids: &mu_old,
                        history: &history,
                        empty_events: &empty_events,
                    },
                );
                if let Err(e) = res {
                    worker_err = Some(e);
                    break;
                }
            }
            trace::emit_iter(iterations, merged.sse, empties, &[]);
            if converged_now {
                converged = true;
                break;
            }
        }
        done.store(true, Ordering::Release);
        barrier.wait(); // release workers into the exit branch
    });

    if let Some(e) = worker_err {
        return Err(e);
    }
    let final_centroids = centroids.into_inner().unwrap();
    let (sse, shift) = *history.last().unwrap_or(&(f64::NAN, f64::NAN));
    Ok(KmeansResult {
        centroids: final_centroids,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
        empty_events,
        pruning: None,
    })
}

/// One worker's pass: stream rows `[lo, hi)` in chunks, assigning into
/// `assign_shard` and folding statistics into the *continuing* `stats`
/// accumulator (bit-identical to a single whole-shard call — the
/// chunked-accumulation contract, which holds within either
/// [`DistancePolicy`]). Verifies the source honors its chunk tiling,
/// reporting [`Error::Data`] when it does not.
///
/// Under [`DistancePolicy::Dot`], centroid norms are computed once per
/// call (= once per iteration per shard) and point norms come from
/// `x_norms` when the caller holds a shard-wide cache (aligned with
/// `[lo, hi)` — the distributed worker's case) or are computed
/// per chunk into a reusable scratch buffer (the out-of-core engine's
/// case, where rows are re-read each pass anyway).
///
/// Shared with the distributed shard worker
/// ([`crate::cluster::worker`]): a remote shard replays exactly this
/// fold, which is what makes `dist(S) ≡ oocore(shards = S)` hold by
/// construction rather than by test luck.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_shard(
    src: &dyn DataSource,
    lo: usize,
    hi: usize,
    chunk_rows: usize,
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_shard: &mut [i32],
    stats: &mut PartialStats,
    policy: DistancePolicy,
    x_norms: Option<&[f32]>,
) -> Result<()> {
    if let (DistancePolicy::Dot, Some(cache)) = (policy, x_norms) {
        if cache.len() != hi - lo {
            return Err(Error::Shape(format!(
                "stream_shard: norm cache len {} != shard rows {}",
                cache.len(),
                hi - lo
            )));
        }
    }
    // centroid norms once per call — once per iteration per shard
    let c_norms = match policy {
        DistancePolicy::Dot => kernel::row_norms_vec(centroids, dim),
        DistancePolicy::Exact => Vec::new(),
    };
    let mut chunk_norms: Vec<f32> = Vec::new();
    let mut reader = src.reader(lo, hi, chunk_rows)?;
    let mut next = lo;
    while let Some(chunk) = reader.next_chunk()? {
        if chunk.lo != next || chunk.rows.is_empty() || chunk.rows.len() % dim != 0 {
            return Err(Error::Data(format!(
                "{}: reader broke the chunk contract at row {next} \
                 (chunk lo {}, len {})",
                src.describe(),
                chunk.lo,
                chunk.rows.len()
            )));
        }
        let nrows = chunk.rows.len() / dim;
        if next + nrows > hi {
            return Err(Error::Data(format!(
                "{}: reader overran its range: [{lo}, {hi}) got row {}",
                src.describe(),
                next + nrows
            )));
        }
        let out = &mut assign_shard[next - lo..next - lo + nrows];
        let mode = match policy {
            DistancePolicy::Exact => DistanceMode::Exact,
            DistancePolicy::Dot => {
                let xn: &[f32] = match x_norms {
                    Some(cache) => &cache[next - lo..next - lo + nrows],
                    None => {
                        // per-chunk norms into the reusable scratch
                        chunk_norms.resize(nrows, 0.0);
                        kernel::row_norms(chunk.rows, dim, &mut chunk_norms[..nrows]);
                        &chunk_norms[..nrows]
                    }
                };
                DistanceMode::Dot { x_norms: xn, c_norms: &c_norms }
            }
        };
        step::assign_accumulate_into_mode(chunk.rows, dim, centroids, k, out, stats, &mode)?;
        next += nrows;
    }
    if next != hi {
        return Err(Error::Data(format!(
            "{}: reader ended early: covered [{lo}, {next}) of [{lo}, {hi})",
            src.describe()
        )));
    }
    Ok(())
}

/// One bounded-memory pass computing the shard's per-row `‖x‖²` cache —
/// the distributed worker's per-shard norm cache
/// ([`crate::cluster::worker`] computes it once per session, then every
/// `Assign` under the `dot` policy reuses it).
pub(crate) fn shard_norms(
    src: &dyn DataSource,
    lo: usize,
    hi: usize,
    chunk_rows: usize,
    dim: usize,
) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(hi - lo);
    let mut reader = src.reader(lo, hi, chunk_rows)?;
    while let Some(chunk) = reader.next_chunk()? {
        if chunk.rows.is_empty() || chunk.rows.len() % dim != 0 {
            return Err(Error::Data(format!(
                "{}: reader broke the chunk contract while computing norms (len {})",
                src.describe(),
                chunk.rows.len()
            )));
        }
        let nrows = chunk.rows.len() / dim;
        let start = out.len();
        if start + nrows > hi - lo {
            return Err(Error::Data(format!(
                "{}: reader overran its range while computing norms",
                src.describe()
            )));
        }
        out.resize(start + nrows, 0.0);
        kernel::row_norms(chunk.rows, dim, &mut out[start..]);
    }
    if out.len() != hi - lo {
        return Err(Error::Data(format!(
            "{}: norm pass covered {} of {} shard rows",
            src.describe(),
            out.len(),
            hi - lo
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::{FileSource, GmmSource, MemorySource};
    use crate::data::{io, MixtureSpec};
    use crate::kmeans::{init, parallel, serial};
    use crate::testutil::assert_bit_identical;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parakm_streaming_engine_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn one_shard_is_bit_identical_to_serial() {
        let ds = MixtureSpec::paper_2d(8).generate(4003, 11);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let reference = serial::run_from(&ds, &cfg, &mu0);

        let src = MemorySource::new(&ds);
        for chunk in [64usize, 333, 4003, 100_000] {
            let opts = StreamOpts { shards: 1, chunk_rows: chunk };
            let run = run_from(&src, &cfg, &opts, &mu0).unwrap();
            assert_bit_identical(&run, &reference, &format!("chunk={chunk}"));
        }
    }

    #[test]
    fn s_shards_bit_identical_to_threads_p() {
        let ds = MixtureSpec::paper_3d(4).generate(3001, 7);
        let cfg = KmeansConfig::new(4).with_seed(2);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let src = MemorySource::new(&ds);
        for p in [2usize, 3, 5, 8] {
            let threads = parallel::run_from(&ds, &cfg, p, parallel::MergeMode::Leader, &mu0);
            let opts = StreamOpts { shards: p, chunk_rows: 256 };
            let run = run_from(&src, &cfg, &opts, &mu0).unwrap();
            assert_bit_identical(&run, &threads, &format!("p={p}"));
        }
    }

    #[test]
    fn chunk_size_never_changes_results() {
        let ds = MixtureSpec::paper_2d(8).generate(2500, 3);
        let cfg = KmeansConfig::new(8).with_seed(9);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let src = MemorySource::new(&ds);
        let baseline =
            run_from(&src, &cfg, &StreamOpts { shards: 3, chunk_rows: 1000 }, &mu0).unwrap();
        for chunk in [1usize, 7, 64, 2500] {
            let run =
                run_from(&src, &cfg, &StreamOpts { shards: 3, chunk_rows: chunk }, &mu0).unwrap();
            assert_bit_identical(&run, &baseline, &format!("chunk={chunk}"));
        }
    }

    #[test]
    fn file_and_generator_sources_match_memory() {
        let gmm = GmmSource::new(MixtureSpec::paper_3d(4), 2001, 13);
        let ds = gmm.materialize();
        let cfg = KmeansConfig::new(4).with_seed(4);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let opts = StreamOpts { shards: 2, chunk_rows: 128 };

        let mem = run_from(&MemorySource::new(&ds), &cfg, &opts, &mu0).unwrap();

        let p = tmp("fg.pkd");
        io::write_binary(&p, &ds).unwrap();
        let file = run_from(&FileSource::open(&p).unwrap(), &cfg, &opts, &mu0).unwrap();
        assert_bit_identical(&file, &mem, "file vs memory");

        let gen = run_from(&gmm, &cfg, &opts, &mu0).unwrap();
        assert_bit_identical(&gen, &mem, "generator vs memory");
    }

    #[test]
    fn dot_policy_preserves_the_shard_identities() {
        // within the dot policy the chunked-accumulation contract still
        // holds: oocore(S, dot) ≡ threads(p = S, dot) bit-for-bit, and
        // chunk size never changes results
        use crate::config::DistancePolicy;
        let ds = MixtureSpec::paper_3d(4).generate(3001, 7);
        let cfg = KmeansConfig::new(4).with_seed(2).with_distance(DistancePolicy::Dot);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let src = MemorySource::new(&ds);
        for p in [1usize, 3] {
            let threads = parallel::run_from(&ds, &cfg, p, parallel::MergeMode::Leader, &mu0);
            for chunk in [97usize, 4000] {
                let opts = StreamOpts { shards: p, chunk_rows: chunk };
                let run = run_from(&src, &cfg, &opts, &mu0).unwrap();
                assert_bit_identical(&run, &threads, &format!("dot p={p} chunk={chunk}"));
            }
        }
        // and the cross-policy contract vs the exact engine
        let exact_cfg = KmeansConfig::new(4).with_seed(2);
        let exact = serial::run_from(&ds, &exact_cfg, &mu0);
        let dot =
            run_from(&src, &cfg, &StreamOpts { shards: 1, chunk_rows: 256 }, &mu0).unwrap();
        assert_eq!(dot.assign, exact.assign);
        assert_eq!(dot.iterations, exact.iterations);
        assert!((dot.sse - exact.sse).abs() / exact.sse.max(1.0) < 1e-5);
    }

    #[test]
    fn shard_norms_match_dataset_cache() {
        let ds = MixtureSpec::paper_2d(4).generate(777, 3);
        let src = MemorySource::new(&ds);
        let norms = shard_norms(&src, 100, 577, 64, 2).unwrap();
        assert_eq!(norms, ds.norms_range(100, 577));
        assert!(shard_norms(&src, 0, 777, 1000, 2).unwrap().len() == 777);
    }

    #[test]
    fn init_random_matches_in_memory_init() {
        let ds = MixtureSpec::paper_2d(4).generate(1200, 6);
        let src = MemorySource::new(&ds);
        let streamed = init_random(&src, 8, 42).unwrap();
        let resident = init::random(&ds, 8, 42);
        assert_eq!(streamed, resident);
    }

    #[test]
    fn full_run_equals_serial_full_run() {
        // run() (source-side init) == serial::run (resident init):
        // identical index sampling makes the whole pipelines coincide
        let ds = MixtureSpec::paper_3d(4).generate(1500, 8);
        let cfg = KmeansConfig::new(4).with_seed(21);
        let reference = serial::run(&ds, &cfg);
        let run = run(&MemorySource::new(&ds), &cfg, &StreamOpts { shards: 1, chunk_rows: 100 })
            .unwrap();
        assert_bit_identical(&run, &reference, "run vs serial::run");
    }

    #[test]
    fn opts_resolution() {
        // explicit chunk wins
        let o = StreamOpts::resolve(3, 4, 1000, 0).unwrap();
        assert_eq!(o.chunk_rows, 1000);
        // budget divides across shards: 4 shards × 12 B/row × factor 3
        let o = StreamOpts::resolve(3, 4, 0, 144_000).unwrap();
        assert_eq!(o.chunk_rows, 1000);
        // decoded-chunk bytes stay a third of the budget (worst-case
        // file-path overhead is budgeted at ROW_BUDGET_FACTOR)
        assert_eq!(o.buffer_bytes(3) * StreamOpts::ROW_BUDGET_FACTOR, 144_000);
        // default
        let o = StreamOpts::resolve(3, 2, 0, 0).unwrap();
        assert_eq!(o.chunk_rows, StreamOpts::DEFAULT_CHUNK_ROWS);
        // contradiction, starvation and overflow are typed errors
        assert!(StreamOpts::resolve(3, 4, 1000, 100).is_err());
        assert!(StreamOpts::resolve(3, 4, 0, 100).is_err());
        assert!(StreamOpts::resolve(3, 0, 0, 0).is_err());
        let err = StreamOpts::resolve(3, 4, usize::MAX / 2, 1 << 30).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn opts_from_run_config_reads_budget() {
        use crate::config::RunConfig;
        let cfg = RunConfig { threads: 4, memory_budget: 144_000, ..Default::default() };
        let o = StreamOpts::from_run_config(&cfg, 3).unwrap();
        assert_eq!(o, StreamOpts { shards: 4, chunk_rows: 1000 });
        let cfg = RunConfig { threads: 2, chunk: 123, ..Default::default() };
        assert_eq!(StreamOpts::from_run_config(&cfg, 3).unwrap().chunk_rows, 123);
    }

    #[test]
    fn error_paths_are_typed() {
        let ds = MixtureSpec::paper_2d(4).generate(50, 1);
        let src = MemorySource::new(&ds);
        let opts = StreamOpts { shards: 2, chunk_rows: 16 };
        // k == 0
        let err = run_from(&src, &KmeansConfig::new(0), &opts, &[]).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // bad centroid shape
        let err = run_from(&src, &KmeansConfig::new(2), &opts, &[0.0; 3]).unwrap_err();
        assert!(matches!(err, Error::Shape(_)), "{err}");
        // kmeans++ init not streamable
        let cfg = KmeansConfig::new(2).with_init(Init::KmeansPlusPlus);
        let err = run(&src, &cfg, &opts).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // k > n through run()
        let err = run(&src, &KmeansConfig::new(51), &opts).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // empty source
        let empty = crate::data::Dataset::from_vec(vec![], 2).unwrap();
        let esrc = MemorySource::new(&empty);
        let err = run_from(&esrc, &KmeansConfig::new(1), &opts, &[0.0, 0.0]).unwrap_err();
        assert!(matches!(err, Error::Shape(_)), "{err}");
    }

    #[test]
    fn truncated_file_fails_cleanly_not_hangs() {
        let ds = MixtureSpec::paper_3d(4).generate(3000, 5);
        let p = tmp("engine_trunc.pkd");
        io::write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // open while intact, then truncate on disk: the mid-run IO
        // failure must surface as a typed error from run_from, with
        // every worker released (no barrier deadlock)
        let src = FileSource::open(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        let cfg = KmeansConfig::new(4).with_seed(1);
        let mu0: Vec<f32> = ds.rows(0, 4).to_vec();
        let err = run_from(&src, &cfg, &StreamOpts { shards: 3, chunk_rows: 256 }, &mu0)
            .unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
    }

    #[test]
    fn more_shards_than_rows() {
        let ds = MixtureSpec::paper_2d(4).generate(10, 1);
        let src = MemorySource::new(&ds);
        let cfg = KmeansConfig::new(2).with_seed(1);
        let r = run(&src, &cfg, &StreamOpts { shards: 64, chunk_rows: 4 }).unwrap();
        assert_eq!(r.assign.len(), 10);
        assert!(r.assign.iter().all(|&a| a >= 0));
    }
}
