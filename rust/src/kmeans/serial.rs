//! Serial Lloyd's algorithm — the paper's baseline (Table 1).
//!
//! A direct rust re-expression of the paper's serial C program:
//! iterate reassignment + mean recomputation until
//! E = Σ‖μ^{t+1} − μ^t‖² < tol (paper: 1e-6) or `max_iters`.

use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::ckpt::{self, CkptSink, CkptState, DenseSnap};
use crate::kmeans::step::{lloyd_iteration_policy_counted, PartialStats};
use crate::kmeans::{init, KmeansConfig, KmeansResult};
use crate::util::trace;

/// Run serial Lloyd on `ds`.
pub fn run(ds: &Dataset, cfg: &KmeansConfig) -> KmeansResult {
    let mut centroids = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
    run_from(ds, cfg, centroids.as_mut_slice())
}

/// Run from explicit initial centroids (used by the eval harness so
/// every engine starts from identical state).
pub fn run_from(ds: &Dataset, cfg: &KmeansConfig, centroids0: &[f32]) -> KmeansResult {
    run_from_ckpt(ds, cfg, centroids0, None, None).expect("no checkpoint io configured")
}

/// [`run`] with checkpoint/resume (DESIGN.md §14): snapshots into
/// `sink` when due, and/or continue from a loaded snapshot. Resume is
/// bit-identical to the uninterrupted run because each Lloyd iteration
/// is a pure function of the centroids it starts from.
pub fn run_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    sink: Option<&CkptSink>,
    resume: Option<CkptState>,
) -> Result<KmeansResult> {
    match resume {
        Some(state) => {
            if let Some(done) = ckpt::resume_dense(ds, cfg, &state)? {
                return Ok(done);
            }
            let c0 = state.centroids.clone();
            run_from_ckpt(ds, cfg, &c0, sink, Some(&state))
        }
        None => {
            let c0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);
            run_from_ckpt(ds, cfg, &c0, sink, None)
        }
    }
}

/// The core loop behind every serial entry point. `resumed` (if any)
/// supplies the iteration counter and telemetry already committed;
/// `centroids0` must then be that snapshot's centroids.
pub fn run_from_ckpt(
    ds: &Dataset,
    cfg: &KmeansConfig,
    centroids0: &[f32],
    sink: Option<&CkptSink>,
    resumed: Option<&CkptState>,
) -> Result<KmeansResult> {
    let k = cfg.k;
    let d = ds.dim();
    assert!(k >= 1, "k must be >= 1");
    assert_eq!(centroids0.len(), k * d, "bad initial centroids");
    let mut centroids = centroids0.to_vec();
    let mut assign = vec![-1i32; ds.len()];
    let mut stats = PartialStats::zeros(k, d);
    let (mut iterations, mut history, mut empty_events) = match resumed {
        Some(s) => (s.iteration as usize, s.history.clone(), s.empty_events.clone()),
        None => (0, Vec::new(), Vec::new()),
    };
    let mut converged = false;

    for _ in iterations..cfg.max_iters {
        let (mu_new, shift, sse, empties) = {
            let _s = trace::span(trace::Phase::Assign);
            lloyd_iteration_policy_counted(ds, &centroids, k, &mut assign, &mut stats, cfg.distance)
                .expect("shapes validated above")
        };
        let prev = std::mem::replace(&mut centroids, mu_new);
        iterations += 1;
        history.push((sse, shift));
        empty_events.push(empties);
        let converged_now = shift < cfg.tol;
        if let Some(sink) = sink {
            let _s = trace::span(trace::Phase::Ckpt);
            ckpt::save_dense(
                sink,
                &DenseSnap {
                    iteration: iterations,
                    converged: converged_now,
                    centroids: &centroids,
                    prev_centroids: &prev,
                    history: &history,
                    empty_events: &empty_events,
                },
            )?;
        }
        trace::emit_iter(iterations, sse, empties, &[]);
        if converged_now {
            converged = true;
            break;
        }
    }

    let (sse, shift) = *history.last().unwrap_or(&(f64::NAN, f64::NAN));
    Ok(KmeansResult {
        centroids,
        assign,
        k,
        dim: d,
        iterations,
        sse,
        shift,
        converged,
        history,
        empty_events,
        pruning: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Init;
    use crate::data::MixtureSpec;
    use crate::metrics;

    #[test]
    fn converges_on_separated_mixture() {
        let spec = MixtureSpec::random(2, 4, 60.0, 0.5, 1);
        let ds = spec.generate(2000, 2);
        let cfg = KmeansConfig::new(4).with_seed(3);
        let r = run(&ds, &cfg);
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        assert!(r.shift < 1e-6);
        // recovered clustering matches ground truth (well-separated)
        let ari = metrics::adjusted_rand_index(&r.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.99, "ari {ari}");
    }

    #[test]
    fn sse_monotone_nonincreasing() {
        let ds = MixtureSpec::paper_2d(8).generate(3000, 5);
        let cfg = KmeansConfig::new(8).with_seed(7);
        let r = run(&ds, &cfg);
        for w in r.history.windows(2) {
            assert!(w[1].0 <= w[0].0 * (1.0 + 1e-9), "sse increased: {w:?}");
        }
    }

    #[test]
    fn deterministic() {
        let ds = MixtureSpec::paper_3d(4).generate(1500, 6);
        let cfg = KmeansConfig::new(4).with_seed(9);
        let a = run(&ds, &cfg);
        let b = run(&ds, &cfg);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn dot_policy_matches_exact_on_paper_data() {
        // the DESIGN.md §11 cross-policy contract: identical
        // assignments and iteration trajectory, SSE within tolerance
        let ds = MixtureSpec::paper_3d(4).generate(2000, 6);
        let exact = run(&ds, &KmeansConfig::new(4).with_seed(9));
        let dot = run(
            &ds,
            &KmeansConfig::new(4)
                .with_seed(9)
                .with_distance(crate::config::DistancePolicy::Dot),
        );
        assert_eq!(dot.assign, exact.assign);
        assert_eq!(dot.iterations, exact.iterations);
        assert_eq!(dot.converged, exact.converged);
        for (a, b) in dot.centroids.iter().zip(&exact.centroids) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{a} vs {b}");
        }
        assert!((dot.sse - exact.sse).abs() / exact.sse.max(1.0) < 1e-5);
    }

    #[test]
    fn respects_max_iters() {
        let ds = MixtureSpec::paper_2d(8).generate(5000, 8);
        let cfg = KmeansConfig::new(11).with_seed(1).with_max_iters(2).with_tol(0.0);
        let r = run(&ds, &cfg);
        assert_eq!(r.iterations, 2);
        assert!(!r.converged);
        assert_eq!(r.history.len(), 2);
    }

    #[test]
    fn kpp_init_not_worse() {
        let ds = MixtureSpec::paper_2d(8).generate(4000, 11);
        let random = run(&ds, &KmeansConfig::new(8).with_seed(13));
        let kpp = run(
            &ds,
            &KmeansConfig::new(8).with_seed(13).with_init(Init::KmeansPlusPlus),
        );
        // kpp shouldn't be dramatically worse on SSE (allow slack; this
        // is a sanity check, the real comparison is the A3 ablation)
        assert!(kpp.sse <= random.sse * 1.5, "kpp {} vs random {}", kpp.sse, random.sse);
    }

    #[test]
    fn k_equals_one() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 3);
        let r = run(&ds, &KmeansConfig::new(1).with_seed(2));
        assert!(r.converged);
        assert_eq!(r.cluster_sizes(), vec![100]);
        // centroid == data mean
        let mut mean = [0.0f64; 2];
        for i in 0..100 {
            mean[0] += ds.point(i)[0] as f64;
            mean[1] += ds.point(i)[1] as f64;
        }
        assert!((r.centroids[0] as f64 - mean[0] / 100.0).abs() < 1e-4);
        assert!((r.centroids[1] as f64 - mean[1] / 100.0).abs() < 1e-4);
    }

    #[test]
    fn assignment_is_nearest_centroid_at_fixpoint() {
        let ds = MixtureSpec::paper_3d(4).generate(800, 4);
        let r = run(&ds, &KmeansConfig::new(4).with_seed(5));
        for i in 0..ds.len() {
            let a = r.assign[i] as usize;
            let da = crate::linalg::sqdist(ds.point(i), r.centroid(a));
            for c in 0..r.k {
                let dc = crate::linalg::sqdist(ds.point(i), r.centroid(c));
                assert!(da <= dc * (1.0 + 1e-5), "point {i}: {a} not nearest");
            }
        }
    }
}
