//! The K-Means algorithm family (pure rust, no XLA).
//!
//! These are the paper's baselines re-expressed in rust:
//! [`serial`] is the serial C program, [`parallel`] the OpenMP program
//! (spawn-once threads, local accumulation, critical-section merge).
//! [`elkan`]/[`hamerly`] implement the triangle-inequality acceleration
//! of the paper's reference [4]; [`minibatch`] and the out-of-core
//! [`streaming`] engine are the big-data extensions motivated in the
//! conclusion — [`streaming`] clusters any [`crate::data::DataSource`]
//! with O(shards × chunk) resident memory, bit-identical to the
//! in-memory engines (see its module docs), and [`dist`] takes the same
//! decomposition across the process boundary: a leader over TCP shard
//! workers ([`crate::cluster`]), still bit-identical. The AOT-backed
//! engines live in [`crate::coordinator`] and share these types.

pub mod bisecting;
pub mod ckpt;
pub mod dist;
pub mod elkan;
pub mod hamerly;
pub mod init;
pub mod kselect;
pub mod minibatch;
pub mod parallel;
pub mod sched;
pub mod serial;
pub mod step;
pub mod streaming;

use crate::config::{DistancePolicy, Init};

/// Configuration for the pure-rust algorithms (the AOT engines use the
/// richer [`crate::config::RunConfig`]).
#[derive(Debug, Clone)]
pub struct KmeansConfig {
    pub k: usize,
    /// Convergence tolerance on E = Σ‖μ^{t+1} − μ^t‖² (paper: 1e-6).
    pub tol: f64,
    pub max_iters: usize,
    pub seed: u64,
    pub init: Init,
    /// Distance formulation (DESIGN.md §11). `Exact` (the default)
    /// preserves every documented bit-identity contract; `Dot` runs the
    /// norm-trick FMA hot path.
    pub distance: DistancePolicy,
}

impl KmeansConfig {
    pub fn new(k: usize) -> KmeansConfig {
        KmeansConfig {
            k,
            tol: 1e-6,
            max_iters: 300,
            seed: 42,
            init: Init::Random,
            distance: DistancePolicy::Exact,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> KmeansConfig {
        self.seed = seed;
        self
    }

    pub fn with_tol(mut self, tol: f64) -> KmeansConfig {
        self.tol = tol;
        self
    }

    pub fn with_max_iters(mut self, m: usize) -> KmeansConfig {
        self.max_iters = m;
        self
    }

    pub fn with_init(mut self, init: Init) -> KmeansConfig {
        self.init = init;
        self
    }

    pub fn with_distance(mut self, distance: DistancePolicy) -> KmeansConfig {
        self.distance = distance;
        self
    }
}

/// Pruning-effectiveness counters for the triangle-inequality engines
/// ([`elkan`], [`hamerly`]): how many point–centroid distance pairs
/// each Lloyd iteration actually evaluated vs. what a dense scan
/// (`n · k`) would have cost. First-class here (not a bench-side
/// estimate) so every run can report its skip rate.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PruneStats {
    /// Distance pairs the dense seeding pass evaluated (always `n·k`).
    pub seed_computed: u64,
    /// Per Lloyd iteration `(computed, skipped)` distance pairs,
    /// aligned with [`KmeansResult::history`]. `computed + skipped`
    /// is the `n·k` dense cost; the final (convergence-detection)
    /// iteration runs no reassignment phase and records `(0, 0)`.
    pub per_iter: Vec<(u64, u64)>,
}

impl PruneStats {
    /// Total distance pairs evaluated, seeding included.
    pub fn computed(&self) -> u64 {
        self.seed_computed + self.per_iter.iter().map(|&(c, _)| c).sum::<u64>()
    }

    /// Total distance pairs pruning avoided.
    pub fn skipped(&self) -> u64 {
        self.per_iter.iter().map(|&(_, s)| s).sum::<u64>()
    }

    /// Fraction of the dense distance work that pruning skipped,
    /// seeding included: `skipped / (computed + skipped)` in `[0, 1]`.
    pub fn skip_rate(&self) -> f64 {
        let total = self.computed() + self.skipped();
        if total == 0 {
            0.0
        } else {
            self.skipped() as f64 / total as f64
        }
    }
}

/// Result of any engine: centroids (k×d row-major), hard assignments,
/// and convergence telemetry.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub centroids: Vec<f32>,
    pub assign: Vec<i32>,
    pub k: usize,
    pub dim: usize,
    /// Lloyd iterations executed.
    pub iterations: usize,
    /// Final objective Σᵢ‖xᵢ − μ_{zᵢ}‖².
    pub sse: f64,
    /// Final centroid-shift error E (the convergence quantity).
    pub shift: f64,
    /// True iff E < tol before `max_iters` ran out.
    pub converged: bool,
    /// Per-iteration (sse, shift) history for convergence tests/plots.
    pub history: Vec<(f64, f64)>,
    /// Per-iteration empty-cluster event counts, aligned with
    /// [`history`](KmeansResult::history) for the engines that track
    /// them (the keep-centroid policy of [`step::finalize`] stays; this
    /// makes the events visible). Empty for engines that do not track.
    pub empty_events: Vec<u64>,
    /// Distance-pruning counters — `Some` for the triangle-inequality
    /// engines ([`elkan`], [`hamerly`]), `None` for dense engines.
    pub pruning: Option<PruneStats>,
}

impl KmeansResult {
    /// Total empty-cluster events across all iterations.
    pub fn empty_total(&self) -> u64 {
        self.empty_events.iter().sum()
    }
}

impl KmeansResult {
    /// Centroid `c` as a slice.
    pub fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }

    /// Number of points assigned to each cluster.
    pub fn cluster_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &a in &self.assign {
            if a >= 0 {
                sizes[a as usize] += 1;
            }
        }
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_builder() {
        let c = KmeansConfig::new(8).with_seed(1).with_tol(1e-4).with_max_iters(10);
        assert_eq!(c.k, 8);
        assert_eq!(c.seed, 1);
        assert_eq!(c.tol, 1e-4);
        assert_eq!(c.max_iters, 10);
    }

    #[test]
    fn result_accessors() {
        let r = KmeansResult {
            centroids: vec![0.0, 0.0, 1.0, 1.0],
            assign: vec![0, 1, 1, -1],
            k: 2,
            dim: 2,
            iterations: 3,
            sse: 0.5,
            shift: 0.0,
            converged: true,
            history: vec![],
            empty_events: vec![1, 0, 2],
            pruning: None,
        };
        assert_eq!(r.centroid(1), &[1.0, 1.0]);
        assert_eq!(r.cluster_sizes(), vec![1, 2]);
        assert_eq!(r.empty_total(), 3);
    }

    #[test]
    fn prune_stats_totals_and_rate() {
        let s = PruneStats {
            seed_computed: 40,
            per_iter: vec![(10, 30), (5, 35), (0, 0)],
        };
        assert_eq!(s.computed(), 55);
        assert_eq!(s.skipped(), 65);
        assert!((s.skip_rate() - 65.0 / 120.0).abs() < 1e-12);
        assert_eq!(PruneStats::default().skip_rate(), 0.0);
    }
}
