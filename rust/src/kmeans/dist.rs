//! Distributed Lloyd over TCP shard workers — the leader engine
//! (DESIGN.md §10).
//!
//! Structurally this is [`crate::kmeans::streaming`] with the shard
//! threads replaced by [`crate::cluster::worker`] processes: each
//! iteration the leader broadcasts the centroids (`Assign` frames to
//! every worker before reading any reply — workers compute in
//! parallel), collects one `Partials` frame per worker, folds them with
//! the canonical [`merge_ordered`] ascending-shard contract, and
//! finalizes. Only `K × d`-sized statistics cross the wire per
//! iteration; the `O(n)` assignment vector is fetched once, after
//! convergence.
//!
//! ## Determinism and bit-identity
//!
//! Workers fold their rows in ascending order through the
//! chunked-accumulation contract (the exact `stream_shard` fold the
//! out-of-core engine runs), floats cross the wire as IEEE bit
//! patterns, and the leader merges partials by *shard index* — the
//! order workers were listed in `--workers`, never reply arrival order
//! (each worker has its own socket; replies are read per-socket in
//! shard order, so a slow shard 0 cannot reorder the fold). Therefore
//! `dist(S)` ≡ `oocore(shards = S)` ≡ `threads(p = S)` bit-for-bit —
//! by construction, for any worker count, any reply timing, any chunk
//! size, and any mix of kernel tiers across the cluster. Pinned by
//! `rust/tests/integration_dist.rs` and re-checked per cell in
//! `benches/dist_scaling.rs`.
//!
//! ## Failure model
//!
//! The leader fails fast and never hangs: every socket carries bounded
//! read/write timeouts ([`DistOpts`]), and every failure surfaces as a
//! typed [`Error::Cluster`] — [`ClusterError::Connection`] for loss or
//! timeout, [`ClusterError::Frame`] for corrupt bytes,
//! [`ClusterError::Shape`] for disagreeing shards, and
//! [`ClusterError::Protocol`] for out-of-order frames or worker-
//! reported errors. Under the default [`DistSched::Static`] scheduler
//! there is no mid-run retry: a half-collected iteration has no
//! consistent state to resume from, and reruns are cheap precisely
//! because results are deterministic. [`DistSched::Elastic`]
//! ([`elastic`], DESIGN.md §12) replaces that abort-on-failure policy
//! with chunk-granular re-dispatch, bounded reconnect retries with
//! exponential backoff, speculative re-execution of straggler chunks
//! and mid-run worker join — a run survives any failure as long as one
//! full-view worker stays reachable, and the recovery is visible in
//! [`NetStats`].

pub mod elastic;

use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::cluster::wire::{self, Frame, WIRE_VERSION};
pub use crate::config::DistSched;
use crate::config::Init;
use crate::error::{ClusterError, Error, Result};
use crate::kmeans::ckpt::{self, CkptSink, CkptState, DenseSnap};
use crate::kmeans::step::{finalize_counted, merge_ordered, PartialStats};
use crate::kmeans::{KmeansConfig, KmeansResult};
use crate::rng::Pcg64;
use crate::util::trace::{self, WorkerPhase};

/// Network knobs for a distributed run. Results never depend on them —
/// they bound how long a dead worker can stall the leader, and (for
/// the elastic scheduler) how hard the leader tries to win it back.
#[derive(Debug, Clone, Copy)]
pub struct DistOpts {
    /// Per-worker TCP connect budget.
    pub connect_timeout: Duration,
    /// Per-read/write socket timeout. A worker that goes silent longer
    /// than this surfaces as [`ClusterError::Connection`]. Generous by
    /// default: one E-step over a large shard sits between frames.
    pub io_timeout: Duration,
    /// Which scheduler runs the iterations (`--dist-sched`).
    pub sched: DistSched,
    /// Elastic only: consecutive reconnect attempts per worker before
    /// it is written off (`--retry`). Each attempt backs off
    /// exponentially from 100 ms; the counter resets on any completed
    /// chunk. Ignored by the static scheduler.
    pub retry: u32,
}

impl Default for DistOpts {
    fn default() -> Self {
        DistOpts {
            connect_timeout: Duration::from_secs(10),
            io_timeout: Duration::from_secs(120),
            sched: DistSched::Static,
            retry: 2,
        }
    }
}

/// Wire traffic and round-trip telemetry for one iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterNet {
    /// Bytes the leader sent (centroid broadcast).
    pub bytes_tx: u64,
    /// Bytes the leader received (partials).
    pub bytes_rx: u64,
    /// Broadcast-to-last-partial wall time.
    pub secs: f64,
}

/// `EngineRun`-style network statistics for a whole distributed run.
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    /// Worker (= shard) count.
    pub workers: usize,
    /// Handshake traffic (Hello/ShardSpec), bytes both directions.
    pub handshake_bytes: u64,
    /// Init gather traffic (Gather/Rows), bytes both directions.
    pub gather_bytes: u64,
    /// Per-iteration traffic and round-trip, aligned with
    /// [`KmeansResult::history`].
    pub per_iter: Vec<IterNet>,
    /// Final assignment collection (FetchAssign/AssignShard for the
    /// static scheduler; the `want_assign` chunk pass for the elastic
    /// one), bytes both directions.
    pub collect_bytes: u64,
    /// Elastic recovery telemetry (all zero under the static
    /// scheduler): chunks returned to the dispatch queue after a
    /// worker failure or timeout.
    pub redispatched_chunks: u64,
    /// Speculative chunk claims — an idle worker re-executing a chunk
    /// that is in flight elsewhere. Nonzero even in healthy runs (the
    /// tail of every iteration invites speculation); duplicated work
    /// is harmless because every execution of a chunk yields the same
    /// bits.
    pub speculative_chunks: u64,
    /// Speculative executions that finished first and were accepted —
    /// each one is a straggler (or corpse) the cluster outran.
    pub speculative_wins: u64,
    /// Mid-run worker connection failures (drops and timeouts).
    pub worker_failures: u64,
    /// Successful reconnects (`Rejoin` handshakes) after a failure.
    pub worker_rejoins: u64,
    /// Wall-clock spent recovering: for every iteration disturbed by a
    /// failure, the time from the first failure detection to the
    /// iteration completing, summed.
    pub recovery_secs: f64,
}

impl NetStats {
    /// Total bytes moved, both directions, all phases.
    pub fn total_bytes(&self) -> u64 {
        self.handshake_bytes
            + self.gather_bytes
            + self.collect_bytes
            + self.per_iter.iter().map(|i| i.bytes_tx + i.bytes_rx).sum::<u64>()
    }

    /// Mean per-iteration wire bytes (0 when no iterations ran).
    pub fn bytes_per_iter(&self) -> f64 {
        if self.per_iter.is_empty() {
            0.0
        } else {
            self.per_iter.iter().map(|i| (i.bytes_tx + i.bytes_rx) as f64).sum::<f64>()
                / self.per_iter.len() as f64
        }
    }

    /// Mean broadcast-to-last-partial round trip (0 when none ran).
    pub fn avg_round_trip_secs(&self) -> f64 {
        if self.per_iter.is_empty() {
            0.0
        } else {
            self.per_iter.iter().map(|i| i.secs).sum::<f64>() / self.per_iter.len() as f64
        }
    }
}

/// A distributed run's result plus its network telemetry.
#[derive(Debug, Clone)]
pub struct DistRun {
    pub result: KmeansResult,
    pub net: NetStats,
}

/// One connected worker.
struct Link {
    stream: TcpStream,
    addr: String,
    /// Shard size reported in the handshake.
    rows: usize,
    /// Global row offset (ascending shard order).
    offset: usize,
}

impl Link {
    fn send(&mut self, frame: &Frame) -> Result<u64> {
        wire::write_frame(&mut self.stream, frame).map_err(|e| ctx(e, &self.addr))
    }

    /// Read one frame; a worker `ErrMsg` becomes a typed protocol
    /// error, any other unexpected frame too.
    fn recv(&mut self, expect: &str) -> Result<(Frame, u64)> {
        let (frame, bytes) =
            wire::read_frame(&mut self.stream, expect).map_err(|e| ctx(e, &self.addr))?;
        if let Frame::ErrMsg { message } = frame {
            return Err(Error::Cluster(ClusterError::Protocol(format!(
                "worker {}: {message}",
                self.addr
            ))));
        }
        Ok((frame, bytes))
    }
}

/// Attach the worker address to a cluster error (the frame layer does
/// not know which peer it spoke to).
fn ctx(e: Error, addr: &str) -> Error {
    match e {
        Error::Cluster(ce) => Error::Cluster(match ce {
            ClusterError::Connection(m) => ClusterError::Connection(format!("worker {addr}: {m}")),
            ClusterError::Frame(m) => ClusterError::Frame(format!("worker {addr}: {m}")),
            ClusterError::Shape(m) => ClusterError::Shape(format!("worker {addr}: {m}")),
            ClusterError::Protocol(m) => ClusterError::Protocol(format!("worker {addr}: {m}")),
        }),
        other => other,
    }
}

/// Resolve `addr`, connect within [`DistOpts::connect_timeout`], and
/// arm both socket directions with [`DistOpts::io_timeout`]. Every
/// failure is a typed [`ClusterError::Connection`]. Shared by the
/// static leader and the elastic agents.
fn open_socket(addr: &str, opts: &DistOpts) -> Result<TcpStream> {
    let sock_addr = addr
        .to_socket_addrs()
        .map_err(|e| {
            Error::Cluster(ClusterError::Connection(format!("worker {addr}: cannot resolve: {e}")))
        })?
        .next()
        .ok_or_else(|| {
            Error::Cluster(ClusterError::Connection(format!(
                "worker {addr}: resolves to no address"
            )))
        })?;
    let stream = TcpStream::connect_timeout(&sock_addr, opts.connect_timeout)
        .map_err(|e| Error::Cluster(ClusterError::Connection(format!("worker {addr}: {e}"))))?;
    let _ = stream.set_nodelay(true);
    // keep the "every failure is a typed Error::Cluster" contract: the
    // OS can reject e.g. a sub-resolution timeout
    stream
        .set_read_timeout(Some(opts.io_timeout))
        .and_then(|_| stream.set_write_timeout(Some(opts.io_timeout)))
        .map_err(|e| {
            Error::Cluster(ClusterError::Connection(format!(
                "worker {addr}: cannot set io timeout {:?}: {e}",
                opts.io_timeout
            )))
        })?;
    Ok(stream)
}

/// A handshaken cluster, ready to run. Workers are shards in the order
/// given — shard `i` is `addrs[i]`, and the merge folds in that order.
pub struct Cluster {
    links: Vec<Link>,
    dim: usize,
    n: usize,
    net: NetStats,
}

impl Cluster {
    /// Connect to every worker and exchange `Hello`/`ShardSpec`. Fails
    /// fast on unreachable workers, version mismatches, disagreeing
    /// dimensionality, or an empty cluster.
    pub fn connect(addrs: &[String], opts: &DistOpts) -> Result<Cluster> {
        if addrs.is_empty() {
            return Err(Error::Config("dist: need at least one worker address".into()));
        }
        let mut links = Vec::with_capacity(addrs.len());
        let mut net = NetStats { workers: addrs.len(), ..Default::default() };
        let mut offset = 0usize;
        for addr in addrs {
            let stream = open_socket(addr, opts)?;
            let mut link = Link { stream, addr: addr.clone(), rows: 0, offset };
            net.handshake_bytes += link.send(&Frame::Hello { version: WIRE_VERSION })?;
            let (frame, bytes) = link.recv("waiting for ShardSpec")?;
            net.handshake_bytes += bytes;
            let (rows, dim) = match frame {
                Frame::ShardSpec { rows, dim } => (rows, dim),
                other => {
                    return Err(Error::Cluster(ClusterError::Protocol(format!(
                        "worker {addr}: expected ShardSpec, got {}",
                        other.name()
                    ))))
                }
            };
            let rows = usize::try_from(rows).map_err(|_| {
                Error::Cluster(ClusterError::Shape(format!(
                    "worker {addr}: implausible shard size {rows}"
                )))
            })?;
            link.rows = rows;
            offset += rows;
            links.push((link, dim as usize));
        }
        let dim = links[0].1;
        if let Some((link, d)) = links.iter().find(|(_, d)| *d != dim) {
            return Err(Error::Cluster(ClusterError::Shape(format!(
                "workers disagree on dimensionality: {} is {dim}D, {} is {d}D",
                links[0].0.addr, link.addr
            ))));
        }
        if dim == 0 {
            return Err(Error::Cluster(ClusterError::Shape("workers report dim = 0".into())));
        }
        let n = offset;
        if n == 0 {
            return Err(Error::Cluster(ClusterError::Shape(
                "cluster holds no rows (every shard is empty)".into(),
            )));
        }
        Ok(Cluster { links: links.into_iter().map(|(l, _)| l).collect(), dim, n, net })
    }

    /// Total rows across all shards.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Point dimensionality every shard agreed on.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Sample K distinct global rows uniformly — the *same* index
    /// sequence as [`crate::kmeans::init::random`] (identical RNG
    /// stream), gathered from the shards that own them. A distributed
    /// run therefore starts from the exact centroids an in-memory run
    /// with the same seed starts from.
    pub fn init_random(&mut self, k: usize, seed: u64) -> Result<Vec<f32>> {
        if k > self.n {
            return Err(Error::Config(format!("init: k {k} > n {}", self.n)));
        }
        let mut rng = Pcg64::new(seed, 0x1417);
        let idx = rng.sample_indices(self.n, k);
        // group requested rows by owning shard, remembering where each
        // lands in the centroid buffer
        let mut per_link: Vec<Vec<(usize, usize)>> = vec![Vec::new(); self.links.len()];
        for (pos, &gi) in idx.iter().enumerate() {
            // linear scan: worker counts are small, and this stays
            // correct even when a shard is empty
            let li = self
                .links
                .iter()
                .position(|l| gi >= l.offset && gi < l.offset + l.rows)
                .expect("sampled index inside [0, n)");
            per_link[li].push((pos, gi - self.links[li].offset));
        }
        let d = self.dim;
        let mut out = vec![0.0f32; k * d];
        for (li, wanted) in per_link.iter().enumerate() {
            if wanted.is_empty() {
                continue;
            }
            let link = &mut self.links[li];
            let indices: Vec<u64> = wanted.iter().map(|&(_, local)| local as u64).collect();
            let m = indices.len();
            self.net.gather_bytes += link.send(&Frame::Gather { indices })?;
            let (frame, bytes) = link.recv("waiting for gathered rows")?;
            self.net.gather_bytes += bytes;
            let rows = match frame {
                Frame::Rows { dim, rows } if dim as usize == d && rows.len() == m * d => rows,
                Frame::Rows { dim, rows } => {
                    return Err(Error::Cluster(ClusterError::Shape(format!(
                        "worker {}: gathered {} values of {}D rows, expected {m} × {d}D",
                        link.addr,
                        rows.len(),
                        dim
                    ))))
                }
                other => {
                    return Err(Error::Cluster(ClusterError::Protocol(format!(
                        "worker {}: expected Rows, got {}",
                        link.addr,
                        other.name()
                    ))))
                }
            };
            for (j, &(pos, _)) in wanted.iter().enumerate() {
                out[pos * d..(pos + 1) * d].copy_from_slice(&rows[j * d..(j + 1) * d]);
            }
        }
        Ok(out)
    }

    /// Run distributed Lloyd from explicit initial centroids, consuming
    /// the cluster (workers receive `Shutdown` on success; on error the
    /// connections drop and workers end their session at the break).
    pub fn run_from(self, cfg: &KmeansConfig, centroids0: &[f32]) -> Result<DistRun> {
        self.run_from_ckpt(cfg, centroids0, None, None)
    }

    /// [`Cluster::run_from`] with checkpoint/resume (DESIGN.md §14).
    /// On resume, `centroids0` must be the snapshot's centroids; a
    /// snapshot that is already terminal replays one assignment-only
    /// round against its `prev_centroids` so the final `FetchAssign`
    /// returns the bits the uninterrupted run produced.
    pub fn run_from_ckpt(
        mut self,
        cfg: &KmeansConfig,
        centroids0: &[f32],
        sink: Option<&CkptSink>,
        resumed: Option<&CkptState>,
    ) -> Result<DistRun> {
        let (n, d, k) = (self.n, self.dim, cfg.k);
        if k == 0 {
            return Err(Error::Config("dist: k must be >= 1".into()));
        }
        if centroids0.len() != k * d {
            return Err(Error::Shape(format!(
                "dist: initial centroids len {} != k {k} × dim {d}",
                centroids0.len()
            )));
        }
        if let Some(state) = resumed {
            state.check_dense(k, d)?;
            if state.fingerprint.n != n as u64 {
                return Err(Error::Ckpt(format!(
                    "state fingerprint n {} != cluster n {n}",
                    state.fingerprint.n
                )));
            }
        }

        let mut centroids = centroids0.to_vec();
        let mut history: Vec<(f64, f64)> =
            resumed.map(|s| s.history.clone()).unwrap_or_default();
        let mut empty_events: Vec<u64> =
            resumed.map(|s| s.empty_events.clone()).unwrap_or_default();
        let mut converged = resumed.map(|s| s.converged).unwrap_or(false);
        let mut iterations = resumed.map(|s| s.iteration as usize).unwrap_or(0);
        let mut parts: Vec<PartialStats> = Vec::with_capacity(self.links.len());
        let mut per_worker: Vec<WorkerPhase> = Vec::new();
        let mut assigned_once = false;

        while !converged && iterations < cfg.max_iters {
            let t0 = Instant::now();
            let mut iter_net = IterNet { bytes_tx: 0, bytes_rx: 0, secs: 0.0 };
            let wire_span = trace::span(trace::Phase::Wire);
            // broadcast to every worker before reading any reply, so
            // all shards compute their E-step concurrently
            let assign_frame = Frame::Assign {
                k: k as u32,
                dim: d as u32,
                policy: cfg.distance,
                centroids: centroids.clone(),
            };
            for link in &mut self.links {
                iter_net.bytes_tx += link.send(&assign_frame)?;
            }
            // collect per-socket in ascending shard order: arrival
            // timing cannot reorder the fold
            parts.clear();
            per_worker.clear();
            for (wi, link) in self.links.iter_mut().enumerate() {
                let (frame, bytes) = link.recv("waiting for Partials")?;
                iter_net.bytes_rx += bytes;
                match frame {
                    Frame::Partials { k: pk, dim: pd, counts, sums, sse, phase }
                        if pk as usize == k
                            && pd as usize == d
                            && counts.len() == k
                            && sums.len() == k * d =>
                    {
                        if trace::enabled() {
                            if let Some(p) = phase {
                                per_worker.push(WorkerPhase {
                                    worker: wi as u64,
                                    assign_ns: p.assign_ns,
                                    ser_ns: p.ser_ns,
                                });
                            }
                        }
                        parts.push(PartialStats { k, dim: d, sums, counts, sse });
                    }
                    Frame::Partials { k: pk, dim: pd, .. } => {
                        return Err(Error::Cluster(ClusterError::Shape(format!(
                            "worker {}: partials shaped {pk}×{pd}, expected {k}×{d}",
                            link.addr
                        ))))
                    }
                    other => {
                        return Err(Error::Cluster(ClusterError::Protocol(format!(
                            "worker {}: expected Partials, got {}",
                            link.addr,
                            other.name()
                        ))))
                    }
                }
            }
            // stamp the round trip at the last partial, before the
            // leader-side fold — secs means what the label says
            iter_net.secs = t0.elapsed().as_secs_f64();
            drop(wire_span);
            assigned_once = true;
            let merged = {
                let _s = trace::span(trace::Phase::Merge);
                merge_ordered(parts.iter())
            };
            let (mu_new, shift, empties) = {
                let _s = trace::span(trace::Phase::Update);
                finalize_counted(&merged, &centroids)
            };
            let prev = std::mem::replace(&mut centroids, mu_new);
            iterations += 1;
            history.push((merged.sse, shift));
            empty_events.push(empties);
            self.net.per_iter.push(iter_net);
            let converged_now = shift < cfg.tol;
            if let Some(sink) = sink {
                let _s = trace::span(trace::Phase::Ckpt);
                ckpt::save_dense(
                    sink,
                    &DenseSnap {
                        iteration: iterations,
                        converged: converged_now,
                        centroids: &centroids,
                        prev_centroids: &prev,
                        history: &history,
                        empty_events: &empty_events,
                    },
                )?;
            }
            trace::emit_iter(iterations, merged.sse, empties, &per_worker);
            if converged_now {
                converged = true;
            }
        }

        if let (Some(state), false) = (resumed, assigned_once) {
            // terminal snapshot: the workers never computed an E-step
            // this session — one assignment-only round against the
            // centroids the final assignment was computed from
            let assign_frame = Frame::Assign {
                k: k as u32,
                dim: d as u32,
                policy: cfg.distance,
                centroids: state.prev_centroids.clone(),
            };
            for link in &mut self.links {
                self.net.collect_bytes += link.send(&assign_frame)?;
            }
            for link in &mut self.links {
                let (frame, bytes) = link.recv("waiting for Partials")?;
                self.net.collect_bytes += bytes;
                match frame {
                    Frame::Partials { .. } => {} // stats replayed from history
                    other => {
                        return Err(Error::Cluster(ClusterError::Protocol(format!(
                            "worker {}: expected Partials, got {}",
                            link.addr,
                            other.name()
                        ))))
                    }
                }
            }
        }

        // fetch the O(n) assignment vector once, after the loop
        let mut assign = vec![-1i32; n];
        for link in &mut self.links {
            self.net.collect_bytes += link.send(&Frame::FetchAssign)?;
        }
        for link in &mut self.links {
            let (frame, bytes) = link.recv("waiting for AssignShard")?;
            self.net.collect_bytes += bytes;
            match frame {
                Frame::AssignShard { assign: shard } if shard.len() == link.rows => {
                    assign[link.offset..link.offset + link.rows].copy_from_slice(&shard);
                }
                Frame::AssignShard { assign: shard } => {
                    return Err(Error::Cluster(ClusterError::Shape(format!(
                        "worker {}: sent {} assignments for a {}-row shard",
                        link.addr,
                        shard.len(),
                        link.rows
                    ))))
                }
                other => {
                    return Err(Error::Cluster(ClusterError::Protocol(format!(
                        "worker {}: expected AssignShard, got {}",
                        link.addr,
                        other.name()
                    ))))
                }
            }
        }

        // polite shutdown; failures here cannot invalidate the result
        for link in &mut self.links {
            let _ = link.send(&Frame::Shutdown);
        }

        let (sse, shift) = *history.last().unwrap_or(&(f64::NAN, f64::NAN));
        Ok(DistRun {
            result: KmeansResult {
                centroids,
                assign,
                k,
                dim: d,
                iterations,
                sse,
                shift,
                converged,
                history,
                empty_events,
                pruning: None,
            },
            net: self.net,
        })
    }

    /// [`Cluster::run_from`] with leader-side seeded-random init
    /// ([`Cluster::init_random`] — identical to the in-memory engines'
    /// init). Only [`Init::Random`] is distributable, as with the
    /// out-of-core engine.
    pub fn run(mut self, cfg: &KmeansConfig) -> Result<DistRun> {
        let centroids0 = match cfg.init {
            Init::Random => self.init_random(cfg.k, cfg.seed)?,
            Init::KmeansPlusPlus => {
                return Err(Error::Config(
                    "dist: kmeans++ init needs a resident dataset; \
                     precompute centroids (kmeans::init) and call run_from"
                        .into(),
                ))
            }
        };
        self.run_from(cfg, &centroids0)
    }
}

/// Connect, init (seeded random — same stream as the in-memory
/// engines), run, shut down. Dispatches on [`DistOpts::sched`]: the
/// static per-shard leader or the elastic chunk-granular one.
pub fn run(addrs: &[String], cfg: &KmeansConfig, opts: &DistOpts) -> Result<DistRun> {
    match opts.sched {
        DistSched::Static => Cluster::connect(addrs, opts)?.run(cfg),
        DistSched::Elastic => elastic::run(addrs, cfg, opts),
    }
}

/// Connect and run from explicit initial centroids (dispatches on
/// [`DistOpts::sched`] like [`run`]).
pub fn run_from(
    addrs: &[String],
    cfg: &KmeansConfig,
    opts: &DistOpts,
    centroids0: &[f32],
) -> Result<DistRun> {
    match opts.sched {
        DistSched::Static => Cluster::connect(addrs, opts)?.run_from(cfg, centroids0),
        DistSched::Elastic => elastic::run_from(addrs, cfg, opts, centroids0),
    }
}

/// [`run`] with checkpoint/resume, dispatching on [`DistOpts::sched`].
/// On resume the snapshot supplies the centroids; otherwise init is
/// the leader-side seeded random gather (only [`Init::Random`] is
/// distributable).
pub fn run_ckpt(
    addrs: &[String],
    cfg: &KmeansConfig,
    opts: &DistOpts,
    sink: Option<&CkptSink>,
    resume: Option<CkptState>,
) -> Result<DistRun> {
    match opts.sched {
        DistSched::Static => {
            let mut cluster = Cluster::connect(addrs, opts)?;
            match resume {
                Some(state) => {
                    let c0 = state.centroids.clone();
                    cluster.run_from_ckpt(cfg, &c0, sink, Some(&state))
                }
                None => {
                    let c0 = match cfg.init {
                        Init::Random => cluster.init_random(cfg.k, cfg.seed)?,
                        Init::KmeansPlusPlus => {
                            return Err(Error::Config(
                                "dist: kmeans++ init needs a resident dataset; \
                                 precompute centroids (kmeans::init) and call run_from"
                                    .into(),
                            ))
                        }
                    };
                    cluster.run_from_ckpt(cfg, &c0, sink, None)
                }
            }
        }
        DistSched::Elastic => elastic::run_ckpt(addrs, cfg, opts, sink, resume),
    }
}

/// Checkpoint/resume request as the CLI knows it: directories and a
/// cadence, no fingerprint. The run fingerprint (DESIGN.md §14) needs
/// the dataset shape `(n, d)`, which the dist leader only learns from
/// the worker handshake — so sink creation and resume validation
/// happen here, after connecting, not at flag-parse time.
#[derive(Debug, Clone, Default)]
pub struct CkptSpec {
    /// `--checkpoint DIR`: write A/B-rotated `.pkc` snapshots here.
    pub checkpoint: Option<std::path::PathBuf>,
    /// `--checkpoint-every N` (>= 1).
    pub every: usize,
    /// `--resume DIR`: load + fingerprint-validate the newest slot.
    pub resume: Option<std::path::PathBuf>,
}

/// [`run_ckpt`] for callers that only hold checkpoint *paths*: connect
/// (or probe, under the elastic scheduler), learn `(n, d)`, build the
/// fingerprint, then create the sink and/or validate the resume slot.
pub fn run_ckpt_spec(
    addrs: &[String],
    cfg: &KmeansConfig,
    opts: &DistOpts,
    spec: &CkptSpec,
) -> Result<DistRun> {
    match opts.sched {
        DistSched::Static => {
            let mut cluster = Cluster::connect(addrs, opts)?;
            let fp = ckpt::fingerprint("dist", "static", cfg, cluster.n, cluster.dim);
            let sink = match &spec.checkpoint {
                Some(dir) => Some(CkptSink::create(dir, spec.every, fp.clone())?),
                None => None,
            };
            let resume = match &spec.resume {
                Some(dir) => Some(ckpt::load_validated(dir, &fp)?),
                None => None,
            };
            match resume {
                Some(state) => {
                    let c0 = state.centroids.clone();
                    cluster.run_from_ckpt(cfg, &c0, sink.as_ref(), Some(&state))
                }
                None => {
                    let c0 = match cfg.init {
                        Init::Random => cluster.init_random(cfg.k, cfg.seed)?,
                        Init::KmeansPlusPlus => {
                            return Err(Error::Config(
                                "dist: kmeans++ init needs a resident dataset; \
                                 precompute centroids (kmeans::init) and call run_from"
                                    .into(),
                            ))
                        }
                    };
                    cluster.run_from_ckpt(cfg, &c0, sink.as_ref(), None)
                }
            }
        }
        DistSched::Elastic => elastic::run_ckpt_spec(addrs, cfg, opts, spec),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::loopback::LoopbackCluster;
    use crate::data::MixtureSpec;
    use crate::kmeans::{init, serial};
    use crate::testutil::assert_bit_identical;

    fn fast_opts() -> DistOpts {
        DistOpts {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(10),
            ..Default::default()
        }
    }

    #[test]
    fn one_worker_reproduces_serial_bit_for_bit() {
        let ds = MixtureSpec::paper_2d(8).generate(1201, 11);
        let cfg = KmeansConfig::new(8).with_seed(5);
        let reference = serial::run(&ds, &cfg);

        let cluster = LoopbackCluster::spawn_dataset(&ds, 1, 128).unwrap();
        let run = run(&cluster.addrs, &cfg, &fast_opts()).unwrap();
        cluster.join().unwrap();
        assert_bit_identical(&run.result, &reference, "dist(1) vs serial");
    }

    #[test]
    fn init_random_matches_in_memory_init() {
        let ds = MixtureSpec::paper_3d(4).generate(900, 6);
        let resident = init::random(&ds, 8, 42);
        let cluster = LoopbackCluster::spawn_dataset(&ds, 3, 64).unwrap();
        let mut c = Cluster::connect(&cluster.addrs, &fast_opts()).unwrap();
        assert_eq!((c.n(), c.dim()), (900, 3));
        let streamed = c.init_random(8, 42).unwrap();
        assert_eq!(streamed, resident);
        drop(c); // close connections so the single-session workers exit
        cluster.join().unwrap();
    }

    #[test]
    fn dot_policy_bit_identical_to_oocore_dot_and_matches_exact() {
        use crate::config::DistancePolicy;
        use crate::data::MemorySource;
        use crate::kmeans::streaming::{self, StreamOpts};
        let ds = MixtureSpec::paper_2d(8).generate(1201, 11);
        let cfg = KmeansConfig::new(8).with_seed(5).with_distance(DistancePolicy::Dot);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);

        let cluster = LoopbackCluster::spawn_dataset(&ds, 2, 128).unwrap();
        let dist_run = run_from(&cluster.addrs, &cfg, &fast_opts(), &mu0).unwrap();
        cluster.join().unwrap();

        // the worker replays the oocore shard fold (same norms, same
        // chunked accumulation), so dist(2, dot) ≡ oocore(2, dot)
        let oocore = streaming::run_from(
            &MemorySource::new(&ds),
            &cfg,
            &StreamOpts { shards: 2, chunk_rows: 128 },
            &mu0,
        )
        .unwrap();
        assert_bit_identical(&dist_run.result, &oocore, "dist(2,dot) vs oocore(2,dot)");

        // and the cross-policy contract vs exact serial
        let exact = serial::run_from(&ds, &KmeansConfig::new(8).with_seed(5), &mu0);
        assert_eq!(dist_run.result.assign, exact.assign);
        assert_eq!(dist_run.result.iterations, exact.iterations);
        let rel = (dist_run.result.sse - exact.sse).abs() / exact.sse.max(1.0);
        assert!(rel < 1e-5, "sse rel err {rel}");
    }

    #[test]
    fn net_stats_track_every_phase() {
        let ds = MixtureSpec::paper_2d(4).generate(600, 2);
        let cfg = KmeansConfig::new(4).with_seed(3);
        let cluster = LoopbackCluster::spawn_dataset(&ds, 2, 64).unwrap();
        let run = run(&cluster.addrs, &cfg, &fast_opts()).unwrap();
        cluster.join().unwrap();
        let net = &run.net;
        assert_eq!(net.workers, 2);
        assert_eq!(net.per_iter.len(), run.result.iterations);
        assert!(net.handshake_bytes > 0);
        assert!(net.gather_bytes > 0);
        assert!(net.collect_bytes as usize > 600 * 4, "{}", net.collect_bytes);
        assert!(net.bytes_per_iter() > 0.0);
        assert!(net.avg_round_trip_secs() > 0.0);
        assert!(net.total_bytes() > net.collect_bytes);
    }

    #[test]
    fn config_errors_are_typed() {
        // no workers
        let err = run(&[], &KmeansConfig::new(2), &fast_opts()).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");

        let ds = MixtureSpec::paper_2d(4).generate(50, 1);
        // k == 0
        let cluster = LoopbackCluster::spawn_dataset(&ds, 1, 16).unwrap();
        let err = run_from(&cluster.addrs, &KmeansConfig::new(0), &fast_opts(), &[]).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        let _ = cluster.join(); // leader dropped: workers end cleanly

        // bad centroid shape
        let cluster = LoopbackCluster::spawn_dataset(&ds, 1, 16).unwrap();
        let err =
            run_from(&cluster.addrs, &KmeansConfig::new(2), &fast_opts(), &[0.0; 3]).unwrap_err();
        assert!(matches!(err, Error::Shape(_)), "{err}");
        let _ = cluster.join();

        // k > n through run()
        let cluster = LoopbackCluster::spawn_dataset(&ds, 1, 16).unwrap();
        let err = run(&cluster.addrs, &KmeansConfig::new(51), &fast_opts()).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        let _ = cluster.join();

        // kmeans++ not distributable
        let cluster = LoopbackCluster::spawn_dataset(&ds, 1, 16).unwrap();
        let cfg = KmeansConfig::new(2).with_init(Init::KmeansPlusPlus);
        let err = run(&cluster.addrs, &cfg, &fast_opts()).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        let _ = cluster.join();
    }

    #[test]
    fn unreachable_worker_is_connection_error() {
        // a port with no listener: refused immediately
        let err = run(
            &["127.0.0.1:1".to_string()],
            &KmeansConfig::new(2),
            &fast_opts(),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Connection(_))), "{err}");
        // every dist connection/frame error names the worker it came
        // from — the operator-facing contract for triaging a cluster
        assert!(err.to_string().contains("127.0.0.1:1"), "address missing: {err}");
    }
}
