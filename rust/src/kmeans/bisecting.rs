//! Bisecting K-Means — hierarchical divisive clustering (Steinbach et
//! al. 2000), an extension the paper's "complex applications" outlook
//! motivates: more robust to initialization than flat Lloyd and yields
//! a cluster hierarchy for free.
//!
//! Start with one cluster; repeatedly pick the cluster with the
//! largest SSE and split it with 2-means (best of `trials` seeded
//! attempts), until K clusters exist. Each split runs the plain serial
//! Lloyd core on the member subset, so every invariant of
//! [`crate::kmeans::step`] applies.

use crate::data::Dataset;
use crate::kmeans::{serial, KmeansConfig, KmeansResult};
use crate::linalg;

/// Run bisecting K-Means to `cfg.k` clusters. `trials` seeded 2-means
/// attempts per split (best SSE wins).
pub fn run(ds: &Dataset, cfg: &KmeansConfig, trials: usize) -> KmeansResult {
    let n = ds.len();
    let d = ds.dim();
    let k_target = cfg.k.max(1).min(n.max(1));
    let trials = trials.max(1);

    let mut assign = vec![0i32; n];
    // cluster id -> member indices (rebuilt as clusters split)
    let mut members: Vec<Vec<usize>> = vec![(0..n).collect()];
    let mut sse_of: Vec<f64> = vec![cluster_sse(ds, &members[0])];
    // a cluster whose 2-means split degenerated (one side empty — e.g.
    // all members identical) can never split; without this mark the
    // `len() >= 2` filter would re-pick it forever
    let mut unsplittable: Vec<bool> = vec![false];
    let mut total_iterations = 0usize;

    while members.len() < k_target {
        // pick the worst (largest-SSE) splittable cluster
        let (worst, _) = sse_of
            .iter()
            .enumerate()
            .filter(|(c, _)| members[*c].len() >= 2 && !unsplittable[*c])
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, s)| (c, *s))
            .unwrap_or((usize::MAX, 0.0));
        if worst == usize::MAX {
            break; // nothing splittable (all singletons or degenerate)
        }

        // subset dataset for the split
        let idx = members[worst].clone();
        let mut sub = Dataset::with_capacity(d, idx.len());
        for &i in &idx {
            sub.push(ds.point(i));
        }

        // best-of-trials 2-means (inherits the distance policy — each
        // subset Dataset lazily builds its own point-norm cache)
        let mut best: Option<KmeansResult> = None;
        for t in 0..trials {
            let sub_cfg = KmeansConfig::new(2)
                .with_seed(cfg.seed ^ ((0xB15EC + t as u64 + members.len() as u64) << 8))
                .with_tol(cfg.tol)
                .with_max_iters(cfg.max_iters)
                .with_distance(cfg.distance);
            let r = serial::run(&sub, &sub_cfg);
            if best.as_ref().map(|b| r.sse < b.sse).unwrap_or(true) {
                best = Some(r);
            }
        }
        let split = best.expect("trials >= 1");
        total_iterations += split.iterations;

        // if the split degenerated (one side empty), stop splitting this
        // cluster
        let sizes = split.cluster_sizes();
        if sizes[0] == 0 || sizes[1] == 0 {
            unsplittable[worst] = true;
            continue;
        }

        // re-home members: side 0 keeps id `worst`, side 1 gets a new id
        let new_id = members.len();
        let mut keep = Vec::with_capacity(sizes[0]);
        let mut moved = Vec::with_capacity(sizes[1]);
        for (si, &gi) in idx.iter().enumerate() {
            if split.assign[si] == 0 {
                keep.push(gi);
            } else {
                assign[gi] = new_id as i32;
                moved.push(gi);
            }
        }
        for &gi in &keep {
            assign[gi] = worst as i32;
        }
        members[worst] = keep;
        members.push(moved);
        sse_of[worst] = cluster_sse(ds, &members[worst]);
        sse_of.push(cluster_sse(ds, &members[new_id]));
        unsplittable.push(false);
    }

    // final centroids from members
    let k = members.len();
    let mut centroids = vec![0.0f32; k * d];
    for (c, m) in members.iter().enumerate() {
        if m.is_empty() {
            continue;
        }
        let mut sums = vec![0.0f64; d];
        for &i in m {
            linalg::add_assign(&mut sums, ds.point(i));
        }
        for j in 0..d {
            centroids[c * d + j] = (sums[j] / m.len() as f64) as f32;
        }
    }
    let sse = crate::metrics::sse(ds, &centroids, k, &assign);
    KmeansResult {
        centroids,
        assign,
        k,
        dim: d,
        iterations: total_iterations,
        sse,
        shift: 0.0,
        converged: true,
        history: vec![(sse, 0.0)],
        empty_events: Vec::new(),
        pruning: None,
    }
}

/// SSE of one cluster around its own mean.
fn cluster_sse(ds: &Dataset, members: &[usize]) -> f64 {
    if members.is_empty() {
        return 0.0;
    }
    let d = ds.dim();
    let mut mean = vec![0.0f64; d];
    for &i in members {
        linalg::add_assign(&mut mean, ds.point(i));
    }
    for v in mean.iter_mut() {
        *v /= members.len() as f64;
    }
    let mean_f32: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
    members
        .iter()
        .map(|&i| linalg::sqdist_f64(ds.point(i), &mean_f32))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    #[test]
    fn reaches_k_clusters_with_full_partition() {
        let ds = MixtureSpec::paper_2d(8).generate(2000, 3);
        let r = run(&ds, &KmeansConfig::new(8).with_seed(5), 3);
        assert_eq!(r.k, 8);
        let sizes = r.cluster_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 2000);
        assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
    }

    #[test]
    fn recovers_separated_mixture() {
        let spec = MixtureSpec::random(3, 4, 80.0, 0.5, 7);
        let ds = spec.generate(3000, 1);
        let r = run(&ds, &KmeansConfig::new(4).with_seed(2), 4);
        let ari = crate::metrics::adjusted_rand_index(&r.assign, ds.truth.as_ref().unwrap());
        assert!(ari > 0.99, "ari {ari}");
    }

    #[test]
    fn quality_competitive_with_flat_lloyd() {
        let ds = MixtureSpec::paper_2d(8).generate(4000, 9);
        let flat = serial::run(&ds, &KmeansConfig::new(8).with_seed(4));
        let bis = run(&ds, &KmeansConfig::new(8).with_seed(4), 5);
        // bisecting is usually close to (sometimes better than) flat
        assert!(bis.sse <= flat.sse * 1.25, "bisecting {} vs flat {}", bis.sse, flat.sse);
    }

    #[test]
    fn k_one_is_single_cluster() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 1);
        let r = run(&ds, &KmeansConfig::new(1).with_seed(1), 2);
        assert_eq!(r.k, 1);
        assert_eq!(r.cluster_sizes(), vec![100]);
    }

    #[test]
    fn deterministic() {
        let ds = MixtureSpec::paper_3d(4).generate(1000, 2);
        let a = run(&ds, &KmeansConfig::new(4).with_seed(3), 3);
        let b = run(&ds, &KmeansConfig::new(4).with_seed(3), 3);
        assert_eq!(a.assign, b.assign);
    }
}
