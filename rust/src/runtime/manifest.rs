//! AOT artifact manifest (`artifacts/manifest.json`).
//!
//! Written by `python/compile/aot.py`; read here with the built-in
//! JSON parser. The manifest is the *contract* between the python
//! compile path and the rust request path: executable names, files,
//! kinds, shape parameters and full input/output signatures.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Data type of a tensor at the artifact boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(Error::Manifest(format!("unsupported dtype `{other}`"))),
        }
    }
}

/// One tensor in an executable signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let name = j.str_field("name")?.to_string();
        let dtype = DType::parse(j.str_field("dtype")?)?;
        let shape = j
            .arr_field("shape")?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Manifest("non-integer shape entry".into()))
            })
            .collect::<Result<Vec<usize>>>()?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// Executable kinds emitted by the AOT pipeline.
///
/// Iteration-loop programs (`StatsPartial`, `FusedStats`) return only
/// per-cluster statistics; `Assign` produces the chunk assignments and
/// runs once after convergence (§Perf L2-1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecKind {
    StatsPartial,
    Assign,
    FusedStats,
    Finalize,
}

impl ExecKind {
    fn parse(s: &str) -> Result<ExecKind> {
        match s {
            "stats_partial" => Ok(ExecKind::StatsPartial),
            "assign" => Ok(ExecKind::Assign),
            "fused_stats" => Ok(ExecKind::FusedStats),
            "finalize" => Ok(ExecKind::Finalize),
            other => Err(Error::Manifest(format!("unknown exec kind `{other}`"))),
        }
    }
}

/// One AOT executable.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub kind: ExecKind,
    pub d: usize,
    pub k: usize,
    /// Streaming chunk size (0 for `finalize`).
    pub chunk: usize,
    pub tile_n: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ExecSpec {
    fn parse(j: &Json) -> Result<ExecSpec> {
        Ok(ExecSpec {
            name: j.str_field("name")?.to_string(),
            file: j.str_field("file")?.to_string(),
            kind: ExecKind::parse(j.str_field("kind")?)?,
            d: j.usize_field("d")?,
            k: j.usize_field("k")?,
            chunk: j.usize_field("chunk")?,
            tile_n: j.usize_field("tile_n")?,
            inputs: j
                .arr_field("inputs")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?,
            outputs: j
                .arr_field("outputs")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub default_chunk: usize,
    pub executables: Vec<ExecSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "{}: {e} (run `make artifacts` first)",
                path.display()
            ))
        })?;
        Manifest::parse(&text, dir)
    }

    /// Parse manifest text (separated for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let format = j.usize_field("format")?;
        if format != 1 {
            return Err(Error::Manifest(format!("unsupported manifest format {format}")));
        }
        let executables = j
            .arr_field("executables")?
            .iter()
            .map(ExecSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            default_chunk: j.usize_field("default_chunk")?,
            executables,
        })
    }

    /// Find an executable by kind and shape parameters. `chunk` is
    /// ignored for `Finalize`.
    pub fn find(&self, kind: ExecKind, d: usize, k: usize, chunk: usize) -> Result<&ExecSpec> {
        self.executables
            .iter()
            .find(|e| {
                e.kind == kind
                    && e.d == d
                    && e.k == k
                    && (kind == ExecKind::Finalize || e.chunk == chunk)
            })
            .ok_or_else(|| {
                Error::Manifest(format!(
                    "no artifact for kind={kind:?} d={d} k={k} chunk={chunk}; \
                     available: {:?}",
                    self.executables
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                ))
            })
    }

    /// All (d, k) variants present for a kind.
    pub fn variants(&self, kind: ExecKind) -> Vec<(usize, usize, usize)> {
        self.executables
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| (e.d, e.k, e.chunk))
            .collect()
    }

    /// Absolute path of an executable's HLO file.
    pub fn hlo_path(&self, spec: &ExecSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "default_chunk": 65536,
      "default_tile": 8192,
      "executables": [
        {"name": "stats_partial_d2_k4_c65536", "file": "a.hlo.txt",
         "sha256": "x", "kind": "stats_partial", "d": 2, "k": 4,
         "chunk": 65536, "tile_n": 8192,
         "inputs": [{"name": "x", "shape": [65536, 2], "dtype": "float32"},
                    {"name": "mu", "shape": [4, 2], "dtype": "float32"},
                    {"name": "n_valid", "shape": [1], "dtype": "int32"}],
         "outputs": [{"name": "sums", "shape": [4, 2], "dtype": "float32"}]},
        {"name": "finalize_d2_k4", "file": "f.hlo.txt",
         "sha256": "y", "kind": "finalize", "d": 2, "k": 4,
         "chunk": 0, "tile_n": 0,
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.default_chunk, 65536);
        assert_eq!(m.executables.len(), 2);
        let e = &m.executables[0];
        assert_eq!(e.kind, ExecKind::StatsPartial);
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![65536, 2]);
        assert_eq!(e.inputs[2].dtype, DType::I32);
        assert_eq!(e.inputs[0].elements(), 131072);
    }

    #[test]
    fn find_by_kind() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.find(ExecKind::StatsPartial, 2, 4, 65536).is_ok());
        assert!(m.find(ExecKind::StatsPartial, 2, 4, 123).is_err());
        // finalize ignores chunk
        assert!(m.find(ExecKind::Finalize, 2, 4, 999).is_ok());
        assert!(m.find(ExecKind::Finalize, 3, 4, 0).is_err());
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("\"format\": 1", "\"format\": 9");
        assert!(Manifest::parse(&bad, Path::new("/t")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("float32", "float64");
        assert!(Manifest::parse(&bad, Path::new("/t")).is_err());
    }

    #[test]
    fn hlo_path_joins() {
        let m = Manifest::parse(SAMPLE, Path::new("/base")).unwrap();
        assert_eq!(
            m.hlo_path(&m.executables[0]),
            PathBuf::from("/base/a.hlo.txt")
        );
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // integration guard: if `make artifacts` has run, the real
        // manifest must parse and contain every (d, k) the eval needs
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for (d, k) in [(2, 4), (2, 8), (2, 11), (3, 4), (3, 8), (3, 11)] {
            m.find(ExecKind::StatsPartial, d, k, m.default_chunk).unwrap();
            m.find(ExecKind::Assign, d, k, m.default_chunk).unwrap();
            m.find(ExecKind::FusedStats, d, k, m.default_chunk).unwrap();
            m.find(ExecKind::Finalize, d, k, 0).unwrap();
        }
    }
}
