//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin). The interchange
//! format is HLO *text* — see `python/compile/aot.py` for why (the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos).
//!
//! `PjRtClient` holds an `Rc` internally, so nothing here is `Send`:
//! each engine (or worker) constructs its own [`Runtime`]. Compilation
//! is cached per runtime keyed by executable name.

pub mod client;
pub mod manifest;

pub use client::{Runtime, TensorArg, TensorOut};
pub use manifest::{ExecSpec, Manifest, TensorSpec};
