//! Execution runtime: resolve AOT executable specs and run them.
//!
//! The manifest (`python/compile/aot.py`, HLO text interchange) remains
//! the contract between the python compile path and the rust request
//! path. Execution is handled by the in-crate [`native`] backend — the
//! SIMD kernel subsystem — because the offline image ships no `xla`
//! crate; [`client`] keeps the PJRT-shaped API (prepare/execute/
//! device buffers) so a real PJRT backend can return behind it, and
//! synthesizes the standard shape matrix when no artifacts exist.
//!
//! Each engine (or worker) constructs its own [`Runtime`]; preparation
//! is cached per runtime keyed by executable name.

pub mod client;
pub mod manifest;
pub mod native;

pub use client::{DeviceBuffer, Runtime, TensorArg, TensorOut};
pub use manifest::{ExecSpec, Manifest, TensorSpec};
