//! Native CPU executor for the AOT executable contract.
//!
//! The artifact manifest defines four executable kinds (`stats_partial`,
//! `assign`, `fused_stats`, `finalize`) with fixed shapes and padding
//! semantics (`n_valid` masks the tail of a chunk). This module
//! implements those semantics directly on the
//! [`crate::linalg::kernel`] subsystem, so every coordinator engine
//! (shared / offload / streaming) and the serving batcher run the same
//! SIMD-dispatched hot path as the pure-rust engines — with or without
//! compiled XLA artifacts on disk.
//!
//! When no `manifest.json` exists, specs are synthesized on demand
//! ([`synthesize_spec`] — any d/k shape), with [`synthetic_manifest`]
//! enumerating the standard matrix (the families
//! `python/compile/aot.py` lowers, up to [`MAX_D`]/[`MAX_K`]) for
//! display and iteration; when a real manifest exists it is honored
//! verbatim (names, shapes, chunk sizes).

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::linalg::kernel;
use crate::runtime::client::TensorOut;
use crate::runtime::manifest::{DType, ExecKind, ExecSpec, Manifest, TensorSpec};

/// Chunk sizes the synthetic manifest offers (superset of the AOT
/// pipeline's `CHUNKS` + ablation sizes, so every pinned-chunk config
/// keeps working without artifacts).
pub const CHUNKS: [usize; 4] = [4096, 16384, 65536, 262144];

/// Default chunk mirrored from `python/compile/aot.py`.
pub const DEFAULT_CHUNK: usize = 65536;

/// Largest dimensionality the synthetic manifest covers.
pub const MAX_D: usize = 8;

/// Largest cluster count the synthetic manifest covers.
pub const MAX_K: usize = 16;

fn tensor(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype }
}

fn chunked_spec(kind: ExecKind, d: usize, k: usize, chunk: usize) -> ExecSpec {
    let (prefix, inputs, outputs): (&str, Vec<TensorSpec>, Vec<TensorSpec>) = match kind {
        ExecKind::StatsPartial => (
            "stats_partial",
            vec![
                tensor("x", &[chunk, d], DType::F32),
                tensor("mu", &[k, d], DType::F32),
                tensor("n_valid", &[1], DType::I32),
            ],
            vec![
                tensor("sums", &[k, d], DType::F32),
                tensor("counts", &[k], DType::F32),
                tensor("sse", &[1], DType::F32),
            ],
        ),
        ExecKind::Assign => (
            "assign",
            vec![
                tensor("x", &[chunk, d], DType::F32),
                tensor("mu", &[k, d], DType::F32),
                tensor("n_valid", &[1], DType::I32),
            ],
            vec![tensor("assign", &[chunk], DType::I32)],
        ),
        ExecKind::FusedStats => (
            "fused_stats",
            vec![
                tensor("x", &[chunk, d], DType::F32),
                tensor("mu", &[k, d], DType::F32),
                // accumulator names mirror python/compile/aot.py
                tensor("acc_sums", &[k, d], DType::F32),
                tensor("acc_counts", &[k], DType::F32),
                tensor("acc_sse", &[1], DType::F32),
                tensor("n_valid", &[1], DType::I32),
            ],
            vec![
                tensor("sums", &[k, d], DType::F32),
                tensor("counts", &[k], DType::F32),
                tensor("sse", &[1], DType::F32),
            ],
        ),
        ExecKind::Finalize => unreachable!("finalize has no chunk"),
    };
    ExecSpec {
        name: format!("{prefix}_d{d}_k{k}_c{chunk}"),
        file: String::new(), // no artifact on disk; executed natively
        kind,
        d,
        k,
        chunk,
        tile_n: chunk.min(8192),
        inputs,
        outputs,
    }
}

fn finalize_spec(d: usize, k: usize) -> ExecSpec {
    ExecSpec {
        name: format!("finalize_d{d}_k{k}"),
        file: String::new(),
        kind: ExecKind::Finalize,
        d,
        k,
        chunk: 0,
        tile_n: 0,
        inputs: vec![
            tensor("sums", &[k, d], DType::F32),
            tensor("counts", &[k], DType::F32),
            tensor("mu_old", &[k, d], DType::F32),
        ],
        outputs: vec![
            tensor("mu_new", &[k, d], DType::F32),
            tensor("shift", &[1], DType::F32),
        ],
    }
}

/// Synthesize a single executable spec on demand. The native executor
/// supports any shape, so artifact-free operation is not capped by the
/// pre-enumerated matrix below — [`crate::runtime::Runtime::find`]
/// calls this directly in fallback mode.
pub fn synthesize_spec(kind: ExecKind, d: usize, k: usize, chunk: usize) -> Result<ExecSpec> {
    if d == 0 || k == 0 {
        return Err(Error::Config(format!("degenerate executable shape d={d} k={k}")));
    }
    if kind == ExecKind::Finalize {
        return Ok(finalize_spec(d, k));
    }
    if chunk == 0 {
        return Err(Error::Config(format!("{kind:?} requires a chunk size >= 1")));
    }
    Ok(chunked_spec(kind, d, k, chunk))
}

/// The standard shape matrix for artifact-free operation — an
/// enumeration surface for manifest iteration only (lookups go through
/// [`synthesize_spec`] and are not bounded by it). Built lazily, once
/// per process: the ~1.6k-spec enumeration is never allocated on the
/// engines' fallback path.
pub fn synthetic_manifest() -> &'static Manifest {
    static SYNTH: std::sync::OnceLock<Manifest> = std::sync::OnceLock::new();
    SYNTH.get_or_init(|| {
        let mut executables = Vec::new();
        for d in 1..=MAX_D {
            for k in 1..=MAX_K {
                for &chunk in &CHUNKS {
                    executables.push(chunked_spec(ExecKind::StatsPartial, d, k, chunk));
                    executables.push(chunked_spec(ExecKind::Assign, d, k, chunk));
                    executables.push(chunked_spec(ExecKind::FusedStats, d, k, chunk));
                }
                executables.push(finalize_spec(d, k));
            }
        }
        Manifest {
            dir: PathBuf::from("<native>"),
            default_chunk: DEFAULT_CHUNK,
            executables,
        }
    })
}

/// A typed, borrowed executable input.
pub enum ArgView<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> ArgView<'a> {
    fn dtype(&self) -> DType {
        match self {
            ArgView::F32(_) => DType::F32,
            ArgView::I32(_) => DType::I32,
        }
    }

    fn len(&self) -> usize {
        match self {
            ArgView::F32(v) => v.len(),
            ArgView::I32(v) => v.len(),
        }
    }

    fn as_f32(&self) -> &'a [f32] {
        match self {
            ArgView::F32(v) => v,
            ArgView::I32(_) => unreachable!("dtype validated against spec"),
        }
    }

    fn as_i32(&self) -> &'a [i32] {
        match self {
            ArgView::I32(v) => v,
            ArgView::F32(_) => unreachable!("dtype validated against spec"),
        }
    }
}

/// Validate `args` against the spec signature (arity, dtype, length).
pub fn validate_args(spec: &ExecSpec, args: &[ArgView]) -> Result<()> {
    if args.len() != spec.inputs.len() {
        return Err(Error::Shape(format!(
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            args.len()
        )));
    }
    for (arg, input) in args.iter().zip(&spec.inputs) {
        if arg.dtype() != input.dtype || arg.len() != input.elements() {
            return Err(Error::Shape(format!(
                "{}: input `{}` expects {:?}×{}, got {:?}×{}",
                spec.name,
                input.name,
                input.dtype,
                input.elements(),
                arg.dtype(),
                arg.len()
            )));
        }
    }
    Ok(())
}

/// Index of the input named `name` (positional fallback for manifests
/// with differing names but the canonical order).
fn input_idx(spec: &ExecSpec, name: &str, fallback: usize) -> usize {
    spec.inputs
        .iter()
        .position(|t| t.name == name)
        .unwrap_or(fallback)
}

/// Execute `spec` natively. `args` must already be validated.
pub fn execute(spec: &ExecSpec, args: &[ArgView]) -> Result<Vec<TensorOut>> {
    let (d, k, chunk) = (spec.d, spec.k, spec.chunk);
    if k == 0 || d == 0 {
        return Err(Error::Config(format!("{}: degenerate shape d={d} k={k}", spec.name)));
    }
    match spec.kind {
        ExecKind::StatsPartial | ExecKind::FusedStats | ExecKind::Assign => {
            let x = args[input_idx(spec, "x", 0)].as_f32();
            let mu = args[input_idx(spec, "mu", 1)].as_f32();
            let nv_pos = if spec.kind == ExecKind::FusedStats { 5 } else { 2 };
            let nv = args[input_idx(spec, "n_valid", nv_pos)].as_i32();
            let n_valid = (nv[0].max(0) as usize).min(chunk);
            let rows = &x[..n_valid * d];

            // assign output is chunk-shaped only for the Assign kind
            // (padding lanes stay -1); the stats kinds drop it, so
            // scratch is sized to the valid rows
            let out_len = if spec.kind == ExecKind::Assign { chunk } else { n_valid };
            let mut assign = vec![-1i32; out_len];
            let mut sums = vec![0.0f64; k * d];
            let mut counts = vec![0u64; k];
            let mut sse = 0.0f64;
            kernel::assign_accumulate(
                rows,
                d,
                mu,
                k,
                &mut assign[..n_valid],
                &mut sums,
                &mut counts,
                &mut sse,
                kernel::active_tier(),
            );

            if spec.kind == ExecKind::Assign {
                return Ok(vec![TensorOut::I32(assign)]);
            }

            let (mut sums_f, mut counts_f, mut sse_f) =
                (vec![0.0f32; k * d], vec![0.0f32; k], 0.0f32);
            if spec.kind == ExecKind::FusedStats {
                // thread the device-resident accumulators through
                sums_f.copy_from_slice(args[input_idx(spec, "acc_sums", 2)].as_f32());
                counts_f.copy_from_slice(args[input_idx(spec, "acc_counts", 3)].as_f32());
                sse_f = args[input_idx(spec, "acc_sse", 4)].as_f32()[0];
            }
            for (o, &v) in sums_f.iter_mut().zip(&sums) {
                *o += v as f32;
            }
            for (o, &v) in counts_f.iter_mut().zip(&counts) {
                *o += v as f32;
            }
            sse_f += sse as f32;
            Ok(vec![
                TensorOut::F32(sums_f),
                TensorOut::F32(counts_f),
                TensorOut::F32(vec![sse_f]),
            ])
        }
        ExecKind::Finalize => {
            let sums = args[input_idx(spec, "sums", 0)].as_f32();
            let counts = args[input_idx(spec, "counts", 1)].as_f32();
            let mu_old = args[input_idx(spec, "mu_old", 2)].as_f32();
            let mut mu_new = vec![0.0f32; k * d];
            let mut shift = 0.0f64;
            for c in 0..k {
                let cnt = counts[c];
                for j in 0..d {
                    let idx = c * d + j;
                    let v = if cnt > 0.0 { sums[idx] / cnt } else { mu_old[idx] };
                    mu_new[idx] = v;
                    let diff = (v - mu_old[idx]) as f64;
                    shift += diff * diff;
                }
            }
            Ok(vec![TensorOut::F32(mu_new), TensorOut::F32(vec![shift as f32])])
        }
    }
}

/// Light structural validation of an HLO text artifact (real-manifest
/// mode): the native executor does not interpret HLO, but a missing or
/// visibly truncated file must still fail at `prepare`, like a real
/// compile would.
pub fn validate_hlo_text(path: &std::path::Path) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let mut depth: i64 = 0;
    for b in text.bytes() {
        match b {
            b'{' => depth += 1,
            b'}' => depth -= 1,
            _ => {}
        }
    }
    if !text.starts_with("HloModule")
        || !text.contains("ENTRY")
        || !text.contains("ROOT")
        || depth != 0
    {
        return Err(Error::Manifest(format!(
            "{}: malformed HLO text (truncated or corrupted artifact)",
            path.display()
        )));
    }
    Ok(())
}

/// Group the native-fallback capabilities for display (`parakm info`).
pub fn synthetic_summary() -> BTreeMap<&'static str, String> {
    let mut m = BTreeMap::new();
    m.insert("backend", "native (in-process SIMD kernels)".to_string());
    m.insert("shapes", "any d/k (specs synthesized on demand)".to_string());
    m.insert("chunks", format!("{CHUNKS:?}"));
    m.insert("kernel tier", kernel::active_tier().to_string());
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_covers_paper_matrix() {
        let m = synthetic_manifest();
        for (d, k) in [(2usize, 4usize), (2, 8), (2, 11), (3, 4), (3, 8), (3, 11)] {
            for kind in [ExecKind::StatsPartial, ExecKind::Assign, ExecKind::FusedStats] {
                for &c in &CHUNKS {
                    m.find(kind, d, k, c).unwrap();
                }
            }
            m.find(ExecKind::Finalize, d, k, 0).unwrap();
        }
        assert!(m.find(ExecKind::StatsPartial, 2, MAX_K + 1, DEFAULT_CHUNK).is_err());
    }

    #[test]
    fn stats_partial_matches_python_contract() {
        // mirror of runtime::client::tests::stats_and_assign_execute_correctly
        let spec = chunked_spec(ExecKind::StatsPartial, 2, 4, 4096);
        let mut x = vec![0.0f32; 4096 * 2];
        x[0..2].copy_from_slice(&[0.1, 0.0]);
        x[2..4].copy_from_slice(&[10.0, 9.9]);
        x[4..6].copy_from_slice(&[0.0, 0.2]);
        let mu = vec![0.0f32, 0.0, 10.0, 10.0, -50.0, -50.0, 50.0, 50.0];
        let nv = [3i32];
        let args = [ArgView::F32(&x), ArgView::F32(&mu), ArgView::I32(&nv)];
        validate_args(&spec, &args).unwrap();
        let outs = execute(&spec, &args).unwrap();
        let sums = outs[0].as_f32();
        assert!((sums[0] - 0.1).abs() < 1e-5);
        assert!((sums[1] - 0.2).abs() < 1e-5);
        assert!((sums[2] - 10.0).abs() < 1e-4);
        assert_eq!(outs[1].as_f32(), &[2.0, 1.0, 0.0, 0.0]);
        let sse = outs[2].as_f32()[0];
        assert!((sse - 0.06).abs() < 1e-4, "sse {sse}");

        let aspec = chunked_spec(ExecKind::Assign, 2, 4, 4096);
        let outs = execute(&aspec, &args).unwrap();
        let assign = outs[0].as_i32();
        assert_eq!(&assign[0..3], &[0, 1, 0]);
        assert!(assign[3..].iter().all(|&a| a == -1));
    }

    #[test]
    fn fused_stats_accumulates_through_calls() {
        let spec = chunked_spec(ExecKind::FusedStats, 2, 2, 4096);
        let mut x = vec![0.0f32; 4096 * 2];
        x[0..2].copy_from_slice(&[1.0, 0.0]);
        let mu = vec![0.0f32, 0.0, 10.0, 10.0];
        let nv = [1i32];
        let zero_s = vec![0.0f32; 4];
        let zero_c = vec![0.0f32; 2];
        let zero_e = vec![0.0f32; 1];
        let args = [
            ArgView::F32(&x),
            ArgView::F32(&mu),
            ArgView::F32(&zero_s),
            ArgView::F32(&zero_c),
            ArgView::F32(&zero_e),
            ArgView::I32(&nv),
        ];
        let outs = execute(&spec, &args).unwrap();
        let (s1, c1, e1) =
            (outs[0].as_f32().to_vec(), outs[1].as_f32().to_vec(), outs[2].as_f32().to_vec());
        assert_eq!(c1, vec![1.0, 0.0]);
        // second call seeded with the first call's accumulators
        let args2 = [
            ArgView::F32(&x),
            ArgView::F32(&mu),
            ArgView::F32(&s1),
            ArgView::F32(&c1),
            ArgView::F32(&e1),
            ArgView::I32(&nv),
        ];
        let outs2 = execute(&spec, &args2).unwrap();
        assert_eq!(outs2[1].as_f32(), &[2.0, 0.0]);
        assert!((outs2[0].as_f32()[0] - 2.0).abs() < 1e-6);
        assert!((outs2[2].as_f32()[0] - 2.0 * e1[0]).abs() < 1e-6);
    }

    #[test]
    fn finalize_matches_step_semantics() {
        let spec = finalize_spec(3, 4);
        let sums = vec![
            2.0f32, 4.0, 6.0, 0.0, 0.0, 0.0, 3.0, 3.0, 3.0, 8.0, 8.0, 8.0,
        ];
        let counts = vec![2.0f32, 0.0, 3.0, 4.0];
        let mu_old = vec![
            1.0f32, 2.0, 3.0, 9.0, 9.0, 9.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0,
        ];
        let outs = execute(
            &spec,
            &[ArgView::F32(&sums), ArgView::F32(&counts), ArgView::F32(&mu_old)],
        )
        .unwrap();
        let mu_new = outs[0].as_f32();
        assert_eq!(&mu_new[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&mu_new[3..6], &[9.0, 9.0, 9.0]); // empty keeps old
        assert_eq!(&mu_new[6..9], &[1.0, 1.0, 1.0]);
        assert_eq!(&mu_new[9..12], &[2.0, 2.0, 2.0]);
        assert!(outs[1].as_f32()[0].abs() < 1e-6);
    }

    #[test]
    fn validate_rejects_wrong_shapes() {
        let spec = chunked_spec(ExecKind::StatsPartial, 2, 4, 4096);
        let x = vec![0.0f32; 10]; // wrong length
        let mu = vec![0.0f32; 8];
        let nv = [1i32];
        assert!(validate_args(&spec, &[ArgView::F32(&x), ArgView::F32(&mu), ArgView::I32(&nv)])
            .is_err());
        assert!(validate_args(&spec, &[]).is_err());
        // wrong dtype for n_valid
        let big_x = vec![0.0f32; 4096 * 2];
        let bad_nv = [1.0f32];
        assert!(validate_args(
            &spec,
            &[ArgView::F32(&big_x), ArgView::F32(&mu), ArgView::F32(&bad_nv)]
        )
        .is_err());
    }

    #[test]
    fn hlo_validation_flags_truncation() {
        let dir = std::env::temp_dir().join("parakm_native_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule m\n\nENTRY main {\n ROOT t = () tuple()\n}\n").unwrap();
        assert!(validate_hlo_text(&good).is_ok());
        let bad = dir.join("bad.hlo.txt");
        std::fs::write(&bad, "HloModule m\n\nENTRY main {\n ROOT t = (").unwrap();
        assert!(validate_hlo_text(&bad).is_err());
        assert!(validate_hlo_text(&dir.join("missing.hlo.txt")).is_err());
    }
}
