//! PJRT client wrapper: compile-once executable cache + typed execute.
//!
//! One [`Runtime`] per engine (the underlying `PjRtClient` is `Rc`-based
//! and not `Send`). Executables compile lazily on first use and stay
//! cached for the life of the runtime — compilation is setup cost, not
//! request-path cost, and the engines report it separately.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::manifest::{DType, ExecKind, ExecSpec, Manifest, TensorSpec};

/// A typed host-side tensor heading into an executable.
#[derive(Debug, Clone)]
pub enum TensorArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// A typed host-side tensor coming out of an executable.
#[derive(Debug, Clone)]
pub enum TensorOut {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorOut {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            TensorOut::F32(v) => v,
            TensorOut::I32(_) => panic!("expected f32 output, got i32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            TensorOut::I32(v) => v,
            TensorOut::F32(_) => panic!("expected i32 output, got f32"),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            TensorOut::F32(v) => v,
            TensorOut::I32(_) => panic!("expected f32 output, got i32"),
        }
    }

    pub fn into_i32(self) -> Vec<i32> {
        match self {
            TensorOut::I32(v) => v,
            TensorOut::F32(_) => panic!("expected i32 output, got f32"),
        }
    }
}

/// PJRT CPU client + manifest + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative compile time (reported as setup cost by the engines).
    pub compile_secs: f64,
}

impl Runtime {
    /// Create a runtime over the artifacts in `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: HashMap::new(), compile_secs: 0.0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Resolve an executable spec (no compilation yet).
    pub fn find(&self, kind: ExecKind, d: usize, k: usize, chunk: usize) -> Result<ExecSpec> {
        self.manifest.find(kind, d, k, chunk).cloned()
    }

    /// Compile (or fetch cached) an executable.
    pub fn prepare(&mut self, spec: &ExecSpec) -> Result<()> {
        if self.cache.contains_key(&spec.name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(spec);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compile_secs += t0.elapsed().as_secs_f64();
        self.cache.insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Execute `spec` with `args`, validating the signature both ways.
    ///
    /// Returns host tensors in the manifest's output order. The AOT
    /// programs are lowered with `return_tuple=True`; the single result
    /// buffer decomposes into `spec.outputs.len()` literals. Keeping
    /// iteration-loop outputs tiny is the engines' job (§Perf L2-1:
    /// stats-only programs; assignments fetched once after
    /// convergence via the separate `Assign` program).
    pub fn execute(&mut self, spec: &ExecSpec, args: &[TensorArg]) -> Result<Vec<TensorOut>> {
        self.prepare(spec)?;
        let literals = build_literals(spec, args)?;
        let exe = self.cache.get(&spec.name).expect("prepared above");
        let result = exe.execute::<xla::Literal>(&literals)?;
        read_tuple_outputs(&result[0][0], spec)
    }
}

impl Runtime {
    /// Upload an f32 tensor to the device once; reusable across many
    /// `execute_buffers` calls. This is the OpenACC `data copyin`
    /// analog: the engines upload immutable X chunks at setup so the
    /// per-iteration transfer is only the (tiny) centroids.
    pub fn upload_f32(&self, v: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(v, dims, None)?)
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, v: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(v, dims, None)?)
    }

    /// Execute with device-resident inputs (X chunks uploaded once at
    /// setup — the OpenACC `data copyin` analog), fetching the outputs
    /// to the host.
    pub fn execute_buffers(
        &mut self,
        spec: &ExecSpec,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<TensorOut>> {
        self.prepare(spec)?;
        if args.len() != spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                args.len()
            )));
        }
        let exe = self.cache.get(&spec.name).expect("prepared above");
        let result = exe.execute_b::<&xla::PjRtBuffer>(args)?;
        read_tuple_outputs(&result[0][0], spec)
    }
}

/// Decompose the (tuple) result buffer and read each element, typed by
/// the manifest signature.
fn read_tuple_outputs(buf: &xla::PjRtBuffer, spec: &ExecSpec) -> Result<Vec<TensorOut>> {
    let tuple = buf.to_literal_sync()?.to_tuple()?;
    if tuple.len() != spec.outputs.len() {
        return Err(Error::Shape(format!(
            "{}: expected {} outputs, got {}",
            spec.name,
            spec.outputs.len(),
            tuple.len()
        )));
    }
    tuple
        .into_iter()
        .zip(&spec.outputs)
        .map(|(lit, out_spec)| read_literal(&lit, out_spec, &spec.name))
        .collect()
}

/// Typed host copy of one output literal.
fn read_literal(lit: &xla::Literal, out: &TensorSpec, exe: &str) -> Result<TensorOut> {
    let n = lit.element_count();
    if n != out.elements() {
        return Err(Error::Shape(format!(
            "{exe}: output `{}` expects {} elements, got {n}",
            out.name,
            out.elements()
        )));
    }
    Ok(match out.dtype {
        DType::F32 => TensorOut::F32(lit.to_vec::<f32>()?),
        DType::I32 => TensorOut::I32(lit.to_vec::<i32>()?),
    })
}

fn build_literals(spec: &ExecSpec, args: &[TensorArg]) -> Result<Vec<xla::Literal>> {
    if args.len() != spec.inputs.len() {
        return Err(Error::Shape(format!(
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            args.len()
        )));
    }
    args.iter()
        .zip(&spec.inputs)
        .map(|(arg, input)| build_literal(arg, input, &spec.name))
        .collect()
}

fn build_literal(arg: &TensorArg, input: &TensorSpec, exe: &str) -> Result<xla::Literal> {
    let (len, dtype) = match arg {
        TensorArg::F32(v) => (v.len(), DType::F32),
        TensorArg::I32(v) => (v.len(), DType::I32),
    };
    if dtype != input.dtype || len != input.elements() {
        return Err(Error::Shape(format!(
            "{exe}: input `{}` expects {:?}×{}, got {:?}×{}",
            input.name,
            input.dtype,
            input.elements(),
            dtype,
            len
        )));
    }
    // one copy host->literal; bytes reinterpreted in place
    let lit = match arg {
        TensorArg::F32(v) => xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &input.shape,
            bytes_of_f32(v),
        )?,
        TensorArg::I32(v) => xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &input.shape,
            bytes_of_i32(v),
        )?,
    };
    Ok(lit)
}

fn bytes_of_f32(v: &[f32]) -> &[u8] {
    // safety: f32 has no invalid bit patterns; alignment of u8 is 1
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytes_of_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ExecKind;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    /// End-to-end: load real artifacts, execute them, compare against a
    /// hand-computed expectation. This is the rust side of the python
    /// kernel-vs-ref contract.
    #[test]
    fn stats_and_assign_execute_correctly() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::new(&dir).unwrap();
        let chunk = 4096;
        let stats = rt.find(ExecKind::StatsPartial, 2, 4, chunk).unwrap();
        let assign_spec = rt.find(ExecKind::Assign, 2, 4, chunk).unwrap();

        // 3 valid points near obvious centroids, rest padding
        let mut x = vec![0.0f32; chunk * 2];
        x[0..2].copy_from_slice(&[0.1, 0.0]); // -> centroid 0
        x[2..4].copy_from_slice(&[10.0, 9.9]); // -> centroid 1
        x[4..6].copy_from_slice(&[0.0, 0.2]); // -> centroid 0
        let mu = vec![0.0f32, 0.0, 10.0, 10.0, -50.0, -50.0, 50.0, 50.0];
        let nv = vec![3i32];
        let args = [TensorArg::F32(&x), TensorArg::F32(&mu), TensorArg::I32(&nv)];

        let outs = rt.execute(&stats, &args).unwrap();
        let sums = outs[0].as_f32();
        assert!((sums[0] - 0.1).abs() < 1e-5); // cluster 0 x-sum
        assert!((sums[1] - 0.2).abs() < 1e-5);
        assert!((sums[2] - 10.0).abs() < 1e-4); // cluster 1
        let counts = outs[1].as_f32();
        assert_eq!(counts, &[2.0, 1.0, 0.0, 0.0]);
        let sse = outs[2].as_f32()[0];
        // (0.1,0)->c0: 0.01; (10,9.9)->c1: 0.01; (0,0.2)->c0: 0.04
        assert!((sse - 0.06).abs() < 1e-4, "sse {sse}");

        let outs = rt.execute(&assign_spec, &args).unwrap();
        let assign = outs[0].as_i32();
        assert_eq!(&assign[0..3], &[0, 1, 0]);
        assert!(assign[3..].iter().all(|&a| a == -1));
    }

    #[test]
    fn finalize_executes_correctly() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::new(&dir).unwrap();
        let spec = rt.find(ExecKind::Finalize, 3, 4, 0).unwrap();
        let sums = vec![2.0f32, 4.0, 6.0, /* c1 */ 0.0, 0.0, 0.0, /* c2 */ 3.0, 3.0, 3.0, /* c3 */ 8.0, 8.0, 8.0];
        let counts = vec![2.0f32, 0.0, 3.0, 4.0];
        let mu_old = vec![1.0f32, 2.0, 3.0, 9.0, 9.0, 9.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0];
        let outs = rt
            .execute(
                &spec,
                &[
                    TensorArg::F32(&sums),
                    TensorArg::F32(&counts),
                    TensorArg::F32(&mu_old),
                ],
            )
            .unwrap();
        let mu_new = outs[0].as_f32();
        assert_eq!(&mu_new[0..3], &[1.0, 2.0, 3.0]); // sums/2
        assert_eq!(&mu_new[3..6], &[9.0, 9.0, 9.0]); // empty keeps old
        assert_eq!(&mu_new[6..9], &[1.0, 1.0, 1.0]); // sums/3
        assert_eq!(&mu_new[9..12], &[2.0, 2.0, 2.0]); // sums/4
        let shift = outs[0 + 1].as_f32()[0];
        assert!(shift.abs() < 1e-6, "converged case: shift {shift}");
    }

    #[test]
    fn shape_validation_rejects_wrong_args() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::new(&dir).unwrap();
        let chunk = rt.manifest().default_chunk;
        let spec = rt.find(ExecKind::StatsPartial, 2, 4, chunk).unwrap();
        // wrong arity
        assert!(rt.execute(&spec, &[]).is_err());
        // wrong dtype for n_valid
        let x = vec![0.0f32; chunk * 2];
        let mu = vec![0.0f32; 8];
        let bad_nv = vec![3.0f32];
        assert!(rt
            .execute(
                &spec,
                &[TensorArg::F32(&x), TensorArg::F32(&mu), TensorArg::F32(&bad_nv)]
            )
            .is_err());
        // wrong length for x
        let short_x = vec![0.0f32; 10];
        let nv = vec![3i32];
        assert!(rt
            .execute(
                &spec,
                &[TensorArg::F32(&short_x), TensorArg::F32(&mu), TensorArg::I32(&nv)]
            )
            .is_err());
    }

    #[test]
    fn buffer_path_matches_literal_path() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::new(&dir).unwrap();
        let chunk = rt.manifest().default_chunk;
        let spec = rt.find(ExecKind::StatsPartial, 3, 4, chunk).unwrap();
        let mut rng = crate::rng::Pcg64::new(5, 0);
        let x: Vec<f32> = (0..chunk * 3).map(|_| rng.next_f32() * 10.0).collect();
        let mu: Vec<f32> = (0..12).map(|_| rng.next_f32() * 10.0).collect();
        let nv = vec![chunk as i32];

        let via_literal = rt
            .execute(&spec, &[TensorArg::F32(&x), TensorArg::F32(&mu), TensorArg::I32(&nv)])
            .unwrap();
        let xb = rt.upload_f32(&x, &[chunk, 3]).unwrap();
        let mub = rt.upload_f32(&mu, &[4, 3]).unwrap();
        let nvb = rt.upload_i32(&nv, &[1]).unwrap();
        let via_buffers = rt.execute_buffers(&spec, &[&xb, &mub, &nvb]).unwrap();

        assert_eq!(via_literal[0].as_f32(), via_buffers[0].as_f32()); // sums
        assert_eq!(via_literal[1].as_f32(), via_buffers[1].as_f32()); // counts
        assert_eq!(via_literal[2].as_f32(), via_buffers[2].as_f32()); // sse
    }

    #[test]
    fn compile_cache_reused() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::new(&dir).unwrap();
        let spec = rt.find(ExecKind::Finalize, 2, 4, 0).unwrap();
        rt.prepare(&spec).unwrap();
        let t_after_first = rt.compile_secs;
        assert!(t_after_first > 0.0);
        rt.prepare(&spec).unwrap();
        assert_eq!(rt.compile_secs, t_after_first, "second prepare must be cached");
    }
}
