//! Runtime facade: resolve executable specs and run them.
//!
//! Historically this wrapped the PJRT C API through the `xla` crate.
//! The offline image ships no `xla` crate, so execution now goes
//! through the in-crate native backend ([`crate::runtime::native`]) —
//! the same SIMD-dispatched kernels every pure-rust engine uses. The
//! API shape (manifest-driven specs, `prepare` as the compile step,
//! typed `execute`/`execute_buffers`, device-resident buffers) is kept
//! so a real PJRT backend can slot back in behind it.
//!
//! Two construction modes:
//! - [`Runtime::new`] requires `<dir>/manifest.json` (the python AOT
//!   contract) and validates each referenced HLO artifact at
//!   [`Runtime::prepare`] — missing/corrupt artifacts fail like a real
//!   compile would.
//! - [`Runtime::new_or_native`] falls back to the synthetic shape
//!   matrix when no manifest exists, so engines run artifact-free.

use std::collections::HashSet;
use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ExecKind, ExecSpec, Manifest};
use crate::runtime::native::{self, ArgView};

/// A typed host-side tensor heading into an executable.
#[derive(Debug, Clone)]
pub enum TensorArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// A typed host-side tensor coming out of an executable.
#[derive(Debug, Clone)]
pub enum TensorOut {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorOut {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            TensorOut::F32(v) => v,
            TensorOut::I32(_) => panic!("expected f32 output, got i32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            TensorOut::I32(v) => v,
            TensorOut::F32(_) => panic!("expected i32 output, got f32"),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            TensorOut::F32(v) => v,
            TensorOut::I32(_) => panic!("expected f32 output, got i32"),
        }
    }

    pub fn into_i32(self) -> Vec<i32> {
        match self {
            TensorOut::I32(v) => v,
            TensorOut::F32(_) => panic!("expected i32 output, got f32"),
        }
    }
}

/// A "device-resident" tensor: uploaded once, reused across calls (the
/// OpenACC `data copyin` analog). The native backend keeps it host-side.
#[derive(Debug, Clone)]
pub struct DeviceBuffer {
    data: TensorOut,
    #[allow(dead_code)] // shape kept for a future real-PJRT backend
    dims: Vec<usize>,
}

impl DeviceBuffer {
    fn view(&self) -> ArgView<'_> {
        match &self.data {
            TensorOut::F32(v) => ArgView::F32(v),
            TensorOut::I32(v) => ArgView::I32(v),
        }
    }
}

/// Manifest + prepared-executable cache over the native backend.
pub struct Runtime {
    /// Loaded artifact manifest; `None` in native fallback mode, where
    /// specs are synthesized on demand and [`Runtime::manifest`] serves
    /// the shared lazily-built enumeration instead.
    manifest: Option<Manifest>,
    prepared: HashSet<String>,
    /// Cumulative prepare/validation time (reported as setup cost by
    /// the engines, the compile-time analog).
    pub compile_secs: f64,
}

impl Runtime {
    /// Create a runtime over the artifacts in `dir` (manifest required).
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Ok(Runtime {
            manifest: Some(manifest),
            prepared: HashSet::new(),
            compile_secs: 0.0,
        })
    }

    /// Like [`Runtime::new`], but when `dir` holds no manifest, fall
    /// back to the synthetic shape matrix executed natively.
    pub fn new_or_native(dir: &Path) -> Result<Runtime> {
        if dir.join("manifest.json").exists() {
            Runtime::new(dir)
        } else {
            Ok(Runtime::native())
        }
    }

    /// Artifact-free runtime: specs are synthesized on demand.
    pub fn native() -> Runtime {
        Runtime { manifest: None, prepared: HashSet::new(), compile_secs: 0.0 }
    }

    /// Whether this runtime synthesizes its specs (no artifacts).
    pub fn is_native_fallback(&self) -> bool {
        self.manifest.is_none()
    }

    pub fn manifest(&self) -> &Manifest {
        match &self.manifest {
            Some(m) => m,
            None => native::synthetic_manifest(),
        }
    }

    /// Resolve an executable spec (no preparation yet). In native
    /// fallback mode specs are synthesized on demand, so any (d, k)
    /// shape resolves — artifact-free operation has no model-size
    /// ceiling beyond the dataset itself.
    pub fn find(&self, kind: ExecKind, d: usize, k: usize, chunk: usize) -> Result<ExecSpec> {
        match &self.manifest {
            Some(m) => m.find(kind, d, k, chunk).cloned(),
            None => native::synthesize_spec(kind, d, k, chunk),
        }
    }

    /// Prepare an executable: for on-disk manifests this validates the
    /// referenced HLO artifact (the compile step's failure surface);
    /// results are cached per runtime like compiled executables were.
    pub fn prepare(&mut self, spec: &ExecSpec) -> Result<()> {
        if self.prepared.contains(&spec.name) {
            return Ok(());
        }
        let t0 = std::time::Instant::now();
        if let Some(m) = &self.manifest {
            native::validate_hlo_text(&m.hlo_path(spec))?;
        }
        self.compile_secs += t0.elapsed().as_secs_f64().max(1e-9);
        self.prepared.insert(spec.name.clone());
        Ok(())
    }

    /// Execute `spec` with host tensors, validating the signature both
    /// ways. Returns host tensors in the manifest's output order.
    pub fn execute(&mut self, spec: &ExecSpec, args: &[TensorArg]) -> Result<Vec<TensorOut>> {
        self.prepare(spec)?;
        let views: Vec<ArgView> = args
            .iter()
            .map(|a| match a {
                TensorArg::F32(v) => ArgView::F32(v),
                TensorArg::I32(v) => ArgView::I32(v),
            })
            .collect();
        native::validate_args(spec, &views)?;
        native::execute(spec, &views)
    }

    /// Upload an f32 tensor "to the device" once; reusable across many
    /// [`Runtime::execute_buffers`] calls.
    pub fn upload_f32(&self, v: &[f32], dims: &[usize]) -> Result<DeviceBuffer> {
        if v.len() != dims.iter().product::<usize>() {
            return Err(Error::Shape(format!(
                "upload_f32: {} elements vs dims {dims:?}",
                v.len()
            )));
        }
        Ok(DeviceBuffer { data: TensorOut::F32(v.to_vec()), dims: dims.to_vec() })
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, v: &[i32], dims: &[usize]) -> Result<DeviceBuffer> {
        if v.len() != dims.iter().product::<usize>() {
            return Err(Error::Shape(format!(
                "upload_i32: {} elements vs dims {dims:?}",
                v.len()
            )));
        }
        Ok(DeviceBuffer { data: TensorOut::I32(v.to_vec()), dims: dims.to_vec() })
    }

    /// Execute with device-resident inputs (uploaded once at setup).
    pub fn execute_buffers(
        &mut self,
        spec: &ExecSpec,
        args: &[&DeviceBuffer],
    ) -> Result<Vec<TensorOut>> {
        self.prepare(spec)?;
        let views: Vec<ArgView> = args.iter().map(|b| b.view()).collect();
        native::validate_args(spec, &views)?;
        native::execute(spec, &views)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ExecKind;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    /// End-to-end over whichever backend is available: execute the
    /// stats/assign contract and compare against a hand-computed
    /// expectation. This is the rust side of the python kernel-vs-ref
    /// contract.
    #[test]
    fn stats_and_assign_execute_correctly() {
        let mut rt = match artifacts_dir() {
            Some(dir) => Runtime::new(&dir).unwrap(),
            None => Runtime::native(),
        };
        let chunk = 4096;
        let stats = rt.find(ExecKind::StatsPartial, 2, 4, chunk).unwrap();
        let assign_spec = rt.find(ExecKind::Assign, 2, 4, chunk).unwrap();

        // 3 valid points near obvious centroids, rest padding
        let mut x = vec![0.0f32; chunk * 2];
        x[0..2].copy_from_slice(&[0.1, 0.0]); // -> centroid 0
        x[2..4].copy_from_slice(&[10.0, 9.9]); // -> centroid 1
        x[4..6].copy_from_slice(&[0.0, 0.2]); // -> centroid 0
        let mu = vec![0.0f32, 0.0, 10.0, 10.0, -50.0, -50.0, 50.0, 50.0];
        let nv = vec![3i32];
        let args = [TensorArg::F32(&x), TensorArg::F32(&mu), TensorArg::I32(&nv)];

        let outs = rt.execute(&stats, &args).unwrap();
        let sums = outs[0].as_f32();
        assert!((sums[0] - 0.1).abs() < 1e-5); // cluster 0 x-sum
        assert!((sums[1] - 0.2).abs() < 1e-5);
        assert!((sums[2] - 10.0).abs() < 1e-4); // cluster 1
        let counts = outs[1].as_f32();
        assert_eq!(counts, &[2.0, 1.0, 0.0, 0.0]);
        let sse = outs[2].as_f32()[0];
        // (0.1,0)->c0: 0.01; (10,9.9)->c1: 0.01; (0,0.2)->c0: 0.04
        assert!((sse - 0.06).abs() < 1e-4, "sse {sse}");

        let outs = rt.execute(&assign_spec, &args).unwrap();
        let assign = outs[0].as_i32();
        assert_eq!(&assign[0..3], &[0, 1, 0]);
        assert!(assign[3..].iter().all(|&a| a == -1));
    }

    #[test]
    fn finalize_executes_correctly() {
        let mut rt = match artifacts_dir() {
            Some(dir) => Runtime::new(&dir).unwrap(),
            None => Runtime::native(),
        };
        let spec = rt.find(ExecKind::Finalize, 3, 4, 0).unwrap();
        let sums = vec![
            2.0f32, 4.0, 6.0, /* c1 */ 0.0, 0.0, 0.0, /* c2 */ 3.0, 3.0, 3.0,
            /* c3 */ 8.0, 8.0, 8.0,
        ];
        let counts = vec![2.0f32, 0.0, 3.0, 4.0];
        let mu_old = vec![1.0f32, 2.0, 3.0, 9.0, 9.0, 9.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0];
        let outs = rt
            .execute(
                &spec,
                &[
                    TensorArg::F32(&sums),
                    TensorArg::F32(&counts),
                    TensorArg::F32(&mu_old),
                ],
            )
            .unwrap();
        let mu_new = outs[0].as_f32();
        assert_eq!(&mu_new[0..3], &[1.0, 2.0, 3.0]); // sums/2
        assert_eq!(&mu_new[3..6], &[9.0, 9.0, 9.0]); // empty keeps old
        assert_eq!(&mu_new[6..9], &[1.0, 1.0, 1.0]); // sums/3
        assert_eq!(&mu_new[9..12], &[2.0, 2.0, 2.0]); // sums/4
        let shift = outs[1].as_f32()[0];
        assert!(shift.abs() < 1e-6, "converged case: shift {shift}");
    }

    #[test]
    fn shape_validation_rejects_wrong_args() {
        let mut rt = Runtime::native();
        let chunk = rt.manifest().default_chunk;
        let spec = rt.find(ExecKind::StatsPartial, 2, 4, chunk).unwrap();
        // wrong arity
        assert!(rt.execute(&spec, &[]).is_err());
        // wrong dtype for n_valid
        let x = vec![0.0f32; chunk * 2];
        let mu = vec![0.0f32; 8];
        let bad_nv = vec![3.0f32];
        assert!(rt
            .execute(
                &spec,
                &[TensorArg::F32(&x), TensorArg::F32(&mu), TensorArg::F32(&bad_nv)]
            )
            .is_err());
        // wrong length for x
        let short_x = vec![0.0f32; 10];
        let nv = vec![3i32];
        assert!(rt
            .execute(
                &spec,
                &[TensorArg::F32(&short_x), TensorArg::F32(&mu), TensorArg::I32(&nv)]
            )
            .is_err());
    }

    #[test]
    fn buffer_path_matches_literal_path() {
        let mut rt = Runtime::native();
        let chunk = 4096;
        let spec = rt.find(ExecKind::StatsPartial, 3, 4, chunk).unwrap();
        let mut rng = crate::rng::Pcg64::new(5, 0);
        let x: Vec<f32> = (0..chunk * 3).map(|_| rng.next_f32() * 10.0).collect();
        let mu: Vec<f32> = (0..12).map(|_| rng.next_f32() * 10.0).collect();
        let nv = vec![chunk as i32];

        let via_literal = rt
            .execute(&spec, &[TensorArg::F32(&x), TensorArg::F32(&mu), TensorArg::I32(&nv)])
            .unwrap();
        let xb = rt.upload_f32(&x, &[chunk, 3]).unwrap();
        let mub = rt.upload_f32(&mu, &[4, 3]).unwrap();
        let nvb = rt.upload_i32(&nv, &[1]).unwrap();
        let via_buffers = rt.execute_buffers(&spec, &[&xb, &mub, &nvb]).unwrap();

        assert_eq!(via_literal[0].as_f32(), via_buffers[0].as_f32()); // sums
        assert_eq!(via_literal[1].as_f32(), via_buffers[1].as_f32()); // counts
        assert_eq!(via_literal[2].as_f32(), via_buffers[2].as_f32()); // sse
    }

    #[test]
    fn upload_validates_dims() {
        let rt = Runtime::native();
        assert!(rt.upload_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(rt.upload_i32(&[1], &[1]).is_ok());
    }

    #[test]
    fn compile_cache_reused() {
        let mut rt = match artifacts_dir() {
            Some(dir) => Runtime::new(&dir).unwrap(),
            None => Runtime::native(),
        };
        let spec = rt.find(ExecKind::Finalize, 2, 4, 0).unwrap();
        rt.prepare(&spec).unwrap();
        let t_after_first = rt.compile_secs;
        assert!(t_after_first > 0.0);
        rt.prepare(&spec).unwrap();
        assert_eq!(rt.compile_secs, t_after_first, "second prepare must be cached");
    }

    #[test]
    fn native_fallback_only_without_manifest() {
        let rt = Runtime::new_or_native(std::path::Path::new("definitely/not/here")).unwrap();
        assert!(rt.is_native_fallback());
        assert!(Runtime::new(std::path::Path::new("definitely/not/here")).is_err());
    }
}
