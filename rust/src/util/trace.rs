//! Process-wide tracing + metrics layer (DESIGN.md §15).
//!
//! Three pieces, all dependency-free:
//!
//! 1. **Metrics registry** — monotonic counters, gauges and log₂-ns
//!    latency histograms ([`Log2Histo`], generalized out of
//!    `serve::histo`) behind one process-global store. The serve loops
//!    dump it via `{"metrics": true}` (one JSON line) or
//!    `{"metrics": "text"}` (Prometheus-style `name value` lines).
//! 2. **Span API** — [`span`]`(Phase::Assign)` returns a guard whose
//!    drop adds the elapsed nanoseconds to the current iteration's
//!    phase accumulator. When tracing is not installed the guard is
//!    inert: one relaxed atomic load and a `None`, no clock read, no
//!    lock — the zero-cost-when-off guarantee `hotpath_micro` pins.
//! 3. **Per-iteration trace events** — engines call [`emit_iter`] at
//!    each iteration boundary; with `--trace FILE` (or `PARAKM_TRACE`)
//!    installed, each call buffers one JSON-lines event
//!    `{iter, sse, empty_events, phase_ns: {...}, per_worker: [...]}`
//!    flushed by [`finish`] through the atomic-write path. With
//!    `--stats-every N` it also prints a live progress line every N
//!    iterations.
//!
//! Tracing never touches the numeric fold: spans wrap call *sites*
//! (leader-side barrier waits, `merge_ordered`, `finalize_counted`,
//! checkpoint saves, wire round trips), never the kernels inside them,
//! so every documented bit-identity contract holds with tracing on or
//! off — `integration_trace.rs` pins this for all eight engines.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::error::Result;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// log₂ histogram (generalized from serve::histo)
// ---------------------------------------------------------------------------

/// Bucket count of a [`Log2Histo`]: bucket 0 holds exact-zero samples,
/// bucket `b` in `1..63` holds `[2^(b-1), 2^b)` ns, and bucket 63 is
/// the explicit saturating overflow bucket for everything `>= 2^62` ns
/// (~146 years — nothing legitimate lands there, but a forged or
/// overflowed sample must not index out of range).
pub const HISTO_BUCKETS: usize = 64;

/// Index of the saturating overflow bucket.
pub const OVERFLOW_BUCKET: usize = HISTO_BUCKETS - 1;

/// A fixed-size log₂-nanosecond histogram: O(1) record, O(buckets)
/// quantile, 520 bytes of state, no allocation.
///
/// Quantiles interpolate linearly *within* a bucket by rank position
/// (midpoint-rank convention), so sub-µs distributions resolve instead
/// of collapsing to a bucket constant; the overflow bucket reports its
/// lower bound `2^62` ns — saturation, stated rather than extrapolated.
#[derive(Debug, Clone)]
pub struct Log2Histo {
    counts: [u64; HISTO_BUCKETS],
    total: u64,
}

impl Default for Log2Histo {
    fn default() -> Self {
        Log2Histo::new()
    }
}

impl Log2Histo {
    pub const fn new() -> Log2Histo {
        Log2Histo { counts: [0; HISTO_BUCKETS], total: 0 }
    }

    /// Bucket index for a nanosecond sample (saturating).
    pub fn bucket_of(ns: u64) -> usize {
        ((64 - ns.leading_zeros()) as usize).min(OVERFLOW_BUCKET)
    }

    /// Record one nanosecond sample.
    pub fn record(&mut self, ns: u64) {
        self.counts[Log2Histo::bucket_of(ns)] += 1;
        self.total += 1;
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Raw bucket counts (diagnostics, tests).
    pub fn buckets(&self) -> &[u64; HISTO_BUCKETS] {
        &self.counts
    }

    /// The `q`-quantile (0 < q <= 1) in nanoseconds; 0.0 when empty.
    ///
    /// The target rank's bucket is located by cumulative walk, then the
    /// rank's position inside the bucket interpolates linearly across
    /// the bucket's value range (midpoint-rank: a bucket holding one
    /// sample reports its middle). The overflow bucket reports `2^62`.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= target {
                if b == 0 {
                    return 0.0; // all samples in this bucket are exactly 0 ns
                }
                if b == OVERFLOW_BUCKET {
                    return (1u64 << 62) as f64; // saturation, not a midpoint
                }
                let lo = (1u64 << (b - 1)) as f64;
                let hi = (1u64 << b) as f64;
                // midpoint-rank position of `target` among the c samples
                let frac = ((target - cum) as f64 - 0.5) / c as f64;
                return lo + frac * (hi - lo);
            }
            cum += c;
        }
        unreachable!("total > 0 guarantees a bucket reaches the target rank");
    }
}

// ---------------------------------------------------------------------------
// phases + spans
// ---------------------------------------------------------------------------

/// The fixed phase vocabulary of an iteration trace event. The JSONL
/// schema's `phase_ns` object carries exactly these six keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Point→centroid assignment + partial-stat accumulation (in the
    /// barrier engines: the leader's wait while workers scan).
    Assign,
    /// Folding partials (`merge_ordered` / event replay).
    Merge,
    /// Centroid finalization (`finalize_counted`).
    Update,
    /// Bound maintenance (Elkan/Hamerly: inter-centroid distances,
    /// bound refresh bookkeeping).
    Bounds,
    /// Network round trips (dist: broadcast + collect).
    Wire,
    /// Checkpoint snapshot writes.
    Ckpt,
}

impl Phase {
    pub const ALL: [Phase; 6] =
        [Phase::Assign, Phase::Merge, Phase::Update, Phase::Bounds, Phase::Wire, Phase::Ckpt];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Assign => "assign",
            Phase::Merge => "merge",
            Phase::Update => "update",
            Phase::Bounds => "bounds",
            Phase::Wire => "wire",
            Phase::Ckpt => "ckpt",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::Assign => 0,
            Phase::Merge => 1,
            Phase::Update => 2,
            Phase::Bounds => 3,
            Phase::Wire => 4,
            Phase::Ckpt => 5,
        }
    }
}

/// A phase timing guard: created by [`span`], adds its elapsed
/// nanoseconds to the current iteration's accumulator on drop. Inert
/// (no clock read, no lock) when tracing is not installed.
pub struct Span {
    live: Option<(Phase, Instant)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((phase, t0)) = self.live.take() {
            let ns = t0.elapsed().as_nanos() as u64;
            if let Some(c) = COLLECTOR.lock().unwrap().as_mut() {
                c.cur_phase_ns[phase.idx()] += ns;
            }
        }
    }
}

/// Start timing `phase`. The disabled path is one relaxed atomic load.
#[inline]
pub fn span(phase: Phase) -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { live: None };
    }
    Span { live: Some((phase, Instant::now())) }
}

/// Is the trace collector installed? Cheap enough to gate optional
/// bookkeeping (e.g. per-worker timing aggregation in the dist leader).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// collector: per-iteration trace events + progress lines
// ---------------------------------------------------------------------------

/// One remote worker's shard-side phase timings for an iteration,
/// shipped back piggybacked on `Partials`/`ChunkPartials` (wire v4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPhase {
    /// Worker index (shard order for static dist, agent order elastic).
    pub worker: u64,
    /// Shard-side assign + accumulate nanoseconds.
    pub assign_ns: u64,
    /// Shard-side reply serialization nanoseconds.
    pub ser_ns: u64,
}

struct Collector {
    /// Trace output path (`None`: progress lines only, nothing kept).
    path: Option<PathBuf>,
    /// Buffered JSONL events, flushed by [`finish`].
    lines: String,
    /// Print a progress line every N iterations (0 = never).
    stats_every: u64,
    /// Accumulated phase ns for the iteration being traced.
    cur_phase_ns: [u64; 6],
    /// SSE of the previous emitted iteration (progress-line delta).
    last_sse: f64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static COLLECTOR: Mutex<Option<Collector>> = Mutex::new(None);

// Always-on, lock-free run totals (satellite: `empty_events` must reach
// `{"stats"}`/`{"metrics"}` and bench.json even without --trace).
static ITERATIONS_TOTAL: AtomicU64 = AtomicU64::new(0);
static EMPTY_EVENTS_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Iterations committed process-wide (all engines, all runs).
pub fn iterations_total() -> u64 {
    ITERATIONS_TOTAL.load(Ordering::Relaxed)
}

/// Empty-cluster keep-centroid events process-wide.
pub fn empty_events_total() -> u64 {
    EMPTY_EVENTS_TOTAL.load(Ordering::Relaxed)
}

/// Install the trace collector: `path` receives the JSONL trace on
/// [`finish`] (None = progress lines only); `stats_every` prints a live
/// progress line every N iterations (0 = never). Idempotent; replaces
/// any previous installation.
pub fn install(path: Option<PathBuf>, stats_every: u64) {
    let mut slot = COLLECTOR.lock().unwrap();
    *slot = Some(Collector {
        path,
        lines: String::new(),
        stats_every,
        cur_phase_ns: [0; 6],
        last_sse: f64::NAN,
    });
    ENABLED.store(true, Ordering::Release);
}

/// Flush the buffered trace to its file (atomic temp+rename) and
/// uninstall the collector. Returns the path written, if any.
pub fn finish() -> Result<Option<PathBuf>> {
    let taken = {
        let mut slot = COLLECTOR.lock().unwrap();
        ENABLED.store(false, Ordering::Release);
        slot.take()
    };
    match taken {
        Some(c) => match c.path {
            Some(p) => {
                crate::data::io::atomic_write(&p, c.lines.as_bytes())?;
                Ok(Some(p))
            }
            None => Ok(None),
        },
        None => Ok(None),
    }
}

fn f64_json(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null // JSON has no NaN; pruned engines report null SSE
    }
}

/// Record one committed iteration: drains the phase accumulator into a
/// JSONL event and (every `stats_every` iterations) prints a progress
/// line. `iter` is the 1-based committed iteration count, `sse` the
/// iteration's objective (NaN for pruned engines → JSON null),
/// `empties` its empty-cluster events, `per_worker` any shard-side
/// timings the leader collected. A no-op beyond two relaxed counter
/// adds when tracing is not installed.
pub fn emit_iter(iter: usize, sse: f64, empties: u64, per_worker: &[WorkerPhase]) {
    ITERATIONS_TOTAL.fetch_add(1, Ordering::Relaxed);
    EMPTY_EVENTS_TOTAL.fetch_add(empties, Ordering::Relaxed);
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let mut guard = COLLECTOR.lock().unwrap();
    let Some(c) = guard.as_mut() else { return };
    let phase_ns = std::mem::replace(&mut c.cur_phase_ns, [0; 6]);

    let mut phases = BTreeMap::new();
    for p in Phase::ALL {
        phases.insert(p.name().to_string(), Json::Num(phase_ns[p.idx()] as f64));
    }
    let workers: Vec<Json> = per_worker
        .iter()
        .map(|w| {
            let mut o = BTreeMap::new();
            o.insert("worker".into(), Json::Num(w.worker as f64));
            o.insert("assign_ns".into(), Json::Num(w.assign_ns as f64));
            o.insert("ser_ns".into(), Json::Num(w.ser_ns as f64));
            Json::Obj(o)
        })
        .collect();
    let mut ev = BTreeMap::new();
    ev.insert("iter".into(), Json::Num(iter as f64));
    ev.insert("sse".into(), f64_json(sse));
    ev.insert("empty_events".into(), Json::Num(empties as f64));
    ev.insert("phase_ns".into(), Json::Obj(phases));
    ev.insert("per_worker".into(), Json::Arr(workers));
    if c.path.is_some() {
        c.lines.push_str(&Json::Obj(ev).to_string());
        c.lines.push('\n');
    }

    if c.stats_every > 0 && iter as u64 % c.stats_every == 0 {
        let delta = sse - c.last_sse;
        let sse_s = if sse.is_finite() { format!("{sse:.6e}") } else { "n/a".into() };
        let delta_s = if delta.is_finite() { format!("{delta:+.3e}") } else { "n/a".into() };
        let mut phases_s = String::new();
        for p in Phase::ALL {
            let ns = phase_ns[p.idx()];
            if ns > 0 {
                if !phases_s.is_empty() {
                    phases_s.push(' ');
                }
                phases_s.push_str(&format!("{}={:.2}ms", p.name(), ns as f64 / 1e6));
            }
        }
        let redispatched = counter_get("dist_redispatched_chunks_total");
        let tail = if redispatched > 0 {
            format!(" redispatched={redispatched}")
        } else {
            String::new()
        };
        eprintln!("iter {iter}: sse {sse_s} (Δ {delta_s}) {phases_s}{tail}");
    }
    c.last_sse = sse;
}

// ---------------------------------------------------------------------------
// metrics registry
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    histos: BTreeMap<&'static str, Log2Histo>,
}

static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

fn with_registry<T>(f: impl FnOnce(&mut Registry) -> T) -> T {
    let mut guard = REGISTRY.lock().unwrap();
    f(guard.get_or_insert_with(Registry::default))
}

/// Add to a monotonic counter (created at zero on first touch).
pub fn counter_add(name: &'static str, v: u64) {
    with_registry(|r| *r.counters.entry(name).or_insert(0) += v);
}

/// Current value of a counter (0 if never touched).
pub fn counter_get(name: &str) -> u64 {
    with_registry(|r| r.counters.get(name).copied().unwrap_or(0))
}

/// Set a gauge to an instantaneous value.
pub fn gauge_set(name: &'static str, v: f64) {
    with_registry(|r| {
        r.gauges.insert(name, v);
    });
}

/// Record a nanosecond sample into a named log₂ histogram.
pub fn histo_record_ns(name: &'static str, ns: u64) {
    with_registry(|r| r.histos.entry(name).or_insert_with(Log2Histo::new).record(ns));
}

/// Snapshot the whole registry (plus the always-on run totals) as one
/// JSON object — the `{"metrics": true}` serve payload. Callers may
/// merge additional fields before rendering.
pub fn metrics_snapshot() -> Json {
    with_registry(|r| {
        let mut o = BTreeMap::new();
        o.insert("iterations_total".into(), Json::Num(iterations_total() as f64));
        o.insert("empty_events_total".into(), Json::Num(empty_events_total() as f64));
        for (k, v) in &r.counters {
            o.insert((*k).to_string(), Json::Num(*v as f64));
        }
        for (k, v) in &r.gauges {
            o.insert((*k).to_string(), f64_json(*v));
        }
        for (k, h) in &r.histos {
            o.insert(format!("{k}_count"), Json::Num(h.count() as f64));
            o.insert(format!("{k}_p50_ns"), Json::Num(h.quantile_ns(0.50)));
            o.insert(format!("{k}_p99_ns"), Json::Num(h.quantile_ns(0.99)));
        }
        Json::Obj(o)
    })
}

/// Render a JSON object of flat numeric metrics as Prometheus-style
/// text: one `name value` line per field, terminated by `# EOF` (the
/// OpenMetrics end marker, which doubles as the line-protocol
/// terminator for `{"metrics": "text"}` scrapes).
pub fn metrics_text_from(snapshot: &Json) -> String {
    let mut out = String::new();
    if let Json::Obj(m) = snapshot {
        for (k, v) in m {
            match v {
                Json::Num(n) => {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{k} {}\n", *n as i64));
                    } else {
                        out.push_str(&format!("{k} {n}\n"));
                    }
                }
                Json::Null => out.push_str(&format!("{k} NaN\n")),
                _ => {}
            }
        }
    }
    out.push_str("# EOF\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // trace-collector tests share process-global state with everything
    // else in the test binary; serialize them against each other
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn histo_empty_reports_zero() {
        let h = Log2Histo::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_ns(0.5), 0.0);
        assert_eq!(h.quantile_ns(0.99), 0.0);
    }

    #[test]
    fn histo_single_sample_dominates_every_quantile() {
        let mut h = Log2Histo::new();
        h.record(500);
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert_eq!(p50, p99, "one sample must pin every quantile to one value");
        // midpoint-rank interpolation: the single sample reports its
        // bucket's middle, inside [256, 512)'s range
        assert!((256.0..=512.0).contains(&p50), "{p50}");
    }

    #[test]
    fn histo_interpolates_within_a_bucket() {
        // 100 samples all inside bucket [512, 1024): the old midpoint
        // rule collapsed p50 == p99; interpolation must resolve ranks
        let mut h = Log2Histo::new();
        for i in 0..100u64 {
            h.record(600 + i);
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 < p99, "interpolation must separate ranks: p50 {p50} p99 {p99}");
        assert!((512.0..1024.0).contains(&p50), "{p50}");
        assert!((512.0..1024.0).contains(&p99), "{p99}");
        // p50 lands near the bucket's middle, p99 near its top
        assert!(p50 < 800.0 && p99 > 950.0, "p50 {p50} p99 {p99}");
    }

    #[test]
    fn histo_overflow_bucket_saturates() {
        let mut h = Log2Histo::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        h.record(1u64 << 62);
        assert_eq!(h.buckets()[OVERFLOW_BUCKET], 3);
        let bound = (1u64 << 62) as f64;
        assert_eq!(h.quantile_ns(0.5), bound);
        assert_eq!(h.quantile_ns(0.99), bound, "overflow reports its lower bound, saturated");
    }

    #[test]
    fn histo_zero_samples_stay_zero() {
        let mut h = Log2Histo::new();
        for _ in 0..10 {
            h.record(0);
        }
        assert_eq!(h.quantile_ns(0.5), 0.0);
        assert_eq!(h.quantile_ns(1.0), 0.0);
    }

    #[test]
    fn histo_quantiles_are_monotone() {
        let mut h = Log2Histo::new();
        for i in 1..=1000u64 {
            h.record(i * 137);
        }
        let mut prev = 0.0;
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let v = h.quantile_ns(q);
            assert!(v >= prev, "quantiles must be monotone: q={q} {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn span_is_inert_when_disabled() {
        let _guard = TEST_LOCK.lock().unwrap();
        assert!(!enabled());
        let s = span(Phase::Assign);
        assert!(s.live.is_none(), "disabled span must not read the clock");
        drop(s);
    }

    #[test]
    fn emit_roundtrips_through_jsonl_schema() {
        let _guard = TEST_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join("parakm_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unit.jsonl");
        install(Some(path.clone()), 0);

        {
            let _s = span(Phase::Merge);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        emit_iter(777_001, 123.5, 2, &[WorkerPhase { worker: 0, assign_ns: 42, ser_ns: 7 }]);
        emit_iter(777_002, f64::NAN, 0, &[]);
        let written = finish().unwrap().expect("path was installed");
        assert_eq!(written, path);
        assert!(!enabled(), "finish() must disable tracing");

        let text = std::fs::read_to_string(&path).unwrap();
        let mut seen_one = false;
        let mut seen_two = false;
        for line in text.lines() {
            let j = Json::parse(line).expect("every trace line parses");
            for key in ["iter", "sse", "empty_events", "phase_ns", "per_worker"] {
                assert!(j.get(key).is_some(), "line missing `{key}`: {line}");
            }
            let phases = j.get("phase_ns").unwrap();
            for p in Phase::ALL {
                assert!(phases.get(p.name()).is_some(), "phase_ns missing {}", p.name());
            }
            match j.get("iter").and_then(Json::as_usize) {
                Some(777_001) => {
                    seen_one = true;
                    assert_eq!(j.get("sse").unwrap().as_f64(), Some(123.5));
                    assert!(
                        phases.get("merge").unwrap().as_f64().unwrap() >= 1e6,
                        "merge span must have recorded ~2ms"
                    );
                    let w = j.get("per_worker").unwrap().as_arr().unwrap();
                    assert_eq!(w.len(), 1);
                    assert_eq!(w[0].get("assign_ns").unwrap().as_f64(), Some(42.0));
                    assert_eq!(w[0].get("ser_ns").unwrap().as_f64(), Some(7.0));
                }
                Some(777_002) => {
                    seen_two = true;
                    assert_eq!(j.get("sse"), Some(&Json::Null), "NaN SSE serializes as null");
                }
                _ => {} // concurrent engine tests may emit their own lines
            }
        }
        assert!(seen_one && seen_two, "both unit events must land in the file");
    }

    #[test]
    fn registry_counters_gauges_histos_render() {
        counter_add("unit_test_counter_total", 3);
        counter_add("unit_test_counter_total", 4);
        assert_eq!(counter_get("unit_test_counter_total"), 7);
        gauge_set("unit_test_gauge", 1.5);
        histo_record_ns("unit_test_lat", 1000);

        let snap = metrics_snapshot();
        assert_eq!(
            snap.get("unit_test_counter_total").and_then(Json::as_f64),
            Some(7.0)
        );
        assert_eq!(snap.get("unit_test_gauge").and_then(Json::as_f64), Some(1.5));
        assert_eq!(snap.get("unit_test_lat_count").and_then(Json::as_f64), Some(1.0));
        assert!(snap.get("iterations_total").is_some());
        assert!(snap.get("empty_events_total").is_some());
        // one line, valid JSON
        let line = snap.to_string();
        assert!(!line.contains('\n'));
        Json::parse(&line).unwrap();

        let text = metrics_text_from(&snap);
        assert!(text.contains("unit_test_counter_total 7\n"), "{text}");
        assert!(text.contains("unit_test_gauge 1.5\n"), "{text}");
        assert!(text.ends_with("# EOF\n"), "text scrape must terminate with # EOF");
    }

    #[test]
    fn disabled_emit_only_bumps_run_totals() {
        let _guard = TEST_LOCK.lock().unwrap();
        assert!(!enabled());
        let before = iterations_total();
        emit_iter(1, 1.0, 5, &[]);
        assert_eq!(iterations_total(), before + 1);
        assert!(empty_events_total() >= 5);
    }
}

/// Path/env resolution for the CLI surface: an explicit `--trace FILE`
/// wins, else the `PARAKM_TRACE` env var, else no trace file.
pub fn trace_path_from(flag: Option<&str>) -> Option<PathBuf> {
    match flag {
        Some(p) => Some(PathBuf::from(p)),
        None => std::env::var("PARAKM_TRACE").ok().filter(|s| !s.is_empty()).map(PathBuf::from),
    }
}

/// Aggregate a trace file into per-phase totals: `(events, phase
/// totals in ns indexed like [`Phase::ALL`], total ns)`. Shared by the
/// `eval::report` phase-share section and the CI schema checker.
pub fn phase_totals(path: &Path) -> Result<(usize, [u64; 6], u64)> {
    let text = std::fs::read_to_string(path)?;
    let mut totals = [0u64; 6];
    let mut events = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)?;
        let phases = j.get("phase_ns").ok_or_else(|| {
            crate::error::Error::Data(format!("trace event missing phase_ns: {line}"))
        })?;
        for p in Phase::ALL {
            if let Some(ns) = phases.get(p.name()).and_then(Json::as_f64) {
                totals[p.idx()] += ns as u64;
            }
        }
        events += 1;
    }
    let total: u64 = totals.iter().sum();
    Ok((events, totals, total))
}
