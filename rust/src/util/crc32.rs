//! Incremental CRC32 (IEEE 802.3 polynomial, the zlib/PNG variant).
//!
//! Hand-rolled because the offline image ships no `crc32fast`
//! (DESIGN.md §8). The table is built at compile time; the hasher is
//! incremental so artifact readers can verify streamed bytes without
//! buffering the whole file (no extra allocation on the read path).

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Incremental CRC32 hasher. `update` as bytes arrive, `finish` for
/// the final value; a fresh hasher starts over.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot convenience over [`Crc32`].
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.finish()
}

/// A [`std::io::Read`] adapter that hashes every byte it passes
/// through — artifact readers verify CRC trailers incrementally with
/// zero extra allocation (the satellite requirement on the `.pkd`
/// read path).
pub struct CrcReader<R> {
    inner: R,
    crc: Crc32,
}

impl<R> CrcReader<R> {
    pub fn new(inner: R) -> Self {
        CrcReader { inner, crc: Crc32::new() }
    }

    /// CRC of everything read so far.
    pub fn digest(&self) -> u32 {
        self.crc.finish()
    }
}

impl<R: std::io::Read> std::io::Read for CrcReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical IEEE CRC32 check values
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let whole = crc32(&data);
        let mut h = Crc32::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), whole);
    }

    #[test]
    fn crc_reader_hashes_what_it_reads() {
        use std::io::Read;
        let data: Vec<u8> = (0..200u8).collect();
        let mut r = CrcReader::new(&data[..]);
        let mut sink = Vec::new();
        r.read_to_end(&mut sink).unwrap();
        assert_eq!(sink, data);
        assert_eq!(r.digest(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let data = vec![0xA5u8; 64];
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), base, "flip at {byte}:{bit} undetected");
            }
        }
    }
}
