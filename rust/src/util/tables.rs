//! Plain-text table rendering for paper-style console output.
//!
//! Every eval/bench target prints its rows through this so `cargo bench`
//! output visually matches the paper's tables (EXPERIMENTS.md pastes
//! these blocks verbatim).

/// Render an aligned text table with a title.
pub fn render(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let mut line = String::new();
    for (i, h) in header.iter().enumerate() {
        line.push_str(&format!("| {:<w$} ", h, w = widths[i]));
    }
    line.push('|');
    let sep: String = line
        .chars()
        .map(|c| if c == '|' { '|' } else { '-' })
        .collect();
    out.push_str(&line);
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        let mut line = String::new();
        for (i, cell) in row.iter().enumerate() {
            line.push_str(&format!("| {:<w$} ", cell, w = widths[i]));
        }
        line.push('|');
        out.push_str(&line);
        out.push('\n');
    }
    out
}

/// Format seconds like the paper's tables (6 decimals).
pub fn secs(v: f64) -> String {
    format!("{v:.6}")
}

/// Format a ratio (speedup/efficiency) with 3 decimals.
pub fn ratio(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render(
            "TABLE X",
            &["N", "p = 2"],
            &[
                vec!["100000".into(), "0.680664".into()],
                vec!["500000".into(), "10.988341".into()],
            ],
        );
        assert!(t.starts_with("TABLE X\n"));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 5);
        // all body lines equal width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
        assert!(lines[4].contains("10.988341"));
    }

    #[test]
    fn formatters() {
        assert_eq!(secs(1.5), "1.500000");
        assert_eq!(ratio(0.98765), "0.988");
    }
}
