//! Benchmark harness (criterion is unavailable offline — DESIGN.md §8).
//!
//! Measurement discipline: warmup runs discarded, `repeats` timed runs,
//! report median + MAD (median absolute deviation) — robust to the odd
//! scheduling hiccup on a shared container. Every `rust/benches/*.rs`
//! target uses this via `harness = false`.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One measured statistic set.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Label for reporting (e.g. `"N=500000 K=8 p=4"`).
    pub label: String,
    /// All timed runs, seconds.
    pub runs: Vec<f64>,
}

impl Sample {
    pub fn median(&self) -> f64 {
        percentile(&self.runs, 0.5)
    }

    /// Median absolute deviation (scaled by nothing; raw seconds).
    pub fn mad(&self) -> f64 {
        let m = self.median();
        let devs: Vec<f64> = self.runs.iter().map(|r| (r - m).abs()).collect();
        percentile(&devs, 0.5)
    }

    pub fn min(&self) -> f64 {
        self.runs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.runs.iter().copied().fold(0.0, f64::max)
    }
}

fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Bench runner configuration.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup: usize,
    pub repeats: usize,
    /// Rows per bench case (`PARAKM_BENCH_N`); benches that scale
    /// with dataset size read this so CI can shrink the workload.
    pub n: usize,
    /// Hard cap on total time per case; once exceeded (and >= 1 timed
    /// run exists) remaining repeats are skipped. Keeps the 1M-point
    /// cases from blowing the bench budget.
    pub time_cap: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 1, repeats: 5, n: 200_000, time_cap: Duration::from_secs(120) }
    }
}

impl BenchOpts {
    /// Read overrides from env: PARAKM_BENCH_WARMUP / _REPEATS /
    /// _CAP_SECS / _N. Lets CI shrink the matrix without code edits.
    pub fn from_env() -> Self {
        let mut o = BenchOpts::default();
        if let Ok(v) = std::env::var("PARAKM_BENCH_N") {
            if let Ok(n) = v.parse() {
                o.n = n;
            }
        }
        if let Ok(v) = std::env::var("PARAKM_BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                o.warmup = n;
            }
        }
        if let Ok(v) = std::env::var("PARAKM_BENCH_REPEATS") {
            if let Ok(n) = v.parse() {
                o.repeats = n;
            }
        }
        if let Ok(v) = std::env::var("PARAKM_BENCH_CAP_SECS") {
            if let Ok(n) = v.parse() {
                o.time_cap = Duration::from_secs_f64(n);
            }
        }
        o
    }
}

/// Time one case: `f` is the workload; its return value is black-boxed.
pub fn run_case<T>(label: &str, opts: &BenchOpts, mut f: impl FnMut() -> T) -> Sample {
    let budget_start = Instant::now();
    for _ in 0..opts.warmup {
        std::hint::black_box(f());
        if budget_start.elapsed() > opts.time_cap {
            break;
        }
    }
    let mut runs = Vec::with_capacity(opts.repeats);
    for _ in 0..opts.repeats {
        let t0 = Instant::now();
        std::hint::black_box(f());
        runs.push(t0.elapsed().as_secs_f64());
        if budget_start.elapsed() > opts.time_cap && !runs.is_empty() {
            break;
        }
    }
    Sample { label: label.to_string(), runs }
}

/// One machine-readable perf-trajectory row for `results/bench.json`
/// (the CI artifact future PRs diff — DESIGN.md §11). `speedup` is
/// vs the exact-scalar baseline of the same `(n, d, k)` cell; pass 0.0
/// where no baseline applies.
#[allow(clippy::too_many_arguments)]
pub fn bench_json_row(
    bench: &str,
    engine: &str,
    policy: &str,
    tier: &str,
    n: usize,
    d: usize,
    k: usize,
    ns_per_point: f64,
    speedup: f64,
) -> Json {
    let mut m = BTreeMap::new();
    m.insert("bench".to_string(), Json::Str(bench.to_string()));
    m.insert("engine".to_string(), Json::Str(engine.to_string()));
    m.insert("policy".to_string(), Json::Str(policy.to_string()));
    m.insert("tier".to_string(), Json::Str(tier.to_string()));
    m.insert("n".to_string(), Json::Num(n as f64));
    m.insert("d".to_string(), Json::Num(d as f64));
    m.insert("k".to_string(), Json::Num(k as f64));
    m.insert("ns_per_point_iter".to_string(), Json::Num(ns_per_point));
    m.insert("speedup_vs_exact_scalar".to_string(), Json::Num(speedup));
    // consistency satellites: the process-wide integrity-warning and
    // keep-centroid counters ride along on every row, so a bench run
    // that read a CRC-less artifact (or hit empty clusters) says so in
    // the trajectory the CI diff watches
    m.insert(
        "artifact_warnings".to_string(),
        Json::Num(crate::data::io::artifact_warnings() as f64),
    );
    m.insert(
        "empty_events".to_string(),
        Json::Num(crate::util::trace::empty_events_total() as f64),
    );
    Json::Obj(m)
}

/// One serving-path perf-trajectory row for `results/bench.json`:
/// sustained request latency through a serve loop (`engine` is
/// `"serve-poll"` or `"serve-threads"`), ns per request plus the
/// p50/p99 tail in microseconds. Complements [`bench_json_row`], whose
/// per-point-iteration shape fits training engines, not request/reply
/// serving.
#[allow(clippy::too_many_arguments)]
pub fn bench_json_serve_row(
    bench: &str,
    engine: &str,
    tier: &str,
    requests: usize,
    points_per_request: usize,
    ns_per_request: f64,
    p50_us: f64,
    p99_us: f64,
) -> Json {
    let mut m = BTreeMap::new();
    m.insert("bench".to_string(), Json::Str(bench.to_string()));
    m.insert("engine".to_string(), Json::Str(engine.to_string()));
    m.insert("tier".to_string(), Json::Str(tier.to_string()));
    m.insert("requests".to_string(), Json::Num(requests as f64));
    m.insert("points_per_request".to_string(), Json::Num(points_per_request as f64));
    m.insert("ns_per_request".to_string(), Json::Num(ns_per_request));
    m.insert("p50_us".to_string(), Json::Num(p50_us));
    m.insert("p99_us".to_string(), Json::Num(p99_us));
    Json::Obj(m)
}

/// Append rows to the `results/bench.json` perf trajectory, merging
/// with whatever a previous bench target in the same run already
/// wrote (each target appends; CI uploads the merged file as an
/// artifact). An unreadable or non-array existing file is replaced
/// rather than poisoning the run.
pub fn append_bench_json(path: &Path, rows: Vec<Json>) -> crate::error::Result<()> {
    let mut all = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Arr(a)) => a,
            _ => Vec::new(),
        },
        Err(_) => Vec::new(),
    };
    all.extend(rows);
    crate::data::io::atomic_write(path, Json::Arr(all).to_string().as_bytes())
}

/// Print a sample row in the house bench format (parsed by EXPERIMENTS
/// tooling; keep stable).
pub fn report(s: &Sample) {
    println!(
        "BENCH  {:<44} median={:>10.6}s  mad={:>9.6}s  min={:>10.6}s  runs={}",
        s.label,
        s.median(),
        s.mad(),
        s.min(),
        s.runs.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let s = Sample { label: "t".into(), runs: vec![1.0, 2.0, 100.0] };
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.mad(), 1.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn percentile_interpolates() {
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.5), 2.5);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn run_case_counts_repeats() {
        let opts = BenchOpts {
            warmup: 1,
            repeats: 3,
            time_cap: Duration::from_secs(60),
            ..Default::default()
        };
        let mut calls = 0;
        let s = run_case("x", &opts, || {
            calls += 1;
            calls
        });
        assert_eq!(s.runs.len(), 3);
        assert_eq!(calls, 4); // 1 warmup + 3 timed
    }

    #[test]
    fn bench_json_appends_and_merges() {
        let dir = std::env::temp_dir().join("parakm_bench_json_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("bench.json");
        let row = |e: &str| bench_json_row("t", e, "exact", "scalar", 10, 2, 4, 1.5, 0.0);
        append_bench_json(&path, vec![row("a")]).unwrap();
        append_bench_json(&path, vec![row("b"), row("c")]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("engine").and_then(Json::as_str), Some("b"));
        assert_eq!(arr[0].get("n").and_then(Json::as_usize), Some(10));
        // every row carries the process-wide consistency counters
        assert!(arr[0].get("artifact_warnings").and_then(Json::as_f64).is_some());
        assert!(arr[0].get("empty_events").and_then(Json::as_f64).is_some());
        // corrupt existing file is replaced, not fatal
        std::fs::write(&path, "{not json").unwrap();
        append_bench_json(&path, vec![row("d")]).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_row_carries_latency_fields() {
        let row =
            bench_json_serve_row("serving_load", "serve-poll", "avx2", 200, 32, 123.0, 1.5, 9.0);
        assert_eq!(row.get("engine").and_then(Json::as_str), Some("serve-poll"));
        assert_eq!(row.get("requests").and_then(Json::as_usize), Some(200));
        assert_eq!(row.get("ns_per_request").and_then(Json::as_f64), Some(123.0));
        assert_eq!(row.get("p50_us").and_then(Json::as_f64), Some(1.5));
        assert_eq!(row.get("p99_us").and_then(Json::as_f64), Some(9.0));
    }

    #[test]
    fn time_cap_short_circuits() {
        let opts = BenchOpts {
            warmup: 0,
            repeats: 1000,
            time_cap: Duration::from_millis(30),
            ..Default::default()
        };
        let s = run_case("slow", &opts, || std::thread::sleep(Duration::from_millis(20)));
        assert!(s.runs.len() < 1000);
        assert!(!s.runs.is_empty());
    }
}
