//! Benchmark harness (criterion is unavailable offline — DESIGN.md §8).
//!
//! Measurement discipline: warmup runs discarded, `repeats` timed runs,
//! report median + MAD (median absolute deviation) — robust to the odd
//! scheduling hiccup on a shared container. Every `rust/benches/*.rs`
//! target uses this via `harness = false`.

use std::time::{Duration, Instant};

/// One measured statistic set.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Label for reporting (e.g. `"N=500000 K=8 p=4"`).
    pub label: String,
    /// All timed runs, seconds.
    pub runs: Vec<f64>,
}

impl Sample {
    pub fn median(&self) -> f64 {
        percentile(&self.runs, 0.5)
    }

    /// Median absolute deviation (scaled by nothing; raw seconds).
    pub fn mad(&self) -> f64 {
        let m = self.median();
        let devs: Vec<f64> = self.runs.iter().map(|r| (r - m).abs()).collect();
        percentile(&devs, 0.5)
    }

    pub fn min(&self) -> f64 {
        self.runs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.runs.iter().copied().fold(0.0, f64::max)
    }
}

fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Bench runner configuration.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup: usize,
    pub repeats: usize,
    /// Rows per bench case (`PARAKM_BENCH_N`); benches that scale
    /// with dataset size read this so CI can shrink the workload.
    pub n: usize,
    /// Hard cap on total time per case; once exceeded (and >= 1 timed
    /// run exists) remaining repeats are skipped. Keeps the 1M-point
    /// cases from blowing the bench budget.
    pub time_cap: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 1, repeats: 5, n: 200_000, time_cap: Duration::from_secs(120) }
    }
}

impl BenchOpts {
    /// Read overrides from env: PARAKM_BENCH_WARMUP / _REPEATS /
    /// _CAP_SECS / _N. Lets CI shrink the matrix without code edits.
    pub fn from_env() -> Self {
        let mut o = BenchOpts::default();
        if let Ok(v) = std::env::var("PARAKM_BENCH_N") {
            if let Ok(n) = v.parse() {
                o.n = n;
            }
        }
        if let Ok(v) = std::env::var("PARAKM_BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                o.warmup = n;
            }
        }
        if let Ok(v) = std::env::var("PARAKM_BENCH_REPEATS") {
            if let Ok(n) = v.parse() {
                o.repeats = n;
            }
        }
        if let Ok(v) = std::env::var("PARAKM_BENCH_CAP_SECS") {
            if let Ok(n) = v.parse() {
                o.time_cap = Duration::from_secs_f64(n);
            }
        }
        o
    }
}

/// Time one case: `f` is the workload; its return value is black-boxed.
pub fn run_case<T>(label: &str, opts: &BenchOpts, mut f: impl FnMut() -> T) -> Sample {
    let budget_start = Instant::now();
    for _ in 0..opts.warmup {
        std::hint::black_box(f());
        if budget_start.elapsed() > opts.time_cap {
            break;
        }
    }
    let mut runs = Vec::with_capacity(opts.repeats);
    for _ in 0..opts.repeats {
        let t0 = Instant::now();
        std::hint::black_box(f());
        runs.push(t0.elapsed().as_secs_f64());
        if budget_start.elapsed() > opts.time_cap && !runs.is_empty() {
            break;
        }
    }
    Sample { label: label.to_string(), runs }
}

/// Print a sample row in the house bench format (parsed by EXPERIMENTS
/// tooling; keep stable).
pub fn report(s: &Sample) {
    println!(
        "BENCH  {:<44} median={:>10.6}s  mad={:>9.6}s  min={:>10.6}s  runs={}",
        s.label,
        s.median(),
        s.mad(),
        s.min(),
        s.runs.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let s = Sample { label: "t".into(), runs: vec![1.0, 2.0, 100.0] };
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.mad(), 1.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn percentile_interpolates() {
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.5), 2.5);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn run_case_counts_repeats() {
        let opts = BenchOpts {
            warmup: 1,
            repeats: 3,
            time_cap: Duration::from_secs(60),
            ..Default::default()
        };
        let mut calls = 0;
        let s = run_case("x", &opts, || {
            calls += 1;
            calls
        });
        assert_eq!(s.runs.len(), 3);
        assert_eq!(calls, 4); // 1 warmup + 3 timed
    }

    #[test]
    fn time_cap_short_circuits() {
        let opts = BenchOpts {
            warmup: 0,
            repeats: 1000,
            time_cap: Duration::from_millis(30),
            ..Default::default()
        };
        let s = run_case("slow", &opts, || std::thread::sleep(Duration::from_millis(20)));
        assert!(s.runs.len() < 1000);
        assert!(!s.runs.is_empty());
    }
}
