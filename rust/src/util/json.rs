//! Minimal JSON parser + writer.
//!
//! The offline image ships no `serde`/`serde_json`, and the only JSON we
//! handle is the AOT artifact manifest (small, trusted, machine-written
//! by `python/compile/aot.py`) plus our own emitted reports — so a small
//! recursive-descent parser is the right tool. Full RFC 8259 value
//! grammar, `f64` numbers, `\uXXXX` escapes; no streaming.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// Maximum nesting depth the parser accepts. The parser is recursive,
/// so without a cap a hostile document of 100k `[` bytes overflows the
/// stack instead of returning a typed error — fatal for the serve path,
/// which feeds untrusted lines through here. 128 is far beyond any
/// document we emit or accept (requests nest 3 deep). The tape parser
/// in [`crate::serve::scan`] enforces the same constant so both parsers
/// stay answer-equivalent.
pub const MAX_DEPTH: usize = 128;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for round-trip tests.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value(0)?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors (manifest reading convenience) ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `obj.str_field("name")` with a manifest-flavored error.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Manifest(format!("missing string field `{key}`")))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Manifest(format!("missing integer field `{key}`")))
    }

    pub fn arr_field(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Manifest(format!("missing array field `{key}`")))
    }

    /// Serialize back to compact JSON (used by report writers).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, message: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek().ok_or_else(|| self.err("unexpected end of input"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(depth),
            b'{' => self.object(depth),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value(depth + 1)?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value(depth + 1)?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences byte-for-byte
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn deep_nesting_is_a_typed_error_not_a_stack_overflow() {
        // just inside the cap parses …
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        // … one past it is a typed error, and a hostile 100k-deep
        // document must not touch the recursion at all
        let over = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(over.ends_with(']'));
        match Json::parse(&over) {
            Err(crate::Error::Json { message, .. }) => assert!(message.contains("nesting")),
            other => panic!("expected Json error, got {other:?}"),
        }
        assert!(Json::parse(&"[".repeat(100_000)).is_err());
        assert!(Json::parse(&"{\"a\":".repeat(100_000)).is_err());
    }

    #[test]
    fn error_carries_offset() {
        match Json::parse("[1, x]") {
            Err(crate::Error::Json { offset, .. }) => assert_eq!(offset, 4),
            other => panic!("expected Json error, got {other:?}"),
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"b":true}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(v.usize_field("n").unwrap(), 3);
        assert_eq!(v.str_field("s").unwrap(), "x");
        assert_eq!(v.arr_field("a").unwrap().len(), 1);
        assert!(v.usize_field("missing").is_err());
        assert!(v.str_field("n").is_err());
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
