//! SVG plot writer for the paper's figures.
//!
//! Two plot kinds cover Figures 1–12: scatter plots of clustered points
//! (Figures 1–6) and line charts (speedup / efficiency / scaling,
//! Figures 7–12). Self-contained SVG, no external assets, categorical
//! palette stable across serial/parallel runs so side-by-side figures
//! are visually comparable like the paper's.

use std::fmt::Write as _;
use std::path::Path;

use crate::error::Result;

/// Categorical palette (12 entries — enough for K=11 plus noise class).
pub const PALETTE: [&str; 12] = [
    "#4e79a7", "#f28e2b", "#e15759", "#76b7b2", "#59a14f", "#edc948",
    "#b07aa1", "#ff9da7", "#9c755f", "#bab0ac", "#2f4b7c", "#a05195",
];

const W: f64 = 720.0;
const H: f64 = 540.0;
const MARGIN: f64 = 56.0;

struct Canvas {
    body: String,
    xmin: f64,
    xmax: f64,
    ymin: f64,
    ymax: f64,
}

impl Canvas {
    fn new(xmin: f64, xmax: f64, ymin: f64, ymax: f64) -> Canvas {
        let pad_x = (xmax - xmin).max(1e-12) * 0.05;
        let pad_y = (ymax - ymin).max(1e-12) * 0.05;
        Canvas {
            body: String::new(),
            xmin: xmin - pad_x,
            xmax: xmax + pad_x,
            ymin: ymin - pad_y,
            ymax: ymax + pad_y,
        }
    }

    fn sx(&self, x: f64) -> f64 {
        MARGIN + (x - self.xmin) / (self.xmax - self.xmin) * (W - 2.0 * MARGIN)
    }

    fn sy(&self, y: f64) -> f64 {
        H - MARGIN - (y - self.ymin) / (self.ymax - self.ymin) * (H - 2.0 * MARGIN)
    }

    fn axes(&mut self, title: &str, xlabel: &str, ylabel: &str) {
        let x0 = MARGIN;
        let x1 = W - MARGIN;
        let y0 = H - MARGIN;
        let y1 = MARGIN;
        let _ = write!(
            self.body,
            "<rect x='{x0}' y='{y1}' width='{}' height='{}' fill='none' stroke='#333'/>",
            x1 - x0,
            y0 - y1
        );
        let _ = write!(
            self.body,
            "<text x='{}' y='24' text-anchor='middle' font-size='16' font-family='sans-serif'>{}</text>",
            W / 2.0,
            esc(title)
        );
        let _ = write!(
            self.body,
            "<text x='{}' y='{}' text-anchor='middle' font-size='13' font-family='sans-serif'>{}</text>",
            W / 2.0,
            H - 12.0,
            esc(xlabel)
        );
        let _ = write!(
            self.body,
            "<text x='16' y='{}' text-anchor='middle' font-size='13' font-family='sans-serif' transform='rotate(-90 16 {})'>{}</text>",
            H / 2.0,
            H / 2.0,
            esc(ylabel)
        );
        // ticks: 5 per axis
        for i in 0..=5 {
            let fx = self.xmin + (self.xmax - self.xmin) * i as f64 / 5.0;
            let px = self.sx(fx);
            let _ = write!(
                self.body,
                "<line x1='{px}' y1='{y0}' x2='{px}' y2='{}' stroke='#333'/>",
                y0 + 5.0
            );
            let _ = write!(
                self.body,
                "<text x='{px}' y='{}' text-anchor='middle' font-size='11' font-family='sans-serif'>{}</text>",
                y0 + 18.0,
                tick(fx)
            );
            let fy = self.ymin + (self.ymax - self.ymin) * i as f64 / 5.0;
            let py = self.sy(fy);
            let _ = write!(
                self.body,
                "<line x1='{}' y1='{py}' x2='{x0}' y2='{py}' stroke='#333'/>",
                x0 - 5.0
            );
            let _ = write!(
                self.body,
                "<text x='{}' y='{}' text-anchor='end' font-size='11' font-family='sans-serif'>{}</text>",
                x0 - 8.0,
                py + 4.0,
                tick(fy)
            );
        }
    }

    fn finish(self) -> String {
        format!(
            "<?xml version='1.0' encoding='UTF-8'?>\n<svg xmlns='http://www.w3.org/2000/svg' width='{W}' height='{H}' viewBox='0 0 {W} {H}'>\n<rect width='{W}' height='{H}' fill='white'/>\n{}\n</svg>\n",
            self.body
        )
    }
}

fn tick(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 10000.0 || v.abs() < 0.01 {
        format!("{v:.1e}")
    } else if v.fract().abs() < 1e-9 {
        format!("{}", v as i64)
    } else {
        format!("{v:.2}")
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// Scatter plot of 2D points colored by label (Figures 1–6; 3D data is
/// plotted as the paper does — a 2D projection of the first two axes,
/// with the projection choice documented in the figure title).
pub fn scatter(
    path: &Path,
    title: &str,
    xs: &[f32],
    ys: &[f32],
    labels: &[i32],
    max_points: usize,
) -> Result<()> {
    assert_eq!(xs.len(), ys.len());
    assert_eq!(xs.len(), labels.len());
    let stride = (xs.len() / max_points.max(1)).max(1);
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for i in (0..xs.len()).step_by(stride) {
        xmin = xmin.min(xs[i] as f64);
        xmax = xmax.max(xs[i] as f64);
        ymin = ymin.min(ys[i] as f64);
        ymax = ymax.max(ys[i] as f64);
    }
    if !xmin.is_finite() {
        xmin = 0.0;
        xmax = 1.0;
        ymin = 0.0;
        ymax = 1.0;
    }
    let mut c = Canvas::new(xmin, xmax, ymin, ymax);
    c.axes(title, "x", "y");
    for i in (0..xs.len()).step_by(stride) {
        let color = if labels[i] < 0 {
            "#999999"
        } else {
            PALETTE[(labels[i] as usize) % PALETTE.len()]
        };
        let _ = write!(
            c.body,
            "<circle cx='{:.1}' cy='{:.1}' r='1.6' fill='{}' fill-opacity='0.55'/>",
            c.sx(xs[i] as f64),
            c.sy(ys[i] as f64),
            color
        );
    }
    write_file(path, &c.finish())
}

/// One line series.
pub struct Series<'a> {
    pub name: &'a str,
    pub points: Vec<(f64, f64)>,
}

/// Line chart (Figures 7–12): one or more named series with markers
/// and a legend.
pub fn line_chart(
    path: &Path,
    title: &str,
    xlabel: &str,
    ylabel: &str,
    series: &[Series],
) -> Result<()> {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if all.is_empty() {
        return write_file(path, &Canvas::new(0.0, 1.0, 0.0, 1.0).finish());
    }
    let xmin = all.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
    let xmax = all.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
    let ymin = all.iter().map(|p| p.1).fold(f64::INFINITY, f64::min).min(0.0);
    let ymax = all.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
    let mut c = Canvas::new(xmin, xmax, ymin, ymax);
    c.axes(title, xlabel, ylabel);
    for (si, s) in series.iter().enumerate() {
        let color = PALETTE[si % PALETTE.len()];
        let mut d = String::new();
        for (i, (x, y)) in s.points.iter().enumerate() {
            let _ = write!(d, "{}{:.1},{:.1} ", if i == 0 { "M" } else { "L" }, c.sx(*x), c.sy(*y));
        }
        let _ = write!(
            c.body,
            "<path d='{}' fill='none' stroke='{}' stroke-width='2'/>",
            d.trim(),
            color
        );
        for (x, y) in &s.points {
            let _ = write!(
                c.body,
                "<circle cx='{:.1}' cy='{:.1}' r='3.5' fill='{}'/>",
                c.sx(*x),
                c.sy(*y),
                color
            );
        }
        // legend
        let ly = MARGIN + 18.0 * si as f64 + 12.0;
        let _ = write!(
            c.body,
            "<rect x='{}' y='{}' width='12' height='12' fill='{}'/><text x='{}' y='{}' font-size='12' font-family='sans-serif'>{}</text>",
            W - MARGIN - 150.0,
            ly - 10.0,
            color,
            W - MARGIN - 132.0,
            ly,
            esc(s.name)
        );
    }
    write_file(path, &c.finish())
}

fn write_file(path: &Path, content: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parakm_svg_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn scatter_writes_valid_svg() {
        let p = tmp("scatter.svg");
        scatter(
            &p,
            "t",
            &[0.0, 1.0, 2.0],
            &[0.0, 1.0, 0.5],
            &[0, 1, -1],
            1000,
        )
        .unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("<?xml"));
        assert!(s.contains("</svg>"));
        assert_eq!(s.matches("<circle").count(), 3);
        assert!(s.contains("#999999")); // noise color for label -1
    }

    #[test]
    fn scatter_subsamples() {
        let n = 10_000;
        let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let ys = xs.clone();
        let labels = vec![0i32; n];
        let p = tmp("sub.svg");
        scatter(&p, "t", &xs, &ys, &labels, 100).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.matches("<circle").count() <= 110);
    }

    #[test]
    fn line_chart_series_and_legend() {
        let p = tmp("line.svg");
        line_chart(
            &p,
            "speedup",
            "threads",
            "psi",
            &[
                Series { name: "N=100k", points: vec![(2.0, 1.5), (4.0, 2.8)] },
                Series { name: "N=1M", points: vec![(2.0, 1.9), (4.0, 3.6)] },
            ],
        )
        .unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s.matches("<path").count(), 2);
        assert!(s.contains("N=100k") && s.contains("N=1M"));
    }

    #[test]
    fn empty_series_ok() {
        let p = tmp("empty.svg");
        line_chart(&p, "t", "x", "y", &[]).unwrap();
        assert!(std::fs::read_to_string(&p).unwrap().contains("</svg>"));
    }

    #[test]
    fn escapes_title() {
        let p = tmp("esc.svg");
        line_chart(&p, "a<b & c", "x", "y", &[Series { name: "s", points: vec![(0.0, 0.0)] }])
            .unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("a&lt;b &amp; c"));
    }
}
