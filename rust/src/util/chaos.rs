//! Deterministic fault injection (DESIGN.md §16).
//!
//! `chaos` is a process-wide registry of named injection *sites* wrapping
//! the crate's I/O choke points — artifact writes and reads, cluster
//! socket frames, serve accept/enqueue, and the batcher loop. Like
//! [`crate::util::trace`], it is a true no-op unless a plan is installed:
//! the disabled fast path is one relaxed atomic load, so production
//! binaries pay nothing for carrying the hooks.
//!
//! A [`ChaosPlan`] is seeded: each site gets an independent SplitMix64
//! stream derived from `seed ^ site`, and fires a fault on a fixed
//! fraction of calls (`1/period`). The same seed therefore replays the
//! same fault schedule run-to-run, which is what makes a failing soak
//! sweep reducible to `--chaos SEED:SITE:PERIOD` on the command line.
//!
//! Every injected failure message starts with `"chaos: injected"` so
//! operators (and the soak harness) can tell synthetic faults from real
//! ones at a glance.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::Error;
use crate::rng::SplitMix64;

/// Fast-path gate: `hit` returns `None` after one relaxed load when no
/// plan is installed.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Installed plan state (counters + per-site RNG streams).
static PLAN: Mutex<Option<PlanState>> = Mutex::new(None);

/// Total faults fired since process start (monotone across installs).
static FIRED_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Injection sites. Names are the stable CLI / spec vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Site {
    /// `data::io::atomic_write` — between tmp sync and rename.
    AtomicWrite,
    /// Artifact read paths (`read_binary`, `read_model`, ckpt slots).
    ArtifactRead,
    /// `cluster::wire::write_frame` — mid-frame close / stall.
    WireWrite,
    /// `cluster::wire::read_frame_opt` — connection failure / stall.
    WireRead,
    /// Serve accept loops (both `poll` and `threads`).
    ServeAccept,
    /// Serve request enqueue into the batcher queue.
    ServeEnqueue,
    /// Batcher flush — injected panic, exercises the supervisor.
    Batcher,
}

/// All sites, in spec order.
pub const ALL_SITES: [Site; 7] = [
    Site::AtomicWrite,
    Site::ArtifactRead,
    Site::WireWrite,
    Site::WireRead,
    Site::ServeAccept,
    Site::ServeEnqueue,
    Site::Batcher,
];

impl Site {
    pub fn name(self) -> &'static str {
        match self {
            Site::AtomicWrite => "atomic-write",
            Site::ArtifactRead => "artifact-read",
            Site::WireWrite => "wire-write",
            Site::WireRead => "wire-read",
            Site::ServeAccept => "serve-accept",
            Site::ServeEnqueue => "serve-enqueue",
            Site::Batcher => "batcher",
        }
    }

    pub fn from_name(name: &str) -> Option<Site> {
        ALL_SITES.iter().copied().find(|s| s.name() == name)
    }

    fn idx(self) -> usize {
        match self {
            Site::AtomicWrite => 0,
            Site::ArtifactRead => 1,
            Site::WireWrite => 2,
            Site::WireRead => 3,
            Site::ServeAccept => 4,
            Site::ServeEnqueue => 5,
            Site::Batcher => 6,
        }
    }
}

impl std::fmt::Display for Site {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A concrete fault drawn from a site's schedule. Call sites interpret
/// only the kinds that make sense for them (see DESIGN.md §16 for the
/// site × kind matrix); kinds a site cannot express degrade to `Fail`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Outright typed failure (failed rename, connection error, ...).
    Fail,
    /// Truncate the payload, keeping `keep_permille`/1000 of its bytes.
    Torn { keep_permille: u16 },
    /// Flip one bit at `pos % (len * 8)` in the payload.
    BitFlip { pos: u64 },
    /// Sleep `ms` milliseconds, then proceed normally.
    Stall { ms: u16 },
    /// Panic at the site (batcher only — exercises the supervisor).
    Panic,
}

/// Parsed, installable chaos plan.
#[derive(Debug, Clone)]
pub struct ChaosPlan {
    pub seed: u64,
    pub sites: Vec<Site>,
    /// Fire on roughly one in `period` calls per armed site (min 1).
    pub period: u64,
    /// When set, path-aware sites (`atomic-write`, `artifact-read`)
    /// only fire for paths under this directory. Lets tests scope a
    /// process-global plan to their own tempdir.
    pub scope: Option<PathBuf>,
}

impl ChaosPlan {
    pub fn new(seed: u64) -> ChaosPlan {
        ChaosPlan {
            seed,
            sites: ALL_SITES.to_vec(),
            period: 3,
            scope: None,
        }
    }

    pub fn with_sites(mut self, sites: &[Site]) -> ChaosPlan {
        self.sites = sites.to_vec();
        self
    }

    pub fn with_period(mut self, period: u64) -> ChaosPlan {
        self.period = period.max(1);
        self
    }

    pub fn with_scope(mut self, dir: &Path) -> ChaosPlan {
        self.scope = Some(dir.to_path_buf());
        self
    }

    /// Parse a `SEED[:SITES[:PERIOD]]` spec. `SITES` is a comma list of
    /// site names or `all` (default); `PERIOD` defaults to 3.
    pub fn parse(spec: &str) -> Result<ChaosPlan, Error> {
        let bad = |m: String| Error::Config(format!("--chaos {spec}: {m}"));
        let mut parts = spec.splitn(3, ':');
        let seed_part = parts.next().unwrap_or("");
        let seed = seed_part
            .parse::<u64>()
            .map_err(|_| bad(format!("bad seed {seed_part:?} (want a u64)")))?;
        let mut plan = ChaosPlan::new(seed);
        if let Some(sites_part) = parts.next() {
            if !sites_part.is_empty() && sites_part != "all" {
                let mut sites = Vec::new();
                for name in sites_part.split(',') {
                    let site = Site::from_name(name).ok_or_else(|| {
                        bad(format!(
                            "unknown site {name:?} (known: {})",
                            ALL_SITES.map(Site::name).join(", ")
                        ))
                    })?;
                    if !sites.contains(&site) {
                        sites.push(site);
                    }
                }
                plan.sites = sites;
            }
        }
        if let Some(period_part) = parts.next() {
            let period = period_part
                .parse::<u64>()
                .map_err(|_| bad(format!("bad period {period_part:?} (want a u64 >= 1)")))?;
            if period == 0 {
                return Err(bad("bad period 0 (want >= 1)".into()));
            }
            plan.period = period;
        }
        Ok(plan)
    }
}

struct SiteState {
    armed: bool,
    rng: SplitMix64,
    calls: u64,
    fired: u64,
}

struct PlanState {
    period: u64,
    scope: Option<PathBuf>,
    sites: Vec<SiteState>,
}

impl PlanState {
    fn build(plan: &ChaosPlan) -> PlanState {
        let sites = ALL_SITES
            .iter()
            .map(|&site| SiteState {
                armed: plan.sites.contains(&site),
                // Independent stream per site so arming one site never
                // perturbs another site's schedule.
                rng: SplitMix64::new(plan.seed ^ (0x51_7E * (site.idx() as u64 + 1))),
                calls: 0,
                fired: 0,
            })
            .collect();
        PlanState {
            period: plan.period.max(1),
            scope: plan.scope.clone(),
            sites,
        }
    }
}

/// Install a plan. Replaces any existing plan (counters restart).
pub fn install(plan: &ChaosPlan) {
    let mut guard = PLAN.lock().unwrap();
    *guard = Some(PlanState::build(plan));
    ENABLED.store(true, Ordering::Release);
}

/// Parse and install a `SEED[:SITES[:PERIOD]]` spec.
pub fn install_spec(spec: &str) -> Result<(), Error> {
    let plan = ChaosPlan::parse(spec)?;
    install(&plan);
    Ok(())
}

/// Remove the plan; `hit` returns to the one-load no-op path.
pub fn uninstall() {
    ENABLED.store(false, Ordering::Release);
    let mut guard = PLAN.lock().unwrap();
    *guard = None;
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Resolve a chaos spec: an explicit flag wins, else `PARAKM_CHAOS`.
pub fn spec_from(flag: Option<&str>) -> Option<String> {
    if let Some(f) = flag {
        return Some(f.to_string());
    }
    match std::env::var("PARAKM_CHAOS") {
        Ok(v) if !v.is_empty() => Some(v),
        _ => None,
    }
}

/// Total faults fired since process start (across plan installs).
pub fn fired_total() -> u64 {
    FIRED_TOTAL.load(Ordering::Relaxed)
}

/// Per-site fired counts for the currently installed plan.
pub fn fired_by_site() -> BTreeMap<&'static str, u64> {
    let guard = PLAN.lock().unwrap();
    let mut out = BTreeMap::new();
    if let Some(state) = guard.as_ref() {
        for (i, s) in state.sites.iter().enumerate() {
            if s.fired > 0 {
                out.insert(ALL_SITES[i].name(), s.fired);
            }
        }
    }
    out
}

/// Poll a site. Returns the scheduled fault on firing calls, `None`
/// otherwise. One relaxed load when no plan is installed.
#[inline]
pub fn hit(site: Site) -> Option<Fault> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    hit_slow(site, None)
}

/// Path-aware variant for artifact sites: respects the plan's `scope`
/// so tests can confine a process-global plan to one tempdir.
#[inline]
pub fn hit_path(site: Site, path: &Path) -> Option<Fault> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    hit_slow(site, Some(path))
}

#[cold]
fn hit_slow(site: Site, path: Option<&Path>) -> Option<Fault> {
    let mut guard = PLAN.lock().unwrap();
    let state = guard.as_mut()?;
    if let Some(scope) = state.scope.as_deref() {
        // A scoped plan only fires for paths under the scope dir; sites
        // that carry no path (wire, serve) are disarmed entirely.
        match path {
            Some(p) if p.starts_with(scope) => {}
            _ => return None,
        }
    }
    let period = state.period;
    let s = &mut state.sites[site.idx()];
    if !s.armed {
        return None;
    }
    s.calls += 1;
    let draw = s.rng.next_u64();
    if draw % period != 0 {
        return None;
    }
    s.fired += 1;
    FIRED_TOTAL.fetch_add(1, Ordering::Relaxed);
    crate::util::trace::counter_add("chaos_faults_total", 1);
    let pick = draw >> 8;
    Some(fault_for(site, pick))
}

/// Map a draw to a fault kind valid for the site. Kinds that could
/// silently corrupt results without a CRC to catch them (bit flips on
/// the un-checksummed wire) are deliberately excluded.
fn fault_for(site: Site, pick: u64) -> Fault {
    match site {
        Site::AtomicWrite | Site::ArtifactRead => match pick % 3 {
            0 => Fault::Fail,
            1 => Fault::Torn {
                keep_permille: (pick / 3 % 1000) as u16,
            },
            _ => Fault::BitFlip { pos: pick / 3 },
        },
        Site::WireWrite => match pick % 4 {
            0 => Fault::Stall {
                ms: (1 + pick / 4 % 10) as u16,
            },
            1 | 2 => Fault::Torn {
                keep_permille: (pick / 4 % 1000) as u16,
            },
            _ => Fault::Fail,
        },
        Site::WireRead => match pick % 3 {
            0 => Fault::Stall {
                ms: (1 + pick / 3 % 10) as u16,
            },
            _ => Fault::Fail,
        },
        Site::ServeAccept | Site::ServeEnqueue => Fault::Fail,
        Site::Batcher => Fault::Panic,
    }
}

/// Serializes tests that install plans: the registry is process-global,
/// so concurrent installs would clobber each other. In-binary tests
/// must also *scope* their plan to a private tempdir so armed sites
/// cannot fire inside unrelated tests running in parallel.
/// Poison-tolerant so one panicking chaos test cannot cascade.
#[doc(hidden)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Apply a byte-mutating fault to a payload in place. Returns
/// `Some(message)` when the fault is `Fail` (the caller should raise a
/// typed error with it), `None` when the payload was mutated (or the
/// fault does not apply to byte payloads) and the caller should proceed.
pub fn apply_to_bytes(site: Site, fault: Fault, bytes: &mut Vec<u8>) -> Option<String> {
    match fault {
        Fault::Fail | Fault::Panic => Some(format!("chaos: injected {site} failure")),
        Fault::Torn { keep_permille } => {
            let keep = (bytes.len() as u64 * keep_permille as u64 / 1000) as usize;
            bytes.truncate(keep);
            None
        }
        Fault::BitFlip { pos } => {
            if !bytes.is_empty() {
                let bit = pos % (bytes.len() as u64 * 8);
                bytes[(bit / 8) as usize] ^= 1 << (bit % 8);
            }
            None
        }
        Fault::Stall { ms } => {
            std::thread::sleep(std::time::Duration::from_millis(ms as u64));
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scope_dir(name: &str) -> PathBuf {
        std::env::temp_dir().join("parakm_chaos_tests").join(name)
    }

    #[test]
    fn disabled_by_default_and_after_uninstall() {
        let _g = test_lock();
        uninstall();
        assert!(!enabled());
        assert_eq!(hit(Site::AtomicWrite), None);
        let scope = scope_dir("toggle");
        install(&ChaosPlan::new(1).with_period(1).with_scope(&scope));
        assert!(enabled());
        uninstall();
        assert!(!enabled());
        assert_eq!(hit_path(Site::AtomicWrite, &scope.join("x")), None);
    }

    #[test]
    fn schedule_is_deterministic_from_seed() {
        let _g = test_lock();
        let scope = scope_dir("determinism");
        let p = scope.join("a.pkm");
        let sweep = |seed: u64| -> Vec<Option<Fault>> {
            install(&ChaosPlan::new(seed).with_period(3).with_scope(&scope));
            let out = (0..64).map(|_| hit_path(Site::AtomicWrite, &p)).collect();
            uninstall();
            out
        };
        let a = sweep(42);
        let b = sweep(42);
        let c = sweep(43);
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert_ne!(a, c, "different seeds should diverge");
        assert!(a.iter().any(|f| f.is_some()), "period 3 over 64 calls must fire");
    }

    #[test]
    fn sites_have_independent_streams() {
        let _g = test_lock();
        // Arming extra sites must not perturb another site's schedule.
        let scope = scope_dir("streams");
        let p = scope.join("a.pkd");
        let narrow = ChaosPlan::new(7)
            .with_sites(&[Site::ArtifactRead])
            .with_period(2)
            .with_scope(&scope);
        install(&narrow);
        let solo: Vec<_> = (0..32).map(|_| hit_path(Site::ArtifactRead, &p)).collect();
        install(&ChaosPlan::new(7).with_period(2).with_scope(&scope));
        let with_all: Vec<_> = (0..32)
            .map(|_| {
                let f = hit_path(Site::ArtifactRead, &p);
                hit_path(Site::AtomicWrite, &p); // interleave the other stream
                f
            })
            .collect();
        uninstall();
        assert_eq!(solo, with_all);
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let _g = test_lock();
        let scope = scope_dir("unarmed");
        let p = scope.join("a.pkc");
        let plan = ChaosPlan::new(9)
            .with_sites(&[Site::ArtifactRead])
            .with_period(1)
            .with_scope(&scope);
        install(&plan);
        for _ in 0..50 {
            assert_eq!(hit_path(Site::AtomicWrite, &p), None);
        }
        assert!(hit_path(Site::ArtifactRead, &p).is_some());
        uninstall();
    }

    #[test]
    fn scope_confines_path_sites_and_disarms_pathless_sites() {
        let _g = test_lock();
        let scope = scope_dir("confine");
        install(&ChaosPlan::new(5).with_period(1).with_scope(&scope));
        assert_eq!(hit(Site::WireWrite), None, "pathless site under scope");
        assert_eq!(
            hit_path(Site::AtomicWrite, Path::new("/elsewhere/x.pkm")),
            None
        );
        assert!(hit_path(Site::AtomicWrite, &scope.join("x.pkm")).is_some());
        uninstall();
    }

    #[test]
    fn fault_kinds_match_site_capabilities() {
        // Pure function, no plan needed: bit flips never reach the
        // un-checksummed wire, the batcher only panics, serve sites
        // only fail.
        for pick in 0..200u64 {
            assert_eq!(fault_for(Site::Batcher, pick), Fault::Panic);
            assert_eq!(fault_for(Site::ServeAccept, pick), Fault::Fail);
            assert_eq!(fault_for(Site::ServeEnqueue, pick), Fault::Fail);
            assert!(!matches!(fault_for(Site::WireWrite, pick), Fault::BitFlip { .. }));
            assert!(matches!(
                fault_for(Site::WireRead, pick),
                Fault::Fail | Fault::Stall { .. }
            ));
            assert!(!matches!(
                fault_for(Site::AtomicWrite, pick),
                Fault::Stall { .. } | Fault::Panic
            ));
        }
    }

    #[test]
    fn spec_parsing_roundtrip_and_errors() {
        let plan = ChaosPlan::parse("42").unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.period, 3);
        assert_eq!(plan.sites.len(), ALL_SITES.len());

        let plan = ChaosPlan::parse("7:wire-read,batcher:10").unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.period, 10);
        assert_eq!(plan.sites, vec![Site::WireRead, Site::Batcher]);

        let plan = ChaosPlan::parse("1:all:2").unwrap();
        assert_eq!(plan.sites.len(), ALL_SITES.len());

        assert!(ChaosPlan::parse("").is_err());
        assert!(ChaosPlan::parse("x").is_err());
        assert!(ChaosPlan::parse("1:nope").is_err());
        assert!(ChaosPlan::parse("1:all:0").is_err());
        assert!(ChaosPlan::parse("1:all:x").is_err());
    }

    #[test]
    fn apply_to_bytes_truncates_flips_and_fails() {
        let mut b = vec![0u8; 100];
        assert!(apply_to_bytes(
            Site::AtomicWrite,
            Fault::Torn { keep_permille: 500 },
            &mut b
        )
        .is_none());
        assert_eq!(b.len(), 50);

        let mut b = vec![0u8; 4];
        assert!(apply_to_bytes(Site::ArtifactRead, Fault::BitFlip { pos: 9 }, &mut b).is_none());
        assert_eq!(b, vec![0, 2, 0, 0]);

        let mut b = vec![1u8; 4];
        let msg = apply_to_bytes(Site::ArtifactRead, Fault::Fail, &mut b).unwrap();
        assert!(msg.starts_with("chaos: injected"), "{msg}");
        assert_eq!(b, vec![1u8; 4], "Fail must not mutate the payload");
    }

    #[test]
    fn fired_counters_accumulate() {
        let _g = test_lock();
        let before = fired_total();
        let scope = scope_dir("counters");
        let p = scope.join("a.pkm");
        let plan = ChaosPlan::new(3)
            .with_sites(&[Site::AtomicWrite])
            .with_period(1)
            .with_scope(&scope);
        install(&plan);
        for _ in 0..5 {
            assert!(hit_path(Site::AtomicWrite, &p).is_some());
        }
        assert_eq!(fired_by_site().get("atomic-write"), Some(&5));
        uninstall();
        assert_eq!(fired_total() - before, 5);
    }
}
