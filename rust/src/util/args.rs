//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §8).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, and
//! subcommands (first positional). Typed getters parse on access and
//! report which flag failed. Unknown-flag detection is the caller's
//! choice via [`Args::finish`].

use std::collections::BTreeMap;
use std::str::FromStr;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, Vec<String>>,
    positionals: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I, S>(items: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positionals = Vec::new();
        let mut it = items.into_iter().map(Into::into).peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.entry(body.to_string()).or_default().push(v);
                } else {
                    flags.entry(body.to_string()).or_default().push(String::new());
                }
            } else {
                positionals.push(a);
            }
        }
        Args { flags, positionals, consumed: Default::default() }
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// First positional (conventionally the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positionals.first().map(String::as_str)
    }

    /// Positionals after the subcommand.
    pub fn rest(&self) -> &[String] {
        if self.positionals.is_empty() {
            &[]
        } else {
            &self.positionals[1..]
        }
    }

    /// Boolean flag: present (with or without value)?
    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    /// Raw string value of the last occurrence of `--key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    /// All values of a repeatable flag.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    /// Typed value with default.
    pub fn get_or<T: FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|_| {
                Error::Config(format!("--{key}: cannot parse `{raw}`"))
            }),
        }
    }

    /// Typed required value.
    pub fn require<T: FromStr>(&self, key: &str) -> Result<T> {
        let raw = self
            .get(key)
            .ok_or_else(|| Error::Config(format!("missing required --{key}")))?;
        raw.parse()
            .map_err(|_| Error::Config(format!("--{key}: cannot parse `{raw}`")))
    }

    /// Comma-separated list, e.g. `--threads 2,4,8,16`.
    pub fn get_list<T: FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        Error::Config(format!("--{key}: cannot parse element `{s}`"))
                    })
                })
                .collect(),
        }
    }

    /// Error on any flag never touched by a getter (typo guard).
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                return Err(Error::Config(format!("unknown flag --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_and_positionals() {
        let a = Args::parse(["run", "--k", "8", "--fast", "--n=100", "extra"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.rest(), &["extra".to_string()]);
        assert_eq!(a.get("k"), Some("8"));
        assert_eq!(a.get("n"), Some("100"));
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(["--k", "8", "--tol", "1e-6"]);
        assert_eq!(a.get_or("k", 4usize).unwrap(), 8);
        assert_eq!(a.get_or("missing", 4usize).unwrap(), 4);
        assert_eq!(a.require::<f64>("tol").unwrap(), 1e-6);
        assert!(a.require::<usize>("tol").is_err());
        assert!(a.require::<usize>("absent").is_err());
    }

    #[test]
    fn lists() {
        let a = Args::parse(["--threads", "2,4,8"]);
        assert_eq!(a.get_list("threads", &[1usize]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.get_list("sizes", &[5usize]).unwrap(), vec![5]);
        let bad = Args::parse(["--threads", "2,x"]);
        assert!(bad.get_list::<usize>("threads", &[]).is_err());
    }

    #[test]
    fn repeated_flags_last_wins_and_all() {
        let a = Args::parse(["--k", "4", "--k", "8"]);
        assert_eq!(a.get("k"), Some("8"));
        assert_eq!(a.get_all("k"), vec!["4", "8"]);
    }

    #[test]
    fn finish_flags_unknown() {
        let a = Args::parse(["--known", "1", "--typo", "2"]);
        let _ = a.get("known");
        assert!(a.finish().is_err());
        let b = Args::parse(["--known", "1"]);
        let _ = b.get("known");
        assert!(b.finish().is_ok());
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = Args::parse(["--verbose", "--k", "3"]);
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some(""));
        assert_eq!(a.get_or("k", 0usize).unwrap(), 3);
    }
}
