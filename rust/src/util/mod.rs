//! Zero-dependency infrastructure: JSON, CLI args, CSV/SVG writers,
//! and the benchmark harness.
//!
//! The offline image ships neither `serde` nor `clap` nor `criterion`
//! (DESIGN.md §8), so these small, tested substitutes live here.

pub mod args;
pub mod bench;
pub mod chaos;
pub mod crc32;
pub mod csv;
pub mod json;
pub mod svg;
pub mod tables;
pub mod trace;
