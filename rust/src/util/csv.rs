//! CSV writer/reader for figure series and dataset interchange.
//!
//! Deliberately minimal: comma separator, no quoting of numeric output,
//! quote-aware reading for robustness. Figure data written here is what
//! `EXPERIMENTS.md` references and what any plotting tool can consume.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::data::io::atomic_write_with;
use crate::error::{Error, Result};

/// Write rows of `f64` columns with a header line. Routed through
/// [`atomic_write_with`] so a crash mid-write never leaves a torn
/// table behind.
pub fn write_table(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    atomic_write_with(path, |f| {
        writeln!(f, "{}", header.join(","))?;
        for row in rows {
            let cells: Vec<String> = row.iter().map(|v| format_num(*v)).collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        Ok(())
    })
}

/// Write string rows (mixed-type tables). Atomic like [`write_table`].
pub fn write_rows(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    atomic_write_with(path, |f| {
        writeln!(f, "{}", header.join(","))?;
        for row in rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    })
}

fn format_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Read a numeric CSV (header returned separately). Quoted cells are
/// unquoted; non-numeric cells become NaN.
pub fn read_table(path: &Path) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let f = std::fs::File::open(path)?;
    let mut lines = BufReader::new(f).lines();
    let header = match lines.next() {
        Some(h) => split_line(&h?),
        None => return Ok((vec![], vec![])),
    };
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        rows.push(
            split_line(&line)
                .iter()
                .map(|c| c.parse().unwrap_or(f64::NAN))
                .collect(),
        );
    }
    Ok((header, rows))
}

/// Strict numeric CSV reader for *dataset* ingestion: any cell that is
/// not a finite number is a typed [`Error::Data`] naming the offending
/// row and column, never a silent NaN that would poison every distance
/// downstream. Report/table readers keep the lenient [`read_table`].
pub fn read_table_strict(path: &Path) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let f = std::fs::File::open(path)?;
    let mut lines = BufReader::new(f).lines();
    let header = match lines.next() {
        Some(h) => split_line(&h?),
        None => return Ok((vec![], vec![])),
    };
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // data-row index (0-based, header excluded) — matches the
        // "row {i}" convention of data::io::read_csv, which delegates
        // here
        let rowno = rows.len();
        let cells = split_line(&line);
        let mut row = Vec::with_capacity(cells.len());
        for (col, cell) in cells.iter().enumerate() {
            let v: f64 = cell.trim().parse().map_err(|_| {
                Error::Data(format!(
                    "csv row {rowno} col {col}: cell {cell:?} is not numeric"
                ))
            })?;
            if !v.is_finite() {
                return Err(Error::Data(format!(
                    "csv row {rowno} col {col}: non-finite value {cell:?}"
                )));
            }
            row.push(v);
        }
        rows.push(row);
    }
    Ok((header, rows))
}

/// Read a mixed-type CSV as strings (header returned separately) —
/// the reader dual of [`write_rows`], for tables with non-numeric
/// columns (e.g. engine names in `tables/pruned.csv`).
pub fn read_rows(path: &Path) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let f = std::fs::File::open(path)?;
    let mut lines = BufReader::new(f).lines();
    let header = match lines.next() {
        Some(h) => split_line(&h?),
        None => return Ok((vec![], vec![])),
    };
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        rows.push(split_line(&line));
    }
    Ok((header, rows))
}

fn split_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parakm_csv_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_numeric() {
        let p = tmp("rt.csv");
        write_table(&p, &["a", "b"], &[vec![1.0, 2.5], vec![3.0, -4.0]]).unwrap();
        let (h, rows) = read_table(&p).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows, vec![vec![1.0, 2.5], vec![3.0, -4.0]]);
    }

    #[test]
    fn integers_written_without_dot() {
        let p = tmp("ints.csv");
        write_table(&p, &["x"], &[vec![100000.0]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("100000\n"), "{text}");
    }

    #[test]
    fn read_rows_preserves_strings() {
        let p = tmp("mixed.csv");
        write_rows(
            &p,
            &["engine", "secs"],
            &[vec!["elkan".into(), "0.5".into()], vec!["hamerly".into(), "0.25".into()]],
        )
        .unwrap();
        let (h, rows) = read_rows(&p).unwrap();
        assert_eq!(h, vec!["engine", "secs"]);
        assert_eq!(rows[0], vec!["elkan", "0.5"]);
        assert_eq!(rows[1][1], "0.25");
    }

    #[test]
    fn quoted_cells() {
        assert_eq!(split_line(r#"a,"b,c",d"#), vec!["a", "b,c", "d"]);
        assert_eq!(split_line(r#""he said ""hi""",2"#), vec![r#"he said "hi""#, "2"]);
    }

    #[test]
    fn strict_reader_rejects_non_numeric_and_non_finite() {
        let p = tmp("strict.csv");
        std::fs::write(&p, "x,y\n1.0,2.0\n3.0,oops\n").unwrap();
        let err = read_table_strict(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err:?}");
        assert!(err.to_string().contains("oops"), "{err}");

        std::fs::write(&p, "x,y\n1.0,inf\n").unwrap();
        let err = read_table_strict(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err:?}");
        assert!(err.to_string().contains("non-finite"), "{err}");

        std::fs::write(&p, "x,y\nnan,1.0\n").unwrap();
        assert!(read_table_strict(&p).is_err());

        // the lenient reader still maps the same cells to NaN
        std::fs::write(&p, "x,y\n3.0,oops\n").unwrap();
        let (_, rows) = read_table(&p).unwrap();
        assert!(rows[0][1].is_nan());
    }

    #[test]
    fn strict_reader_accepts_clean_tables() {
        let p = tmp("strict_ok.csv");
        write_table(&p, &["a", "b"], &[vec![1.0, -2.5]]).unwrap();
        let (h, rows) = read_table_strict(&p).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows, vec![vec![1.0, -2.5]]);
    }

    #[test]
    fn empty_file() {
        let p = tmp("empty.csv");
        std::fs::write(&p, "").unwrap();
        let (h, rows) = read_table(&p).unwrap();
        assert!(h.is_empty() && rows.is_empty());
    }
}
