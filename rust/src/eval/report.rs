//! Markdown report generator: turns the CSVs under `results/` into a
//! single human-readable report (tables + shape checks), so a full
//! eval run ends with one reviewable document.
//!
//! `parakm eval --exp report` (or `report::generate(dir)`) reads
//! whatever CSVs exist — missing experiments are skipped with a note —
//! and writes `results/REPORT.md`.

use std::fmt::Write as _;
use std::path::Path;

use crate::error::Result;
use crate::util::csv;

/// Generate `REPORT.md` inside `results_dir`. Returns the report text.
pub fn generate(results_dir: &Path) -> Result<String> {
    let mut out = String::new();
    let _ = writeln!(out, "# parakmeans — evaluation report\n");
    let _ = writeln!(
        out,
        "Generated from the CSVs in `{}`. Shape checks follow DESIGN.md §5.\n",
        results_dir.display()
    );
    let _ = writeln!(
        out,
        "Hot path: `linalg::kernel` tier **{}** (detected: {}) in this reporting \
         process — per-experiment tiers are whatever was active when each CSV was \
         produced.\n",
        crate::linalg::kernel::active_tier(),
        crate::linalg::kernel::detect()
    );

    table1(results_dir, &mut out);
    thread_tables(results_dir, &mut out);
    offload_tables(results_dir, &mut out);
    speedup(results_dir, &mut out);
    scaling(results_dir, &mut out);
    ablations(results_dir, &mut out);
    oocore(results_dir, &mut out);
    pruned(results_dir, &mut out);
    dist(results_dir, &mut out);
    run_trace(results_dir, &mut out);
    bench_json(results_dir, &mut out);

    let path = results_dir.join("REPORT.md");
    std::fs::create_dir_all(results_dir)?;
    std::fs::write(&path, &out)?;
    Ok(out)
}

fn load(dir: &Path, rel: &str) -> Option<(Vec<String>, Vec<Vec<f64>>)> {
    let p = dir.join(rel);
    if !p.exists() {
        return None;
    }
    csv::read_table(&p).ok()
}

fn md_table(out: &mut String, header: &[&str], rows: &[Vec<String>]) {
    let _ = writeln!(out, "| {} |", header.join(" | "));
    let _ = writeln!(out, "|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        let _ = writeln!(out, "| {} |", r.join(" | "));
    }
    let _ = writeln!(out);
}

fn check(out: &mut String, label: &str, ok: bool) {
    let _ = writeln!(out, "- {} **{label}**", if ok { "✔" } else { "✘" });
}

fn table1(dir: &Path, out: &mut String) {
    let _ = writeln!(out, "## Table 1 — serial time vs K\n");
    let Some((_, rows)) = load(dir, "tables/table1.csv") else {
        let _ = writeln!(out, "_not run_\n");
        return;
    };
    // rows: n, k, secs, raw, iters — group by n
    let mut by_n: std::collections::BTreeMap<u64, Vec<&Vec<f64>>> = Default::default();
    for r in &rows {
        by_n.entry(r[0] as u64).or_default().push(r);
    }
    let mut md = Vec::new();
    let mut grows_with_k = true;
    for (n, cells) in &by_n {
        let mut row = vec![n.to_string()];
        for c in cells.iter() {
            row.push(format!("{:.4}s ({} it)", c[2], c[4] as u64));
        }
        // weak check: max-K cell slower than min-K cell
        if let (Some(first), Some(last)) = (cells.first(), cells.last()) {
            grows_with_k &= last[2] >= first[2] * 0.5;
        }
        md.push(row);
    }
    md_table(out, &["N", "K=4", "K=8", "K=11"], &md);
    check(out, "time grows with K (weak, iteration-count dominated)", grows_with_k);
    let _ = writeln!(out);
}

fn thread_tables(dir: &Path, out: &mut String) {
    for (name, title) in [("table2", "Table 2 — 2D"), ("table3", "Table 3 — 3D")] {
        let _ = writeln!(out, "## {title} shared-engine time vs p\n");
        let Some((_, rows)) = load(dir, &format!("tables/{name}.csv")) else {
            let _ = writeln!(out, "_not run_\n");
            continue;
        };
        let mut by_n: std::collections::BTreeMap<u64, Vec<&Vec<f64>>> = Default::default();
        for r in &rows {
            by_n.entry(r[0] as u64).or_default().push(r);
        }
        let mut md = Vec::new();
        let mut monotone = true;
        for (n, cells) in &by_n {
            let mut row = vec![n.to_string()];
            for c in cells.iter() {
                row.push(format!("{:.4}", c[2]));
            }
            if let (Some(first), Some(last)) = (cells.first(), cells.last()) {
                monotone &= last[2] <= first[2] * 1.1;
            }
            md.push(row);
        }
        md_table(out, &["N", "p=2", "p=4", "p=8", "p=16"], &md);
        check(out, "p=16 no slower than p=2 for every N", monotone);
        let _ = writeln!(out);
    }
}

fn offload_tables(dir: &Path, out: &mut String) {
    for (name, title) in [("table4", "Table 4 — 2D"), ("table5", "Table 5 — 3D")] {
        let _ = writeln!(out, "## {title} offload-engine time vs N\n");
        let Some((_, rows)) = load(dir, &format!("tables/{name}.csv")) else {
            let _ = writeln!(out, "_not run_\n");
            continue;
        };
        let md: Vec<Vec<String>> = rows
            .iter()
            .map(|r| vec![(r[0] as u64).to_string(), format!("{:.4}", r[1])])
            .collect();
        md_table(out, &["N", "time (s)"], &md);
    }
}

fn speedup(dir: &Path, out: &mut String) {
    for dim in [3, 2] {
        let _ = writeln!(out, "## Figures {} — speedup/efficiency {dim}D\n",
            if dim == 3 { "7/9" } else { "8/10" });
        let Some((_, rows)) = load(dir, &format!("figures/speedup_efficiency_{dim}d.csv"))
        else {
            let _ = writeln!(out, "_not run_\n");
            continue;
        };
        // rows: n, p, t_serial, t_parallel, speedup, efficiency
        let md: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    (r[0] as u64).to_string(),
                    (r[1] as u64).to_string(),
                    format!("{:.2}", r[4]),
                    format!("{:.2}", r[5]),
                ]
            })
            .collect();
        md_table(out, &["N", "p", "ψ", "ε"], &md);
        let all_speedup_positive = rows.iter().all(|r| r[4] > 1.0);
        check(out, "ψ(n,p) > 1 everywhere", all_speedup_positive);
        // speedup grows with N at p=16
        let p16: Vec<&Vec<f64>> = rows.iter().filter(|r| r[1] == 16.0).collect();
        let grows = p16.windows(2).all(|w| w[1][4] >= w[0][4] * 0.6);
        check(out, "ψ at p=16 grows with N (weak monotone)", grows);
        let _ = writeln!(out);
    }
}

fn scaling(dir: &Path, out: &mut String) {
    for dim in [3, 2] {
        let _ = writeln!(out, "## Figure {} — time vs scaling {dim}D\n",
            if dim == 3 { 11 } else { 12 });
        let Some((_, rows)) = load(dir, &format!("figures/scaling_{dim}d.csv")) else {
            let _ = writeln!(out, "_not run_\n");
            continue;
        };
        let md: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    (r[0] as u64).to_string(),
                    format!("{:.4}", r[1]),
                    format!("{:.4}", r[2]),
                    format!("{:.4}", r[3]),
                ]
            })
            .collect();
        md_table(out, &["N", "serial", "shared p=8", "offload"], &md);
        let offload_wins = rows.iter().all(|r| r[3] <= r[2]);
        check(out, "offload ≤ shared(p=8) at every N", offload_wins);
        let _ = writeln!(out);
    }
}

fn ablations(dir: &Path, out: &mut String) {
    let _ = writeln!(out, "## Ablations\n");
    if let Some((_, rows)) = load(dir, "ablations/a1_chunk.csv") {
        let md: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    (r[0] as u64).to_string(),
                    format!("{:.4}", r[1]),
                    (r[2] as u64).to_string(),
                ]
            })
            .collect();
        let _ = writeln!(out, "### A1 — chunk size (offload, raw wall)\n");
        md_table(out, &["chunk", "secs", "exec calls"], &md);
    }
    if let Some((_, rows)) = load(dir, "ablations/a2_merge.csv") {
        let md: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    (r[0] as u64).to_string(),
                    format!("{:.4}", r[1]),
                    format!("{:.4}", r[2]),
                ]
            })
            .collect();
        let _ = writeln!(out, "### A2 — merge policy (virtual totals)\n");
        md_table(out, &["p", "leader", "critical"], &md);
    }
    if let Some((header, _)) = load(dir, "ablations/a3_algorithms.csv") {
        let _ = writeln!(
            out,
            "### A3 — algorithms/init: see `ablations/a3_algorithms.csv` (columns: {})\n",
            header.join(", ")
        );
    }
}

fn oocore(dir: &Path, out: &mut String) {
    let _ = writeln!(out, "## Out-of-core streaming — chunk × shard sweep\n");
    let Some((_, rows)) = load(dir, "tables/oocore.csv") else {
        let _ = writeln!(out, "_not run_ (`cargo bench --bench streaming_oocore`)\n");
        return;
    };
    // rows: shards, chunk_rows, buffer_bytes, secs, iters, sse
    if rows.iter().any(|r| r.len() < 6) {
        let _ = writeln!(out, "_malformed oocore.csv (expected 6 columns)_\n");
        return;
    }
    let md: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                (r[0] as u64).to_string(),
                (r[1] as u64).to_string(),
                format!("{:.1}", r[2] / 1024.0),
                format!("{:.4}", r[3]),
                (r[4] as u64).to_string(),
            ]
        })
        .collect();
    md_table(out, &["shards", "chunk rows", "buffer KiB", "secs", "iters"], &md);
    // the contract's observable: chunk size can never change results,
    // so within each shard count every cell must land on identical f64
    // SSE bits and iteration count. Across shard counts the f64 merge
    // grouping differs legitimately, so nothing is compared here —
    // cross-shard agreement is checked exactly against the in-memory
    // twins inside the bench itself.
    let mut by_shards: std::collections::BTreeMap<u64, Vec<&Vec<f64>>> = Default::default();
    for r in &rows {
        by_shards.entry(r[0] as u64).or_default().push(r);
    }
    let same_sse = by_shards
        .values()
        .all(|grp| grp.windows(2).all(|w| w[0][5] == w[1][5]));
    let same_iters = by_shards
        .values()
        .all(|grp| grp.windows(2).all(|w| w[0][4] == w[1][4]));
    check(out, "identical SSE across every chunk size (per shard count)", same_sse);
    check(out, "identical iteration count across every chunk size (per shard count)", same_iters);
    let _ = writeln!(out);
}

fn pruned(dir: &Path, out: &mut String) {
    let _ = writeln!(out, "## Pruned × parallel — engine × threads × K sweep\n");
    let p = dir.join("tables/pruned.csv");
    if !p.exists() {
        let _ = writeln!(out, "_not run_ (`cargo bench --bench pruned_parallel`)\n");
        return;
    }
    // columns: engine, k, threads, sched, secs, speedup, efficiency,
    // skip_rate, iters — engine/sched are strings, so the string reader
    let Ok((_, rows)) = csv::read_rows(&p) else {
        let _ = writeln!(out, "_unreadable pruned.csv_\n");
        return;
    };
    if rows.iter().any(|r| r.len() < 9) {
        let _ = writeln!(out, "_malformed pruned.csv (expected 9 columns)_\n");
        return;
    }
    let num = |s: &str| s.parse::<f64>().unwrap_or(f64::NAN);
    let md: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r[0].clone(),
                r[1].clone(),
                r[2].clone(),
                r[3].clone(),
                format!("{:.4}", num(&r[4])),
                format!("{:.2}", num(&r[5])),
                format!("{:.2}", num(&r[6])),
                format!("{:.1}%", 100.0 * num(&r[7])),
                r[8].clone(),
            ]
        })
        .collect();
    md_table(out, &["engine", "K", "p", "sched", "secs", "ψ", "ε", "skip rate", "iters"], &md);
    // shape checks: skip rates are sane; pruned engines actually prune;
    // the pruned-engine iteration count never depends on p or sched
    let pruned_rows: Vec<&Vec<String>> =
        rows.iter().filter(|r| r[0] == "elkan" || r[0] == "hamerly").collect();
    let rates_sane = rows.iter().all(|r| {
        let s = num(&r[7]);
        (0.0..=1.0).contains(&s)
    });
    check(out, "skip rate in [0, 1] for every cell", rates_sane);
    let prunes = pruned_rows.iter().all(|r| num(&r[7]) > 0.0);
    check(out, "elkan/hamerly skip rate > 0 everywhere", prunes && !pruned_rows.is_empty());
    let mut iters_by_cfg: std::collections::BTreeMap<(String, String), f64> = Default::default();
    let mut iters_stable = true;
    for r in &pruned_rows {
        let key = (r[0].clone(), r[1].clone()); // (engine, k)
        let it = num(&r[8]);
        iters_stable &= *iters_by_cfg.entry(key).or_insert(it) == it;
    }
    check(out, "pruned-engine iterations independent of p and sched", iters_stable);
    let _ = writeln!(out);
}

fn dist(dir: &Path, out: &mut String) {
    let _ = writeln!(out, "## Distributed loopback — workers × K sweep\n");
    let Some((_, rows)) = load(dir, "tables/dist.csv") else {
        let _ = writeln!(out, "_not run_ (`cargo bench --bench dist_scaling`)\n");
        return;
    };
    // rows: dim, k, workers, sched (0 = static, 1 = elastic), secs,
    // speedup, efficiency, bytes_per_iter, iters, sse, identical
    if rows.iter().any(|r| r.len() < 11) {
        let _ = writeln!(out, "_malformed dist.csv (expected 11 columns)_\n");
        return;
    }
    let sched_name = |code: f64| if code == 1.0 { "elastic" } else { "static" };
    let md: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}D", r[0] as u64),
                (r[1] as u64).to_string(),
                (r[2] as u64).to_string(),
                sched_name(r[3]).to_string(),
                format!("{:.4}", r[4]),
                format!("{:.2}", r[5]),
                format!("{:.2}", r[6]),
                format!("{:.1}", r[7] / 1024.0),
                (r[8] as u64).to_string(),
            ]
        })
        .collect();
    md_table(
        out,
        &["dim", "K", "S", "sched", "secs", "ψ", "ε", "wire KiB/iter", "iters"],
        &md,
    );
    // every cell was cross-checked inside the bench — static against
    // threads(p=S), elastic against threads(p=S, steal) — and the CSV
    // records the verdict so the report can refuse to bless a sweep
    // whose identity check was skipped
    let all_identical = rows.iter().all(|r| r[10] == 1.0);
    check(out, "every dist cell bit-identical to its threads twin", all_identical);
    let bytes_positive = rows.iter().all(|r| r[7] > 0.0);
    check(out, "wire bytes/iter > 0 in every cell", bytes_positive);
    // iteration count is a pure function of the data/K: dist(S) ≡
    // threads(p=S) per scheduler, and static/elastic agree on
    // assignments (only the f64 merge grouping differs), so neither S
    // nor the scheduler may change it
    let mut iters_by_cfg: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
    let mut iters_stable = true;
    for r in &rows {
        let key = (r[0] as u64, r[1] as u64); // (dim, k)
        iters_stable &= *iters_by_cfg.entry(key).or_insert(r[8]) == r[8];
    }
    check(out, "iterations independent of worker count and scheduler per (dim, K)", iters_stable);
    let _ = writeln!(out);
}

/// Phase-share table from a `--trace` JSONL file dropped at
/// `results/trace.jsonl` (DESIGN.md §15): where each run iteration's
/// wall time went — assign, merge, update, bounds, wire, ckpt — both
/// absolute and as a share of the traced total.
fn run_trace(dir: &Path, out: &mut String) {
    use crate::util::trace::Phase;
    let _ = writeln!(out, "## Run trace — phase shares (trace.jsonl)\n");
    let p = dir.join("trace.jsonl");
    if !p.exists() {
        let _ = writeln!(out, "_not run_ (`parakm run ... --trace results/trace.jsonl`)\n");
        return;
    }
    let (iters, totals, total_ns) = match crate::util::trace::phase_totals(&p) {
        Ok(t) => t,
        Err(e) => {
            let _ = writeln!(out, "_unreadable trace: {e}_\n");
            return;
        }
    };
    let _ = writeln!(out, "{iters} traced iterations, {:.3} ms total in spans.\n", total_ns as f64 / 1e6);
    let md: Vec<Vec<String>> = Phase::ALL
        .iter()
        .enumerate()
        .map(|(i, ph)| {
            let ns = totals[i];
            let share = if total_ns > 0 { 100.0 * ns as f64 / total_ns as f64 } else { 0.0 };
            vec![
                ph.name().to_string(),
                format!("{:.3}", ns as f64 / 1e6),
                format!("{share:.1}%"),
            ]
        })
        .collect();
    md_table(out, &["phase", "total ms", "share"], &md);
    check(out, "trace parses with per-iteration phase_ns", iters > 0);
    let _ = writeln!(out);
}

fn bench_json(dir: &Path, out: &mut String) {
    use crate::util::json::Json;
    let _ = writeln!(out, "## Perf trajectory — distance policy × tier (bench.json)\n");
    let p = dir.join("bench.json");
    let Ok(text) = std::fs::read_to_string(&p) else {
        let _ = writeln!(
            out,
            "_not run_ (`cargo bench --bench distance_policy` / `--bench hotpath_micro`)\n"
        );
        return;
    };
    let Ok(parsed) = Json::parse(&text) else {
        let _ = writeln!(out, "_unreadable bench.json_\n");
        return;
    };
    let Some(rows) = parsed.as_arr() else {
        let _ = writeln!(out, "_malformed bench.json (expected an array)_\n");
        return;
    };
    let field = |r: &Json, k: &str| r.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
    let num = |r: &Json, k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    // serving rows (bench_json_serve_row) carry ns_per_request instead
    // of the per-point-iteration shape — render them separately
    let (serve_rows, train_rows): (Vec<&Json>, Vec<&Json>) =
        rows.iter().partition(|r| r.get("ns_per_request").is_some());
    let md: Vec<Vec<String>> = train_rows
        .iter()
        .map(|r| {
            vec![
                field(r, "bench"),
                field(r, "engine"),
                field(r, "policy"),
                field(r, "tier"),
                format!("{}", num(r, "n") as u64),
                format!("{}", num(r, "d") as u64),
                format!("{}", num(r, "k") as u64),
                format!("{:.1}", num(r, "ns_per_point_iter")),
                format!("{:.2}", num(r, "speedup_vs_exact_scalar")),
            ]
        })
        .collect();
    md_table(
        out,
        &["bench", "engine", "policy", "tier", "n", "d", "k", "ns/pt/iter", "ψ vs exact-scalar"],
        &md,
    );
    let sane = train_rows.iter().all(|r| num(r, "ns_per_point_iter") > 0.0);
    check(out, "ns/point positive in every row", sane);
    let _ = writeln!(out);

    let _ = writeln!(out, "## Perf trajectory — serving path (bench.json)\n");
    if serve_rows.is_empty() {
        let _ = writeln!(out, "_not run_ (`cargo bench --bench serving_load`)\n");
        return;
    }
    let md: Vec<Vec<String>> = serve_rows
        .iter()
        .map(|r| {
            vec![
                field(r, "bench"),
                field(r, "engine"),
                field(r, "tier"),
                format!("{}", num(r, "requests") as u64),
                format!("{}", num(r, "points_per_request") as u64),
                format!("{:.0}", num(r, "ns_per_request")),
                format!("{:.1}", num(r, "p50_us")),
                format!("{:.1}", num(r, "p99_us")),
            ]
        })
        .collect();
    md_table(
        out,
        &["bench", "engine", "tier", "requests", "pts/req", "ns/request", "p50 µs", "p99 µs"],
        &md,
    );
    let sane = serve_rows.iter().all(|r| {
        num(r, "ns_per_request") > 0.0 && num(r, "p50_us") <= num(r, "p99_us")
    });
    check(out, "serving rows positive with ordered percentiles", sane);
    let _ = writeln!(out);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parakm_report_tests");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("tables")).unwrap();
        std::fs::create_dir_all(dir.join("figures")).unwrap();
        dir
    }

    #[test]
    fn generates_from_partial_results() {
        let dir = fixture_dir();
        csv::write_table(
            &dir.join("tables/table1.csv"),
            &["n", "k", "secs", "raw_secs", "iters"],
            &[
                vec![1000.0, 4.0, 0.1, 0.1, 5.0],
                vec![1000.0, 8.0, 0.3, 0.3, 9.0],
                vec![1000.0, 11.0, 0.5, 0.5, 12.0],
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        assert!(report.contains("# parakmeans — evaluation report"));
        assert!(report.contains("Hot path: `linalg::kernel` tier"));
        assert!(report.contains("## Table 1"));
        assert!(report.contains("✔ **time grows with K"));
        // missing experiments noted, not fatal
        assert!(report.contains("_not run_"));
        assert!(dir.join("REPORT.md").exists());
    }

    #[test]
    fn serving_rows_render_in_their_own_table() {
        use crate::util::bench::{bench_json_row, bench_json_serve_row};
        use crate::util::json::Json;
        let dir = fixture_dir();
        let rows = vec![
            bench_json_row("hotpath", "threads", "exact", "scalar", 1000, 3, 4, 2.5, 1.0),
            bench_json_serve_row(
                "serving_load",
                "serve-poll",
                "scalar",
                200,
                32,
                85_000.0,
                60.0,
                400.0,
            ),
            bench_json_serve_row(
                "serving_load",
                "serve-threads",
                "scalar",
                200,
                32,
                90_000.0,
                70.0,
                500.0,
            ),
        ];
        std::fs::write(dir.join("bench.json"), Json::Arr(rows).to_string()).unwrap();
        let report = generate(&dir).unwrap();
        assert!(report.contains("## Perf trajectory — serving path"), "{report}");
        assert!(report.contains("serve-poll"), "{report}");
        assert!(
            report.contains("✔ **serving rows positive with ordered percentiles**"),
            "{report}"
        );
        // the training table's sanity check must not trip on serve rows
        assert!(report.contains("✔ **ns/point positive in every row**"), "{report}");
    }

    #[test]
    fn trace_section_renders_phase_shares() {
        let dir = fixture_dir();
        let lines = [
            r#"{"empty_events": 0, "iter": 1, "phase_ns": {"assign": 700, "bounds": 0, "ckpt": 100, "merge": 100, "update": 100, "wire": 0}, "per_worker": [], "sse": 10.5}"#,
            r#"{"empty_events": 1, "iter": 2, "phase_ns": {"assign": 600, "bounds": 0, "ckpt": 100, "merge": 200, "update": 100, "wire": 0}, "per_worker": [], "sse": 9.0}"#,
        ];
        std::fs::write(dir.join("trace.jsonl"), lines.join("\n")).unwrap();
        let report = generate(&dir).unwrap();
        assert!(report.contains("## Run trace — phase shares"), "{report}");
        assert!(report.contains("2 traced iterations"), "{report}");
        // assign = 1300 of 2100 ns ≈ 61.9%
        assert!(report.contains("61.9%"), "{report}");
        assert!(report.contains("✔ **trace parses with per-iteration phase_ns**"), "{report}");
    }

    #[test]
    fn speedup_checks_flag_regressions() {
        let dir = fixture_dir();
        csv::write_table(
            &dir.join("figures/speedup_efficiency_3d.csv"),
            &["n", "p", "t_serial", "t_parallel", "speedup", "efficiency"],
            &[
                vec![1000.0, 2.0, 1.0, 2.0, 0.5, 0.25], // speedup < 1!
                vec![1000.0, 16.0, 1.0, 0.5, 2.0, 0.125],
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        assert!(report.contains("✘ **ψ(n,p) > 1 everywhere**"), "{report}");
    }

    #[test]
    fn oocore_determinism_check() {
        let dir = fixture_dir();
        // SSE may differ BETWEEN shard counts (f64 merge grouping) but
        // never within one — this fixture exercises exactly that
        csv::write_table(
            &dir.join("tables/oocore.csv"),
            &["shards", "chunk_rows", "buffer_bytes", "secs", "iters", "sse"],
            &[
                vec![1.0, 4096.0, 49152.0, 1.0, 23.0, 5.5000001],
                vec![4.0, 4096.0, 196608.0, 0.4, 23.0, 5.5],
                vec![4.0, 65536.0, 3145728.0, 0.3, 23.0, 5.5],
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        assert!(report.contains("## Out-of-core streaming"), "{report}");
        let ok = "✔ **identical SSE across every chunk size (per shard count)**";
        assert!(report.contains(ok), "{report}");

        // a chunk-size-dependent SSE within one shard count flips it
        csv::write_table(
            &dir.join("tables/oocore.csv"),
            &["shards", "chunk_rows", "buffer_bytes", "secs", "iters", "sse"],
            &[
                vec![4.0, 4096.0, 196608.0, 0.4, 23.0, 5.5],
                vec![4.0, 65536.0, 3145728.0, 0.3, 23.0, 5.6],
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        let bad = "✘ **identical SSE across every chunk size (per shard count)**";
        assert!(report.contains(bad), "{report}");
    }

    #[test]
    fn pruned_section_checks_and_renders() {
        let dir = fixture_dir();
        let header = [
            "engine", "k", "threads", "sched", "secs", "speedup", "efficiency", "skip_rate",
            "iters",
        ];
        csv::write_rows(
            &dir.join("tables/pruned.csv"),
            &header,
            &[
                svec(["threads", "4", "1", "steal", "1.0", "1.0", "1.0", "0", "23"]),
                svec(["elkan", "4", "1", "steal", "0.4", "1.0", "1.0", "0.8", "23"]),
                svec(["elkan", "4", "4", "static", "0.15", "2.7", "0.67", "0.8", "23"]),
                svec(["hamerly", "4", "4", "steal", "0.1", "3.1", "0.78", "0.9", "23"]),
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        assert!(report.contains("## Pruned × parallel"), "{report}");
        assert!(report.contains("✔ **skip rate in [0, 1] for every cell**"), "{report}");
        assert!(report.contains("✔ **elkan/hamerly skip rate > 0 everywhere**"), "{report}");
        assert!(
            report.contains("✔ **pruned-engine iterations independent of p and sched**"),
            "{report}"
        );

        // an iteration count that shifts with p must flip the check
        csv::write_rows(
            &dir.join("tables/pruned.csv"),
            &header,
            &[
                svec(["elkan", "4", "1", "steal", "0.4", "1.0", "1.0", "0.8", "23"]),
                svec(["elkan", "4", "4", "steal", "0.15", "2.7", "0.67", "0.8", "24"]),
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        assert!(
            report.contains("✘ **pruned-engine iterations independent of p and sched**"),
            "{report}"
        );
    }

    fn svec<const N: usize>(cells: [&str; N]) -> Vec<String> {
        cells.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn dist_section_checks_and_renders() {
        let dir = fixture_dir();
        let header = [
            "dim", "k", "workers", "sched", "secs", "speedup", "efficiency", "bytes_per_iter",
            "iters", "sse", "identical",
        ];
        csv::write_table(
            &dir.join("tables/dist.csv"),
            &header,
            &[
                vec![2.0, 8.0, 1.0, 0.0, 1.0, 1.0, 1.0, 300.0, 23.0, 5.5, 1.0],
                vec![2.0, 8.0, 2.0, 0.0, 0.6, 1.7, 0.85, 450.0, 23.0, 5.5, 1.0],
                // elastic cells: same iterations, different sse bits is
                // legitimate (chunk-grouped fold) — only iters is keyed
                vec![2.0, 8.0, 2.0, 1.0, 0.7, 1.4, 0.71, 460.0, 23.0, 5.5001, 1.0],
                vec![3.0, 4.0, 4.0, 0.0, 0.3, 3.1, 0.78, 700.0, 31.0, 7.25, 1.0],
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        assert!(report.contains("## Distributed loopback"), "{report}");
        assert!(report.contains("| elastic |"), "{report}");
        assert!(
            report.contains("✔ **every dist cell bit-identical to its threads twin**"),
            "{report}"
        );
        assert!(report.contains("✔ **wire bytes/iter > 0 in every cell**"), "{report}");
        assert!(
            report
                .contains("✔ **iterations independent of worker count and scheduler per (dim, K)**"),
            "{report}"
        );

        // a failed identity check or S-dependent iteration count flips
        csv::write_table(
            &dir.join("tables/dist.csv"),
            &header,
            &[
                vec![2.0, 8.0, 1.0, 0.0, 1.0, 1.0, 1.0, 300.0, 23.0, 5.5, 1.0],
                vec![2.0, 8.0, 2.0, 1.0, 0.6, 1.7, 0.85, 450.0, 24.0, 5.5, 0.0],
            ],
        )
        .unwrap();
        let report = generate(&dir).unwrap();
        assert!(
            report.contains("✘ **every dist cell bit-identical to its threads twin**"),
            "{report}"
        );
        assert!(
            report
                .contains("✘ **iterations independent of worker count and scheduler per (dim, K)**"),
            "{report}"
        );
    }

    #[test]
    fn empty_dir_still_produces_report() {
        let dir = fixture_dir();
        let report = generate(&dir).unwrap();
        assert!(report.contains("_not run_"));
    }
}
