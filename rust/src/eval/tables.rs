//! Tables 1–5 runners (DESIGN.md §5: T1–T5).
//!
//! Each runner prints the paper-format table, writes a CSV twin under
//! `results/tables/`, and returns the rows for benches/tests. Rows
//! carry both the comparison time (virtual testbed for the shared
//! engine — DESIGN.md §8) and the raw 1-core wall-clock.

use std::path::PathBuf;

use crate::config::Engine;
use crate::data::gmm::workloads;
use crate::error::Result;
use crate::eval::{paper_dataset, results_dir, run_engine, Scale};
use crate::util::{csv, tables};

/// One measured cell: (N, parameter, secs, raw_secs, iterations).
#[derive(Debug, Clone)]
pub struct Cell {
    pub n: usize,
    pub param: usize, // K for T1; p for T2/T3; unused (0) for T4/T5
    pub secs: f64,
    pub raw_secs: f64,
    pub iterations: usize,
}

fn write_csv(name: &str, header: &[&str], rows: &[Vec<f64>]) -> Result<PathBuf> {
    let path = results_dir().join("tables").join(format!("{name}.csv"));
    csv::write_table(&path, header, rows)?;
    Ok(path)
}

/// TABLE 1 — serial time for K ∈ {4, 8, 11} on the largest 2D (500k)
/// and 3D (1M) datasets.
pub fn table1(scale: Scale) -> Result<Vec<Cell>> {
    let mut cells = Vec::new();
    let mut printed = Vec::new();
    for (dim, n_full) in [(2usize, 500_000usize), (3, 1_000_000)] {
        let n = scale.apply(n_full);
        let ds = paper_dataset(dim, n);
        let mut row = vec![format!("{n} ({dim}D)")];
        for k in workloads::TABLE1_KS {
            let t = run_engine(Engine::Serial, &ds, k, 1, 42)?;
            row.push(tables::secs(t.secs));
            cells.push(Cell {
                n,
                param: k,
                secs: t.secs,
                raw_secs: t.raw_secs,
                iterations: t.iterations,
            });
        }
        printed.push(row);
    }
    let rendered = tables::render(
        "TABLE 1. Size of dataset (N) vs time taken for convergence (serial)",
        &["N", "K = 4", "K = 8", "K = 11"],
        &printed,
    );
    println!("{rendered}");
    let csv_rows: Vec<Vec<f64>> = cells
        .iter()
        .map(|c| vec![c.n as f64, c.param as f64, c.secs, c.raw_secs, c.iterations as f64])
        .collect();
    write_csv("table1", &["n", "k", "secs", "raw_secs", "iters"], &csv_rows)?;
    Ok(cells)
}

/// Shared runner for Tables 2 (2D, K=8) and 3 (3D, K=4): time vs
/// thread count for the shared-memory engine.
fn thread_table(
    title: &str,
    name: &str,
    dim: usize,
    k: usize,
    sizes: &[usize],
    scale: Scale,
) -> Result<Vec<Cell>> {
    let mut cells = Vec::new();
    let mut printed = Vec::new();
    for &n_full in sizes {
        let n = scale.apply(n_full);
        let ds = paper_dataset(dim, n);
        let mut row = vec![n.to_string()];
        for p in workloads::THREADS {
            let t = run_engine(Engine::Shared, &ds, k, p, 42)?;
            row.push(tables::secs(t.secs));
            cells.push(Cell {
                n,
                param: p,
                secs: t.secs,
                raw_secs: t.raw_secs,
                iterations: t.iterations,
            });
        }
        printed.push(row);
    }
    let rendered = tables::render(
        title,
        &["N", "p = 2", "p = 4", "p = 8", "p = 16"],
        &printed,
    );
    println!("{rendered}");
    let csv_rows: Vec<Vec<f64>> = cells
        .iter()
        .map(|c| vec![c.n as f64, c.param as f64, c.secs, c.raw_secs, c.iterations as f64])
        .collect();
    write_csv(name, &["n", "p", "secs", "raw_secs", "iters"], &csv_rows)?;
    Ok(cells)
}

/// TABLE 2 — 2D dataset, time vs threads (K = 8).
pub fn table2(scale: Scale) -> Result<Vec<Cell>> {
    thread_table(
        "TABLE 2. 2D dataset time taken vs number of threads (K = 8, shared engine)",
        "table2",
        2,
        workloads::K_2D,
        &workloads::SIZES_2D,
        scale,
    )
}

/// TABLE 3 — 3D dataset, time vs threads (K = 4).
pub fn table3(scale: Scale) -> Result<Vec<Cell>> {
    thread_table(
        "TABLE 3. 3D dataset time taken vs number of threads (K = 4, shared engine)",
        "table3",
        3,
        workloads::K_3D,
        &workloads::SIZES_3D,
        scale,
    )
}

/// Shared runner for Tables 4 (2D) and 5 (3D): offload-engine time.
fn offload_table(
    title: &str,
    name: &str,
    dim: usize,
    k: usize,
    sizes: &[usize],
    scale: Scale,
) -> Result<Vec<Cell>> {
    let mut cells = Vec::new();
    let mut printed = Vec::new();
    for &n_full in sizes {
        let n = scale.apply(n_full);
        let ds = paper_dataset(dim, n);
        let t = run_engine(Engine::Offload, &ds, k, 1, 42)?;
        printed.push(vec![n.to_string(), tables::secs(t.secs)]);
        cells.push(Cell {
            n,
            param: 0,
            secs: t.secs,
            raw_secs: t.raw_secs,
            iterations: t.iterations,
        });
    }
    let rendered = tables::render(title, &["N", "Time Taken"], &printed);
    println!("{rendered}");
    let csv_rows: Vec<Vec<f64>> = cells
        .iter()
        .map(|c| vec![c.n as f64, c.secs, c.raw_secs, c.iterations as f64])
        .collect();
    write_csv(name, &["n", "secs", "raw_secs", "iters"], &csv_rows)?;
    Ok(cells)
}

/// TABLE 4 — 2D dataset size vs offload-engine time (K = 8).
pub fn table4(scale: Scale) -> Result<Vec<Cell>> {
    offload_table(
        "TABLE 4. 2D dataset size vs Time Taken (K = 8, offload engine)",
        "table4",
        2,
        workloads::K_2D,
        &workloads::SIZES_2D,
        scale,
    )
}

/// TABLE 5 — 3D dataset size vs offload-engine time (K = 4).
pub fn table5(scale: Scale) -> Result<Vec<Cell>> {
    offload_table(
        "TABLE 5. 3D dataset size vs Time Taken (K = 4, offload engine)",
        "table5",
        3,
        workloads::K_3D,
        &workloads::SIZES_3D,
        scale,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
            || std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts/manifest.json")
                .exists()
    }

    #[test]
    fn table1_smoke_shape() {
        // paper shape: time grows with K for fixed N
        let cells = table1(Scale::Smoke).unwrap();
        assert_eq!(cells.len(), 6);
        // per-dataset: K=11 slower than K=4 (iterations × K work)
        for chunk in cells.chunks(3) {
            assert!(
                chunk[2].secs > chunk[0].secs * 0.5,
                "K=11 unexpectedly much faster than K=4: {chunk:?}"
            );
        }
    }

    #[test]
    fn table3_smoke_speedup_shape() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        std::env::set_var("PARAKM_RESULTS", std::env::temp_dir().join("parakm_t3"));
        let cells = table3(Scale::Smoke).unwrap();
        assert_eq!(cells.len(), workloads::SIZES_3D.len() * workloads::THREADS.len());
        // paper shape: more threads => less (virtual) time from p=2 to
        // p=8 — observable only where the p=8 shard still spans at
        // least one full smallest chunk (4096 rows); smaller cases are
        // dominated by the single padded call per worker
        for rows in cells.chunks(workloads::THREADS.len()) {
            if rows[0].n / 8 >= 4096 {
                assert!(
                    rows[2].secs < rows[0].secs * 1.1,
                    "p=8 not faster than p=2: {rows:?}"
                );
            }
        }
    }
}
