//! Ablations A1–A3 (DESIGN.md §5) — the design choices the paper leaves
//! unexplored, quantified on the paper's headline workload (3D, K=4).
//!
//! - **A1 chunk size**: streaming-chunk size of the AOT engines
//!   (launch overhead vs padding waste vs device-buffer pressure).
//! - **A2 merge policy**: leader fold vs critical-section serialization
//!   in the shared engine's virtual clock.
//! - **A3 algorithms**: Lloyd vs Elkan vs Hamerly vs mini-batch, and
//!   random vs k-means++ init — wall-clock and SSE on identical data.

use crate::config::{Engine, Init, RunConfig};
use crate::coordinator::shared::{self, MergePolicy};
use crate::data::gmm::workloads;
use crate::error::Result;
use crate::eval::{paper_dataset, results_dir, run_engine, Scale};
use crate::kmeans::{self, KmeansConfig};
use crate::util::{csv, tables};

/// A1 — chunk-size sweep on the offload engine (3D, K=4).
/// Chunks must exist as artifacts: 16384, 65536, 262144.
pub fn chunk_size(scale: Scale) -> Result<Vec<(usize, f64, usize)>> {
    let n = scale.apply(1_000_000);
    let ds = paper_dataset(3, n);
    let mut rows = Vec::new();
    for chunk in [16384usize, 65536, 262144] {
        let cfg = RunConfig { k: workloads::K_3D, chunk, ..Default::default() };
        let run = crate::eval::with_runtime(&cfg.artifacts_dir.clone(), |rt| {
            crate::coordinator::offload::run_with(rt, &ds, &cfg)
        })?;
        println!(
            "A1 chunk={chunk:<7} time={:.4}s calls={} iters={}",
            run.wall_secs, run.exec_calls, run.result.iterations
        );
        rows.push((chunk, run.wall_secs, run.exec_calls));
    }
    let csv_rows: Vec<Vec<f64>> =
        rows.iter().map(|r| vec![r.0 as f64, r.1, r.2 as f64]).collect();
    csv::write_table(
        &results_dir().join("ablations/a1_chunk.csv"),
        &["chunk", "secs", "exec_calls"],
        &csv_rows,
    )?;
    Ok(rows)
}

/// A2 — merge policy: virtual-clock totals for leader vs critical at
/// p ∈ {2, 4, 8, 16} (3D, K=4).
pub fn merge_policy(scale: Scale) -> Result<Vec<(usize, f64, f64)>> {
    let n = scale.apply(1_000_000);
    let ds = paper_dataset(3, n);
    let cfg = RunConfig { k: workloads::K_3D, ..Default::default() };
    let mut rows = Vec::new();
    for p in workloads::THREADS {
        let leader = crate::eval::with_runtime(&cfg.artifacts_dir.clone(), |rt| {
            shared::run_with(rt, &ds, &cfg, p, MergePolicy::Leader)
        })?;
        let critical = crate::eval::with_runtime(&cfg.artifacts_dir.clone(), |rt| {
            shared::run_with(rt, &ds, &cfg, p, MergePolicy::Critical)
        })?;
        let (tl, tc) = (leader.table_secs(), critical.table_secs());
        println!("A2 p={p:<3} leader={tl:.4}s critical={tc:.4}s overhead_ratio={:.3}", tc / tl);
        rows.push((p, tl, tc));
    }
    let csv_rows: Vec<Vec<f64>> =
        rows.iter().map(|r| vec![r.0 as f64, r.1, r.2]).collect();
    csv::write_table(
        &results_dir().join("ablations/a2_merge.csv"),
        &["p", "leader_secs", "critical_secs"],
        &csv_rows,
    )?;
    Ok(rows)
}

/// A3 — algorithm/init matrix on identical data (3D, K=4):
/// (label, secs, sse, iterations).
pub fn algorithms(scale: Scale) -> Result<Vec<(String, f64, f64, usize)>> {
    let n = scale.apply(1_000_000);
    let ds = paper_dataset(3, n);
    let k = workloads::K_3D;
    let mut rows: Vec<(String, f64, f64, usize)> = Vec::new();

    for engine in [Engine::Serial, Engine::Elkan, Engine::Hamerly, Engine::MiniBatch] {
        let t = run_engine(engine, &ds, k, 1, 42)?;
        rows.push((engine.to_string(), t.secs, t.sse, t.iterations));
    }
    // init comparison on serial Lloyd
    for (label, init) in [("serial+random", Init::Random), ("serial+kpp", Init::KmeansPlusPlus)] {
        let kc = KmeansConfig::new(k).with_seed(42).with_init(init);
        let t0 = std::time::Instant::now();
        let r = kmeans::serial::run(&ds, &kc);
        rows.push((label.to_string(), t0.elapsed().as_secs_f64(), r.sse, r.iterations));
    }

    let printed: Vec<Vec<String>> = rows
        .iter()
        .map(|(l, s, sse, it)| {
            vec![l.clone(), tables::secs(*s), format!("{sse:.3e}"), it.to_string()]
        })
        .collect();
    println!(
        "{}",
        tables::render(
            "A3. Algorithm / init ablation (3D, K=4)",
            &["variant", "secs", "sse", "iters"],
            &printed
        )
    );
    let csv_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(l, s, sse, it)| vec![l.clone(), s.to_string(), sse.to_string(), it.to_string()])
        .collect();
    csv::write_rows(
        &results_dir().join("ablations/a3_algorithms.csv"),
        &["variant", "secs", "sse", "iters"],
        &csv_rows,
    )?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a3_accelerated_variants_match_lloyd_sse() {
        std::env::set_var("PARAKM_RESULTS", std::env::temp_dir().join("parakm_abl"));
        let rows = algorithms(Scale::Smoke).unwrap();
        let sse_of = |name: &str| {
            rows.iter().find(|r| r.0 == name).map(|r| r.2).unwrap()
        };
        let lloyd = sse_of("serial");
        // Elkan/Hamerly are exact: same SSE as Lloyd
        assert!((sse_of("elkan") - lloyd).abs() / lloyd < 1e-4);
        assert!((sse_of("hamerly") - lloyd).abs() / lloyd < 1e-4);
        // mini-batch approximate: within 10% on this easy mixture
        assert!(sse_of("minibatch") <= lloyd * 1.10);
    }
}
