//! Experiment harness: regenerates every table and figure in the
//! paper's evaluation (DESIGN.md §5 maps ids to modules).
//!
//! Every runner is callable from `cargo bench` targets, from the CLI
//! (`parakm eval --exp t1`), and from the E2E example. Output goes to
//! `results/` as printed tables (paper format), CSV series and SVG
//! figures.
//!
//! Scaling: the full paper workloads (up to 1M×3D) are expensive on a
//! 1-core container, so every runner takes a [`Scale`]; `Scale::Full`
//! is the paper's exact sizes, `Scale::Smoke` a 50× reduction with the
//! same structure (used by `cargo test` integration and quick runs).
//! `PARAKM_SCALE=full|smoke` selects at bench time.

pub mod ablations;
pub mod figures;
pub mod report;
pub mod tables;

use std::path::PathBuf;

use crate::config::{DistancePolicy, Engine, RunConfig, SchedMode};
use crate::coordinator::{offload, shared};
use crate::data::gmm::{workloads, MixtureSpec};
use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::{self, KmeansConfig};

/// Workload scale for the experiment runners.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// The paper's exact dataset sizes.
    Full,
    /// Same structure, 50× smaller (CI / quick iteration).
    Smoke,
}

impl Scale {
    /// Read from `PARAKM_SCALE` (default smoke — full runs opt in).
    pub fn from_env() -> Scale {
        match std::env::var("PARAKM_SCALE").as_deref() {
            Ok("full") => Scale::Full,
            _ => Scale::Smoke,
        }
    }

    pub fn apply(&self, n: usize) -> usize {
        match self {
            Scale::Full => n,
            // /10 keeps p=8 shards above the smallest artifact chunk on
            // the larger sizes, so scaling shapes remain observable
            Scale::Smoke => (n / 10).max(1000),
        }
    }
}

/// Where results (tables, CSVs, SVGs) are written.
pub fn results_dir() -> PathBuf {
    std::env::var("PARAKM_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Generate a paper dataset (deterministic per (dim, n)).
pub fn paper_dataset(dim: usize, n: usize) -> Dataset {
    let spec = match dim {
        2 => MixtureSpec::paper_2d(workloads::GEN_K_2D),
        3 => MixtureSpec::paper_3d(workloads::GEN_K_3D),
        _ => panic!("paper datasets are 2D/3D"),
    };
    spec.generate(n, workloads::seed_for(dim, n))
}

thread_local! {
    /// Per-thread runtime cache: compiled executables are reused across
    /// every eval cell instead of recompiling per run (PjRtClient is
    /// `Rc`-based, hence thread-local rather than global).
    static RUNTIME: std::cell::RefCell<Option<(PathBuf, crate::runtime::Runtime)>> =
        const { std::cell::RefCell::new(None) };
}

/// Run `f` with the cached thread-local [`crate::runtime::Runtime`] for
/// `dir`, creating or replacing it when the artifacts dir changes.
pub fn with_runtime<T>(
    dir: &std::path::Path,
    f: impl FnOnce(&mut crate::runtime::Runtime) -> Result<T>,
) -> Result<T> {
    RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        let rebuild = match &*slot {
            Some((cached_dir, _)) => cached_dir != dir,
            None => true,
        };
        if rebuild {
            *slot = Some((dir.to_path_buf(), crate::runtime::Runtime::new_or_native(dir)?));
        }
        let (_, rt) = slot.as_mut().expect("just initialized");
        f(rt)
    })
}

/// Timing outcome of one engine run, as the tables need it.
#[derive(Debug, Clone)]
pub struct Timed {
    pub engine: Engine,
    /// Seconds used for paper-table comparison (virtual-testbed time
    /// for the shared engine, real wall-clock otherwise).
    pub secs: f64,
    /// Real wall-clock on this container (always recorded).
    pub raw_secs: f64,
    pub iterations: usize,
    pub sse: f64,
    pub converged: bool,
    pub assign: Vec<i32>,
    pub centroids: Vec<f32>,
}

/// Run one engine on a dataset with paper-standard settings.
/// `threads` is the worker count for Threads/Shared/Elkan/Hamerly and
/// the shard count for OutOfCore (which requires `threads >= 1`);
/// ignored by the other engines.
pub fn run_engine(
    engine: Engine,
    ds: &Dataset,
    k: usize,
    threads: usize,
    seed: u64,
) -> Result<Timed> {
    run_engine_policy(engine, ds, k, threads, seed, DistancePolicy::Exact)
}

/// [`run_engine`] under an explicit distance policy. The AOT
/// coordinator engines (shared/offload/streaming) run their own
/// executables and only support `exact`; requesting `dot` there is a
/// typed config error rather than a silent fallback.
pub fn run_engine_policy(
    engine: Engine,
    ds: &Dataset,
    k: usize,
    threads: usize,
    seed: u64,
    distance: DistancePolicy,
) -> Result<Timed> {
    if distance == DistancePolicy::Dot && !engine.supports_distance_policy() {
        return Err(crate::error::Error::Config(format!(
            "distance policy dot applies to the pure-rust engines, not `{engine}`"
        )));
    }
    let kc = KmeansConfig::new(k).with_seed(seed).with_distance(distance);
    let t0 = std::time::Instant::now();
    let (secs, raw, result) = match engine {
        Engine::Serial => {
            let r = kmeans::serial::run(ds, &kc);
            let dt = t0.elapsed().as_secs_f64();
            (dt, dt, r)
        }
        Engine::Threads => {
            let r = kmeans::parallel::run(ds, &kc, threads);
            let dt = t0.elapsed().as_secs_f64();
            (dt, dt, r)
        }
        Engine::Elkan => {
            // results are bit-identical for every worker count, so
            // threads only changes wall-clock here
            let r = kmeans::elkan::run_threads(ds, &kc, threads, SchedMode::Steal);
            let dt = t0.elapsed().as_secs_f64();
            (dt, dt, r)
        }
        Engine::Hamerly => {
            let r = kmeans::hamerly::run_threads(ds, &kc, threads, SchedMode::Steal);
            let dt = t0.elapsed().as_secs_f64();
            (dt, dt, r)
        }
        Engine::MiniBatch => {
            let r = kmeans::minibatch::run(ds, &kc, 8192);
            let dt = t0.elapsed().as_secs_f64();
            (dt, dt, r)
        }
        Engine::Shared => {
            let cfg = RunConfig { k, seed, threads, ..Default::default() };
            let run = with_runtime(&cfg.artifacts_dir.clone(), |rt| {
                shared::run_with(rt, ds, &cfg, threads, shared::MergePolicy::Leader)
            })?;
            (run.table_secs(), run.wall_secs, run.result)
        }
        Engine::Offload => {
            let cfg = RunConfig { k, seed, ..Default::default() };
            let run = with_runtime(&cfg.artifacts_dir.clone(), |rt| {
                offload::run_with(rt, ds, &cfg)
            })?;
            (run.table_secs(), run.wall_secs, run.result)
        }
        Engine::Streaming => {
            // materialize to a temp file: the streaming engine is
            // file-oriented by design (bounded memory)
            let path = std::env::temp_dir().join(format!(
                "parakm_eval_stream_{}_{}.pkd",
                ds.dim(),
                ds.len()
            ));
            crate::data::io::write_binary(&path, ds)?;
            let cfg = RunConfig { k, seed, ..Default::default() };
            let run = with_runtime(&cfg.artifacts_dir.clone(), |rt| {
                crate::coordinator::streaming::run_file_with(rt, &path, &cfg)
            })?;
            let _ = std::fs::remove_file(&path);
            (run.table_secs(), run.wall_secs, run.result)
        }
        Engine::OutOfCore => {
            use crate::kmeans::streaming::{self, StreamOpts};
            let src = crate::data::MemorySource::new(ds);
            // paper-standard settings: default chunk, no budget —
            // `threads` shards (chunk/budget sweeps live in
            // benches/streaming_oocore.rs)
            let opts = StreamOpts::resolve(ds.dim(), threads, 0, 0)?;
            let r = streaming::run(&src, &kc, &opts)?;
            let dt = t0.elapsed().as_secs_f64();
            (dt, dt, r)
        }
        Engine::Dist => {
            // loopback cluster: `threads` shard workers on localhost —
            // the full wire protocol, timed including worker spawn.
            // Deliberately the *static* scheduler: the t-tables compare
            // dist against threads-static bit-for-bit. The elastic
            // scheduler's identity contract (vs threads-steal) is
            // pinned in kmeans::dist::elastic tests and swept in
            // benches/dist_scaling.rs

            let cluster =
                crate::cluster::LoopbackCluster::spawn_dataset(ds, threads.max(1), 65_536)?;
            let run = crate::kmeans::dist::run(
                &cluster.addrs,
                &kc,
                &crate::kmeans::dist::DistOpts::default(),
            )?;
            cluster.join()?;
            let dt = t0.elapsed().as_secs_f64();
            (dt, dt, run.result)
        }
    };
    Ok(Timed {
        engine,
        secs,
        raw_secs: raw,
        iterations: result.iterations,
        sse: result.sse,
        converged: result.converged,
        assign: result.assign,
        centroids: result.centroids,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_applies() {
        assert_eq!(Scale::Full.apply(1_000_000), 1_000_000);
        assert_eq!(Scale::Smoke.apply(1_000_000), 100_000);
        assert_eq!(Scale::Smoke.apply(5_000), 1000); // floor
    }

    #[test]
    fn paper_dataset_shapes() {
        let d2 = paper_dataset(2, 5000);
        assert_eq!(d2.dim(), 2);
        assert_eq!(d2.len(), 5000);
        let d3 = paper_dataset(3, 5000);
        assert_eq!(d3.dim(), 3);
    }

    #[test]
    fn run_engine_serial_smoke() {
        let ds = paper_dataset(3, 3000);
        let t = run_engine(Engine::Serial, &ds, 4, 1, 42).unwrap();
        assert!(t.converged);
        assert!(t.secs > 0.0);
        assert_eq!(t.assign.len(), 3000);
    }

    #[test]
    fn run_engine_policy_dot_matches_exact_and_rejects_aot() {
        let ds = paper_dataset(3, 2000);
        let exact = run_engine(Engine::Serial, &ds, 4, 1, 42).unwrap();
        let dot =
            run_engine_policy(Engine::Serial, &ds, 4, 1, 42, DistancePolicy::Dot).unwrap();
        assert_eq!(dot.assign, exact.assign);
        assert_eq!(dot.iterations, exact.iterations);
        assert!((dot.sse - exact.sse).abs() / exact.sse.max(1.0) < 1e-5);
        assert!(run_engine_policy(Engine::Offload, &ds, 4, 1, 42, DistancePolicy::Dot).is_err());
    }

    #[test]
    fn run_engine_dist_matches_serial() {
        let ds = paper_dataset(2, 2000);
        let serial = run_engine(Engine::Serial, &ds, 4, 1, 42).unwrap();
        let dist = run_engine(Engine::Dist, &ds, 4, 2, 42).unwrap();
        assert_eq!(dist.assign, serial.assign);
        assert_eq!(dist.iterations, serial.iterations);
        assert_eq!(dist.converged, serial.converged);
    }
}
