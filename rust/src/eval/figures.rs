//! Figures 1–12 runners (DESIGN.md §5: F1–F12).
//!
//! - F1–F6: cluster scatter plots, serial vs parallel, checked by ARI
//!   instead of the paper's eyeball comparison (plus the SVGs for the
//!   eyeball anyway).
//! - F7/F8: speedup ψ(n, p); F9/F10: efficiency ε(n, p); F11/F12:
//!   time vs dataset scale — all as CSV series + SVG line charts.

use crate::config::Engine;
use crate::data::gmm::workloads;
use crate::error::Result;
use crate::eval::{paper_dataset, results_dir, run_engine, Scale};
use crate::metrics;
use crate::util::svg::{self, Series};

/// Outcome of the cluster-figure pair (F1/F2, F3/F4, F5/F6): the ARI
/// between serial and parallel assignments, which the paper asserts
/// visually ("the parallel program achieves similar clustering").
#[derive(Debug, Clone)]
pub struct ClusterFigure {
    pub name: String,
    pub n: usize,
    pub ari_serial_vs_parallel: f64,
    pub serial_svg: std::path::PathBuf,
    pub parallel_svg: std::path::PathBuf,
}

/// Figures 1–4 (3D, K=4, 1M and 400k) and 5–6 (2D, K=11, 500k).
pub fn cluster_figures(scale: Scale) -> Result<Vec<ClusterFigure>> {
    let jobs: [(usize, usize, usize, &str); 3] = [
        (3, 1_000_000, workloads::K_3D, "fig1_2_3d_1m"),
        (3, 400_000, workloads::K_3D, "fig3_4_3d_400k"),
        (2, 500_000, 11, "fig5_6_2d_500k"),
    ];
    let dir = results_dir().join("figures");
    let mut out = Vec::new();
    for (dim, n_full, k, name) in jobs {
        let n = scale.apply(n_full);
        let ds = paper_dataset(dim, n);
        let serial = run_engine(Engine::Serial, &ds, k, 1, 42)?;
        // Offload is "the parallel program" of Figures 2/4/6 (OpenACC)
        let parallel = run_engine(Engine::Offload, &ds, k, 1, 42)?;
        let ari = metrics::adjusted_rand_index(&serial.assign, &parallel.assign);

        let xs = ds.column(0);
        let ys = ds.column(1);
        let s_path = dir.join(format!("{name}_serial.svg"));
        let p_path = dir.join(format!("{name}_parallel.svg"));
        svg::scatter(
            &s_path,
            &format!("Serial K-Means, N={n} {dim}D, K={k} (x0/x1 projection)"),
            &xs,
            &ys,
            &serial.assign,
            20_000,
        )?;
        svg::scatter(
            &p_path,
            &format!("Parallel K-Means (offload), N={n} {dim}D, K={k} — ARI vs serial: {ari:.4}"),
            &xs,
            &ys,
            &parallel.assign,
            20_000,
        )?;
        println!("FIGURE {name}: ARI(serial, parallel) = {ari:.5}");
        out.push(ClusterFigure {
            name: name.to_string(),
            n,
            ari_serial_vs_parallel: ari,
            serial_svg: s_path,
            parallel_svg: p_path,
        });
    }
    Ok(out)
}

/// One speedup/efficiency series point.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub n: usize,
    pub p: usize,
    pub t_serial: f64,
    pub t_parallel: f64,
    pub speedup: f64,
    pub efficiency: f64,
}

/// Figures 7–10: speedup and efficiency vs p, one series per dataset
/// size, for `dim` ∈ {3 (F7/F9), 2 (F8/F10)}.
pub fn speedup_efficiency(dim: usize, scale: Scale) -> Result<Vec<ScalingPoint>> {
    let (sizes, k): (&[usize], usize) = if dim == 3 {
        (&workloads::SIZES_3D, workloads::K_3D)
    } else {
        (&workloads::SIZES_2D, workloads::K_2D)
    };
    let mut points = Vec::new();
    for &n_full in sizes {
        let n = scale.apply(n_full);
        let ds = paper_dataset(dim, n);
        // ψ's denominator substrate must match its numerator (the
        // paper divides its serial C time by its OpenMP C time):
        // here both sides are the AOT shared engine, serial = p 1.
        let serial = run_engine(Engine::Shared, &ds, k, 1, 42)?;
        for p in workloads::THREADS {
            let par = run_engine(Engine::Shared, &ds, k, p, 42)?;
            points.push(ScalingPoint {
                n,
                p,
                t_serial: serial.secs,
                t_parallel: par.secs,
                speedup: metrics::speedup(serial.secs, par.secs),
                efficiency: metrics::efficiency(serial.secs, par.secs, p),
            });
        }
    }

    let dir = results_dir().join("figures");
    let mk_series = |f: &dyn Fn(&ScalingPoint) -> f64| -> Vec<Series> {
        sizes
            .iter()
            .map(|&n_full| {
                let n = scale.apply(n_full);
                Series {
                    name: Box::leak(format!("N={n}").into_boxed_str()),
                    points: points
                        .iter()
                        .filter(|pt| pt.n == n)
                        .map(|pt| (pt.p as f64, f(pt)))
                        .collect(),
                }
            })
            .collect()
    };
    let fig_s = if dim == 3 { 7 } else { 8 };
    let fig_e = if dim == 3 { 9 } else { 10 };
    svg::line_chart(
        &dir.join(format!("fig{fig_s}_speedup_{dim}d.svg")),
        &format!("FIGURE {fig_s}. Speedup for {dim}D Dataset"),
        "threads p",
        "speedup psi(n,p)",
        &mk_series(&|pt| pt.speedup),
    )?;
    svg::line_chart(
        &dir.join(format!("fig{fig_e}_efficiency_{dim}d.svg")),
        &format!("FIGURE {fig_e}. Efficiency for {dim}D Dataset"),
        "threads p",
        "efficiency eps(n,p)",
        &mk_series(&|pt| pt.efficiency),
    )?;
    let rows: Vec<Vec<f64>> = points
        .iter()
        .map(|pt| {
            vec![pt.n as f64, pt.p as f64, pt.t_serial, pt.t_parallel, pt.speedup, pt.efficiency]
        })
        .collect();
    crate::util::csv::write_table(
        &dir.join(format!("speedup_efficiency_{dim}d.csv")),
        &["n", "p", "t_serial", "t_parallel", "speedup", "efficiency"],
        &rows,
    )?;
    for pt in &points {
        println!(
            "FIGURE {fig_s}/{fig_e} {dim}D  N={:<8} p={:<2} psi={:.3} eps={:.3}",
            pt.n, pt.p, pt.speedup, pt.efficiency
        );
    }
    Ok(points)
}

/// Figures 11–12: time vs dataset scale for serial / shared(p=8) /
/// offload, per dim.
pub fn time_vs_scaling(dim: usize, scale: Scale) -> Result<Vec<(usize, f64, f64, f64)>> {
    let (sizes, k): (&[usize], usize) = if dim == 3 {
        (&workloads::SIZES_3D, workloads::K_3D)
    } else {
        (&workloads::SIZES_2D, workloads::K_2D)
    };
    let mut rows = Vec::new();
    for &n_full in sizes {
        let n = scale.apply(n_full);
        let ds = paper_dataset(dim, n);
        let serial = run_engine(Engine::Serial, &ds, k, 1, 42)?;
        let shared = run_engine(Engine::Shared, &ds, k, 8, 42)?;
        let offload = run_engine(Engine::Offload, &ds, k, 1, 42)?;
        println!(
            "FIGURE {} {dim}D  N={n:<8} serial={:.4}s shared(p=8)={:.4}s offload={:.4}s",
            if dim == 3 { 11 } else { 12 },
            serial.secs,
            shared.secs,
            offload.secs
        );
        rows.push((n, serial.secs, shared.secs, offload.secs));
    }
    let dir = results_dir().join("figures");
    let fig = if dim == 3 { 11 } else { 12 };
    let series = [
        Series { name: "serial", points: rows.iter().map(|r| (r.0 as f64, r.1)).collect() },
        Series { name: "shared p=8", points: rows.iter().map(|r| (r.0 as f64, r.2)).collect() },
        Series { name: "offload", points: rows.iter().map(|r| (r.0 as f64, r.3)).collect() },
    ];
    svg::line_chart(
        &dir.join(format!("fig{fig}_scaling_{dim}d.svg")),
        &format!("FIGURE {fig}. Time taken vs Scaling for {dim}D Datasets"),
        "dataset size N",
        "time (s)",
        &series,
    )?;
    let csv_rows: Vec<Vec<f64>> =
        rows.iter().map(|r| vec![r.0 as f64, r.1, r.2, r.3]).collect();
    crate::util::csv::write_table(
        &dir.join(format!("scaling_{dim}d.csv")),
        &["n", "serial", "shared_p8", "offload"],
        &csv_rows,
    )?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    #[test]
    fn cluster_figures_parallel_matches_serial() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        std::env::set_var("PARAKM_RESULTS", std::env::temp_dir().join("parakm_figs"));
        let figs = cluster_figures(Scale::Smoke).unwrap();
        assert_eq!(figs.len(), 3);
        for f in &figs {
            // the paper's claim: parallel == serial clustering
            assert!(
                f.ari_serial_vs_parallel > 0.99,
                "{}: ARI {}",
                f.name,
                f.ari_serial_vs_parallel
            );
            assert!(f.serial_svg.exists() && f.parallel_svg.exists());
        }
    }

    #[test]
    fn speedup_shape_3d() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        std::env::set_var("PARAKM_RESULTS", std::env::temp_dir().join("parakm_figs2"));
        let pts = speedup_efficiency(3, Scale::Smoke).unwrap();
        // paper shape: speedup > 1 and grows from p=2 to p=8 for the
        // largest dataset; efficiency peaks at p=2
        let largest = pts.iter().filter(|p| p.n == pts.last().unwrap().n).collect::<Vec<_>>();
        let by_p = |p: usize| largest.iter().find(|x| x.p == p).unwrap();
        assert!(by_p(2).speedup > 1.0, "{:?}", by_p(2));
        assert!(by_p(8).speedup > by_p(2).speedup * 0.9);
        assert!(by_p(2).efficiency >= by_p(16).efficiency);
    }
}
