//! Dense linear-algebra helpers plus the SIMD-dispatched assign/
//! accumulate kernel subsystem ([`kernel`]).
//!
//! The scalar helpers below operate on row-major `f32`/`f64` slices
//! and favor clarity; the [`kernel`] module is the blocked, runtime-
//! dispatched (AVX2/NEON/scalar) hot path every engine shares — see
//! `rust/src/linalg/README.md` for the design.

pub mod kernel;

/// Squared L2 distance between two d-vectors.
#[inline(always)]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Squared L2 distance, f64 accumulate (metrics paths that must not
/// drift on 1M-point sums).
#[inline(always)]
pub fn sqdist_f64(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    acc
}

/// Dot product.
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// `y += x`.
#[inline(always)]
pub fn add_assign(y: &mut [f64], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += x[i] as f64;
    }
}

/// Cholesky factorization of a symmetric positive-definite `d×d` matrix
/// (row-major). Returns lower-triangular `L` with `L·Lᵀ = A`, or `None`
/// if not positive definite.
pub fn cholesky(a: &[f64], d: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), d * d);
    let mut l = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut sum = a[i * d + j];
            for k in 0..j {
                sum -= l[i * d + k] * l[j * d + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * d + i] = sum.sqrt();
            } else {
                l[i * d + j] = sum / l[j * d + j];
            }
        }
    }
    Some(l)
}

/// `y = L·x` for lower-triangular `L` (d×d row-major).
pub fn tril_matvec(l: &[f64], x: &[f64], d: usize) -> Vec<f64> {
    let mut y = vec![0.0f64; d];
    tril_matvec_into(l, x, d, &mut y);
    y
}

/// [`tril_matvec`] into a caller-owned buffer — the allocation-free
/// form for per-row hot loops (GMM sampling).
pub fn tril_matvec_into(l: &[f64], x: &[f64], d: usize, y: &mut [f64]) {
    assert_eq!(y.len(), d);
    for i in 0..d {
        let mut acc = 0.0;
        for j in 0..=i {
            acc += l[i * d + j] * x[j];
        }
        y[i] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqdist_basic() {
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(sqdist(&[1.0], &[1.0]), 0.0);
        assert_eq!(sqdist_f64(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut y = vec![1.0f64, 2.0];
        add_assign(&mut y, &[0.5, 0.5]);
        assert_eq!(y, vec![1.5, 2.5]);
    }

    #[test]
    fn cholesky_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let l = cholesky(&a, 2).unwrap();
        assert_eq!(l, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = [[4, 2], [2, 3]] — SPD
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2).unwrap();
        // verify L L^T = A
        for i in 0..2 {
            for j in 0..2 {
                let mut acc = 0.0;
                for k in 0..2 {
                    acc += l[i * 2 + k] * l[j * 2 + k];
                }
                assert!((acc - a[i * 2 + j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }

    #[test]
    fn tril_matvec_applies() {
        let l = vec![2.0, 0.0, 1.0, 3.0];
        let y = tril_matvec(&l, &[1.0, 1.0], 2);
        assert_eq!(y, vec![2.0, 4.0]);
    }
}
