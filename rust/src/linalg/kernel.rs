//! SIMD-dispatched assign/accumulate kernels — the crate-wide hot path.
//!
//! Every engine's per-iteration cost is dominated by one loop: for each
//! point, the squared distance to every centroid, an argmin, and a
//! statistics update. This module implements that loop once, blocked
//! and vectorized, with runtime feature dispatch:
//!
//! - **tiling**: points are processed in blocks of [`POINTS_BLOCK`]
//!   rows, transposed into a `d × POINTS_BLOCK` tile so the inner loop
//!   vectorizes *across points* for arbitrary `d` (not just the old
//!   d ∈ {2, 3} monomorphizations). Centroids are walked in blocks of
//!   [`CENTROID_BLOCK`] so large-`k` models stay cache-resident.
//! - **dispatch**: AVX2 (x86_64) and NEON (aarch64) tiers via
//!   `std::arch`, selected once per process by [`active_tier`]; a
//!   portable scalar tier is always available and is the reference
//!   implementation.
//! - **bit-identical results**: the SIMD tiers perform, per point, the
//!   *same sequence* of f32 operations as the scalar tier (lane-per-
//!   point layout, mul+add — never FMA — and strict `<` argmin with
//!   ascending centroid index). Assignments, best distances, and the
//!   f64-accumulated sums are therefore identical across tiers, which
//!   the property tests assert exactly.
//! - **two distance formulations** ([`DistancePolicy`], DESIGN.md §11):
//!   the subtract-square loop above is the `exact` reference every
//!   bit-identity contract is defined against; the `dot` policy
//!   expands `‖x − μ‖² = ‖x‖² − 2·x·μ + ‖μ‖²` so the inner loop
//!   becomes a pure dot-product FMA micro-kernel over cached norms
//!   (the `*_dot` entry points). `dot` keeps the strict-`<`
//!   first-lowest-index argmin and clamps at 0, but intentionally
//!   relaxes cross-tier bit-identity: FMA fuses the multiply-add
//!   rounding, so `dot` distances may differ from `exact` (and between
//!   tiers) in the last ulps. Callers own the norm caches: per-row
//!   `‖x‖²` computed once per dataset/chunk ([`row_norms`]),
//!   per-centroid `‖μ‖²` recomputed once per iteration.
//!
//! See `rust/src/linalg/README.md` for the dispatch/tiling design and
//! how to force a tier for debugging (`PARAKM_KERNEL`, `--kernel`).

use std::sync::OnceLock;

use crate::error::{Error, Result};

/// Rows per tile; 64 × 4 bytes per dimension keeps the transposed tile
/// in L1 for any realistic `d`, and is a multiple of both SIMD widths.
pub const POINTS_BLOCK: usize = 64;

/// Centroids per inner sweep (cache tile over `k`).
pub const CENTROID_BLOCK: usize = 32;

/// An implementation tier the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable blocked scalar loop (reference semantics).
    Scalar,
    /// 8-lane f32 AVX2 (x86_64).
    Avx2,
    /// 4-lane f32 NEON (aarch64).
    Neon,
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Neon => "neon",
        })
    }
}

/// A tier *request* (configuration surface): auto-detect or force one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// Pick the best tier the host supports (the default).
    #[default]
    Auto,
    Scalar,
    Avx2,
    Neon,
}

impl std::str::FromStr for KernelChoice {
    type Err = Error;

    fn from_str(s: &str) -> Result<KernelChoice> {
        Ok(match s {
            "auto" => KernelChoice::Auto,
            "scalar" => KernelChoice::Scalar,
            "avx2" => KernelChoice::Avx2,
            "neon" => KernelChoice::Neon,
            other => {
                return Err(Error::Config(format!(
                    "unknown kernel tier `{other}` (auto|scalar|avx2|neon)"
                )))
            }
        })
    }
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelChoice::Auto => f.write_str("auto"),
            KernelChoice::Scalar => f.write_str("scalar"),
            KernelChoice::Avx2 => f.write_str("avx2"),
            KernelChoice::Neon => f.write_str("neon"),
        }
    }
}

/// How assignment kernels compute squared distances (DESIGN.md §11).
///
/// `Exact` is the subtract-square reference — the formulation every
/// documented bit-identity contract (oocore ≡ threads ≡ dist, scalar ≡
/// SIMD, pruned ≡ serial) is defined against, and therefore the
/// default. `Dot` computes `‖x‖² − 2·x·μ + ‖μ‖²` through the FMA
/// micro-kernels over caller-cached norms: same strict-`<`
/// first-lowest-index argmin, distances clamped at 0, but values may
/// differ from `Exact` in the last ulps (and between tiers — FMA
/// rounds the fused multiply-add once).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DistancePolicy {
    /// Subtract-square `(x − μ)²` loop (the bit-identity reference).
    #[default]
    Exact,
    /// Norm-trick `‖x‖² − 2·x·μ + ‖μ‖²` FMA dot-product path.
    Dot,
}

impl std::str::FromStr for DistancePolicy {
    type Err = Error;

    fn from_str(s: &str) -> Result<DistancePolicy> {
        Ok(match s {
            "exact" => DistancePolicy::Exact,
            "dot" => DistancePolicy::Dot,
            other => {
                return Err(Error::Config(format!(
                    "unknown distance policy `{other}` (exact|dot)"
                )))
            }
        })
    }
}

impl std::fmt::Display for DistancePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DistancePolicy::Exact => "exact",
            DistancePolicy::Dot => "dot",
        })
    }
}

impl DistancePolicy {
    /// Resolve the `PARAKM_DISTANCE` env var (the CLI `--distance` flag
    /// wins over it; absent both, `Exact`). A set-but-unparsable value
    /// is a typed config error, never silently substituted.
    pub fn from_env() -> Result<DistancePolicy> {
        match std::env::var("PARAKM_DISTANCE") {
            Ok(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("PARAKM_DISTANCE: {e}"))),
            Err(_) => Ok(DistancePolicy::Exact),
        }
    }
}

/// Per-row squared norms `out[i] = ‖rowᵢ‖²` — the `‖x‖²` cache the
/// `Dot` policy consumes. Plain ascending-`j` f32 mul+add (computed
/// once per dataset/chunk, never the hot loop). Also used for centroid
/// norms: centroids are `k` rows of width `dim`.
pub fn row_norms(rows: &[f32], dim: usize, out: &mut [f32]) {
    assert!(dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(out.len() * dim, rows.len());
    for (o, p) in out.iter_mut().zip(rows.chunks_exact(dim)) {
        let mut acc = 0.0f32;
        for &v in p {
            acc += v * v;
        }
        *o = acc;
    }
}

/// [`row_norms`] into a fresh vector (per-iteration centroid norms).
pub fn row_norms_vec(rows: &[f32], dim: usize) -> Vec<f32> {
    assert!(dim >= 1);
    let mut out = vec![0.0f32; rows.len() / dim];
    row_norms(rows, dim, &mut out);
    out
}

/// Best tier the running host supports.
pub fn detect() -> KernelTier {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return KernelTier::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelTier::Neon;
        }
    }
    KernelTier::Scalar
}

/// Resolve a request against the host, erroring on impossible forces.
pub fn resolve(choice: KernelChoice) -> Result<KernelTier> {
    match choice {
        KernelChoice::Auto => Ok(detect()),
        KernelChoice::Scalar => Ok(KernelTier::Scalar),
        KernelChoice::Avx2 => {
            if detect() == KernelTier::Avx2 {
                Ok(KernelTier::Avx2)
            } else {
                Err(Error::Config("kernel tier avx2 not available on this host".into()))
            }
        }
        KernelChoice::Neon => {
            if detect() == KernelTier::Neon {
                Ok(KernelTier::Neon)
            } else {
                Err(Error::Config("kernel tier neon not available on this host".into()))
            }
        }
    }
}

/// Soundness gate for the safe pub entry points: the SIMD paths use
/// `target_feature` code and raw-pointer loads, so an unsupported tier
/// (freely constructible — `KernelTier` is a pub enum) must never
/// reach them from safe code.
fn assert_tier_supported(tier: KernelTier) {
    assert!(
        tier == KernelTier::Scalar || tier == detect(),
        "kernel tier {tier} not supported on this host (detected: {})",
        detect()
    );
}

static ACTIVE: OnceLock<KernelTier> = OnceLock::new();

/// The process-global tier used by every engine's hot path. Resolved
/// once: an explicit [`set_active`] call wins, else the
/// `PARAKM_KERNEL` env var (`auto|scalar|avx2|neon`), else detection.
///
/// Panics at first use when `PARAKM_KERNEL` is set to a value that
/// cannot be parsed or that the host cannot execute — an explicitly
/// forced tier must never be silently substituted.
pub fn active_tier() -> KernelTier {
    *ACTIVE.get_or_init(|| match std::env::var("PARAKM_KERNEL") {
        Ok(v) => {
            let choice = v
                .parse::<KernelChoice>()
                .unwrap_or_else(|e| panic!("PARAKM_KERNEL: {e}"));
            resolve(choice).unwrap_or_else(|e| panic!("PARAKM_KERNEL: {e}"))
        }
        Err(_) => detect(),
    })
}

/// Fix the process-global tier (CLI `--kernel`). Must be called before
/// the first kernel use; errors if a different tier is already active
/// or the host cannot satisfy the request.
pub fn set_active(choice: KernelChoice) -> Result<KernelTier> {
    let want = resolve(choice)?;
    let got = *ACTIVE.get_or_init(|| want);
    if got != want {
        return Err(Error::Config(format!(
            "kernel tier already fixed to {got}; cannot switch to {want}"
        )));
    }
    Ok(got)
}

/// Transposed point tile: `xt[j * POINTS_BLOCK + i]` holds coordinate
/// `j` of tile row `i`. Lanes past the tile's live row count hold stale
/// (finite) values and are never read back.
struct Tile {
    xt: Vec<f32>,
    dim: usize,
}

impl Tile {
    fn new(dim: usize) -> Tile {
        Tile { xt: vec![0.0f32; dim * POINTS_BLOCK], dim }
    }

    /// Load `bn` rows starting at `rows[lo * dim]`.
    fn load(&mut self, rows: &[f32], lo: usize, bn: usize) {
        for i in 0..bn {
            let p = &rows[(lo + i) * self.dim..(lo + i + 1) * self.dim];
            for (j, &v) in p.iter().enumerate() {
                self.xt[j * POINTS_BLOCK + i] = v;
            }
        }
    }
}

/// Fused assign + accumulate over `rows` (row-major, `dim` wide):
/// nearest-centroid assignment into `assign_out`, per-cluster f64 sums
/// and counts, and the f64 SSE — one pass, tiled, on the given tier.
///
/// The caller owns zeroing/resetting the accumulators.
#[allow(clippy::too_many_arguments)]
pub fn assign_accumulate(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    sums: &mut [f64],
    counts: &mut [u64],
    sse: &mut f64,
    tier: KernelTier,
) {
    // real asserts, not debug: the SIMD tiers read through raw
    // pointers, so shape violations from safe callers must panic
    // instead of reading out of bounds (checks are outside the loops)
    assert_tier_supported(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    assert_eq!(assign_out.len() * dim, rows.len());
    assert_eq!(sums.len(), k * dim);
    assert_eq!(counts.len(), k);
    let n = rows.len() / dim;
    let mut tile = Tile::new(dim);
    let mut best_d = [f32::INFINITY; POINTS_BLOCK];
    let mut best_i = [0i32; POINTS_BLOCK];

    let mut lo = 0usize;
    while lo < n {
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        best_d.fill(f32::INFINITY);
        best_i.fill(0);

        let mut c0 = 0usize;
        while c0 < k {
            let c1 = (c0 + CENTROID_BLOCK).min(k);
            match tier {
                KernelTier::Scalar => {
                    argmin_block_scalar(&tile.xt, dim, centroids, c0, c1, &mut best_d, &mut best_i)
                }
                #[cfg(target_arch = "x86_64")]
                // safety: tier == Avx2 only when resolve()/detect()
                // confirmed AVX2 support on this host
                KernelTier::Avx2 => unsafe {
                    x86::argmin_block(&tile.xt, dim, centroids, c0, c1, &mut best_d, &mut best_i)
                },
                #[cfg(target_arch = "aarch64")]
                KernelTier::Neon => unsafe {
                    arm::argmin_block(&tile.xt, dim, centroids, c0, c1, &mut best_d, &mut best_i)
                },
                #[allow(unreachable_patterns)]
                _ => {
                    argmin_block_scalar(&tile.xt, dim, centroids, c0, c1, &mut best_d, &mut best_i)
                }
            }
            c0 = c1;
        }

        // scatter + accumulate in point order (identical across tiers)
        for i in 0..bn {
            let c = best_i[i] as usize;
            assign_out[lo + i] = best_i[i];
            counts[c] += 1;
            *sse += best_d[i] as f64;
            let p = &rows[(lo + i) * dim..(lo + i + 1) * dim];
            let s = &mut sums[c * dim..(c + 1) * dim];
            for j in 0..dim {
                s[j] += p[j] as f64;
            }
        }
        lo += bn;
    }
}

/// Nearest-centroid assignment plus the squared distances to the two
/// nearest centroids (Hamerly-style bound seeding), tiled + SIMD.
#[allow(clippy::too_many_arguments)]
pub fn assign_two_nearest(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign_out: &mut [i32],
    d1_out: &mut [f32],
    d2_out: &mut [f32],
    tier: KernelTier,
) {
    assert_tier_supported(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    let n = rows.len() / dim;
    assert_eq!(assign_out.len(), n);
    assert_eq!(d1_out.len(), n);
    assert_eq!(d2_out.len(), n);
    let mut tile = Tile::new(dim);
    let mut d1 = [f32::INFINITY; POINTS_BLOCK];
    let mut d2 = [f32::INFINITY; POINTS_BLOCK];
    let mut bi = [0i32; POINTS_BLOCK];

    let mut lo = 0usize;
    while lo < n {
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        d1.fill(f32::INFINITY);
        d2.fill(f32::INFINITY);
        bi.fill(0);
        match tier {
            KernelTier::Scalar => {
                two_nearest_block_scalar(&tile.xt, dim, centroids, k, &mut d1, &mut d2, &mut bi)
            }
            #[cfg(target_arch = "x86_64")]
            KernelTier::Avx2 => unsafe {
                x86::two_nearest_block(&tile.xt, dim, centroids, k, &mut d1, &mut d2, &mut bi)
            },
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => unsafe {
                arm::two_nearest_block(&tile.xt, dim, centroids, k, &mut d1, &mut d2, &mut bi)
            },
            #[allow(unreachable_patterns)]
            _ => two_nearest_block_scalar(&tile.xt, dim, centroids, k, &mut d1, &mut d2, &mut bi),
        }
        assign_out[lo..lo + bn].copy_from_slice(&bi[..bn]);
        d1_out[lo..lo + bn].copy_from_slice(&d1[..bn]);
        d2_out[lo..lo + bn].copy_from_slice(&d2[..bn]);
        lo += bn;
    }
}

/// Dense squared-distance matrix `out[i * k + c] = ‖rowᵢ − μ_c‖²`
/// (Elkan-style bound seeding), tiled + SIMD.
pub fn sqdist_matrix(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    out: &mut [f32],
    tier: KernelTier,
) {
    assert_tier_supported(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    let n = rows.len() / dim;
    assert_eq!(out.len(), n * k);
    let mut tile = Tile::new(dim);
    let mut dist = [0.0f32; POINTS_BLOCK];

    let mut lo = 0usize;
    while lo < n {
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        for c in 0..k {
            match tier {
                KernelTier::Scalar => dist_block_scalar(&tile.xt, dim, centroids, c, &mut dist),
                #[cfg(target_arch = "x86_64")]
                KernelTier::Avx2 => unsafe {
                    x86::dist_block(&tile.xt, dim, centroids, c, &mut dist)
                },
                #[cfg(target_arch = "aarch64")]
                KernelTier::Neon => unsafe {
                    arm::dist_block(&tile.xt, dim, centroids, c, &mut dist)
                },
                #[allow(unreachable_patterns)]
                _ => dist_block_scalar(&tile.xt, dim, centroids, c, &mut dist),
            }
            for i in 0..bn {
                out[(lo + i) * k + c] = dist[i];
            }
        }
        lo += bn;
    }
}

/// Batched bound-refresh kernel: squared distances for a *masked*
/// subset of (point-block, centroid) pairs — the hot path of the
/// Elkan/Hamerly reassignment phase, where pruning leaves an irregular
/// candidate set (DESIGN.md §9).
///
/// `mask` holds one flag per `(block, centroid)`: `mask[b * k + c]`
/// with `b = row / POINTS_BLOCK` (so `ceil(n / POINTS_BLOCK) * k`
/// entries). When set, `out[i * k + c]` is written with
/// `‖rowᵢ − μ_c‖²` for every row `i` of block `b` — the same
/// lane-per-point tile and f32 op sequence as [`sqdist_matrix`], so a
/// masked entry is bit-identical to the dense matrix entry on every
/// tier (and to [`crate::linalg::sqdist`]). Unmasked entries are left
/// **untouched** — callers own staleness tracking (the mask itself is
/// the validity map). Blocks with no masked centroid are never loaded.
///
/// Returns the number of (point, centroid) pairs evaluated:
/// `Σ_masked(b,c) live_rows(b)` — the "distances computed" counter the
/// pruned engines report ([`crate::kmeans::PruneStats`]).
pub fn sqdist_pruned(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    mask: &[bool],
    out: &mut [f32],
    tier: KernelTier,
) -> u64 {
    assert_tier_supported(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    let n = rows.len() / dim;
    let nblocks = n.div_ceil(POINTS_BLOCK);
    assert_eq!(mask.len(), nblocks * k);
    assert_eq!(out.len(), n * k);
    let mut tile = Tile::new(dim);
    let mut dist = [0.0f32; POINTS_BLOCK];
    let mut computed = 0u64;

    for b in 0..nblocks {
        let bmask = &mask[b * k..(b + 1) * k];
        if !bmask.iter().any(|&m| m) {
            continue;
        }
        let lo = b * POINTS_BLOCK;
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        for c in 0..k {
            if !bmask[c] {
                continue;
            }
            match tier {
                KernelTier::Scalar => dist_block_scalar(&tile.xt, dim, centroids, c, &mut dist),
                #[cfg(target_arch = "x86_64")]
                // safety: tier == Avx2 only when resolve()/detect()
                // confirmed AVX2 support on this host
                KernelTier::Avx2 => unsafe {
                    x86::dist_block(&tile.xt, dim, centroids, c, &mut dist)
                },
                #[cfg(target_arch = "aarch64")]
                KernelTier::Neon => unsafe {
                    arm::dist_block(&tile.xt, dim, centroids, c, &mut dist)
                },
                #[allow(unreachable_patterns)]
                _ => dist_block_scalar(&tile.xt, dim, centroids, c, &mut dist),
            }
            for i in 0..bn {
                out[(lo + i) * k + c] = dist[i];
            }
            computed += bn as u64;
        }
    }
    computed
}

// ---- dot-policy entry points (norm-trick FMA micro-kernels) ------------

/// Downgrade a `Dot`-policy AVX2 request to scalar when the host lacks
/// FMA (AVX2 without FMA is essentially hypothetical, but executing a
/// `target_feature(fma)` function there would be UB, so the gate is
/// mandatory). The `Exact` kernels never fuse, so they keep the plain
/// tier.
fn dot_tier(tier: KernelTier) -> KernelTier {
    #[cfg(target_arch = "x86_64")]
    {
        if tier == KernelTier::Avx2 && !std::arch::is_x86_feature_detected!("fma") {
            return KernelTier::Scalar;
        }
    }
    tier
}

/// [`assign_accumulate`] under the `Dot` policy: distances come from
/// the register-blocked FMA micro-kernel `‖x‖² − 2·x·μ + ‖μ‖²` over
/// the caller-cached norms (`x_norms[i] = ‖rowᵢ‖²`, `c_norms[c] =
/// ‖μ_c‖²`), clamped at 0. Argmin semantics are unchanged (strict `<`,
/// ascending centroid index — first-lowest-index ties), and the f64
/// accumulation folds in the same ascending row order, so the chunked-
/// accumulation contract holds within the policy. Values may differ
/// from [`assign_accumulate`] in the last ulps (module docs).
#[allow(clippy::too_many_arguments)]
pub fn assign_accumulate_dot(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    x_norms: &[f32],
    c_norms: &[f32],
    assign_out: &mut [i32],
    sums: &mut [f64],
    counts: &mut [u64],
    sse: &mut f64,
    tier: KernelTier,
) {
    assert_tier_supported(tier);
    let tier = dot_tier(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    let n = rows.len() / dim;
    assert_eq!(x_norms.len(), n);
    assert_eq!(c_norms.len(), k);
    assert_eq!(assign_out.len(), n);
    assert_eq!(sums.len(), k * dim);
    assert_eq!(counts.len(), k);
    let mut tile = Tile::new(dim);
    let mut xn = [0.0f32; POINTS_BLOCK];
    let mut best_d = [f32::INFINITY; POINTS_BLOCK];
    let mut best_i = [0i32; POINTS_BLOCK];

    let mut lo = 0usize;
    while lo < n {
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        xn[..bn].copy_from_slice(&x_norms[lo..lo + bn]);
        best_d.fill(f32::INFINITY);
        best_i.fill(0);

        let mut c0 = 0usize;
        while c0 < k {
            let c1 = (c0 + CENTROID_BLOCK).min(k);
            match tier {
                KernelTier::Scalar => dot_argmin_block_scalar(
                    &tile.xt, dim, centroids, c_norms, c0, c1, &xn, &mut best_d, &mut best_i,
                ),
                #[cfg(target_arch = "x86_64")]
                // safety: dot_tier() confirmed avx2 + fma on this host
                KernelTier::Avx2 => unsafe {
                    x86dot::argmin_block(
                        &tile.xt, dim, centroids, c_norms, c0, c1, &xn, &mut best_d, &mut best_i,
                    )
                },
                #[cfg(target_arch = "aarch64")]
                KernelTier::Neon => unsafe {
                    armdot::argmin_block(
                        &tile.xt, dim, centroids, c_norms, c0, c1, &xn, &mut best_d, &mut best_i,
                    )
                },
                #[allow(unreachable_patterns)]
                _ => dot_argmin_block_scalar(
                    &tile.xt, dim, centroids, c_norms, c0, c1, &xn, &mut best_d, &mut best_i,
                ),
            }
            c0 = c1;
        }

        // scatter + accumulate in point order, exactly like the exact
        // path — partition statistics depend only on the assignments
        for i in 0..bn {
            let c = best_i[i] as usize;
            assign_out[lo + i] = best_i[i];
            counts[c] += 1;
            *sse += best_d[i] as f64;
            let p = &rows[(lo + i) * dim..(lo + i + 1) * dim];
            let s = &mut sums[c * dim..(c + 1) * dim];
            for j in 0..dim {
                s[j] += p[j] as f64;
            }
        }
        lo += bn;
    }
}

/// [`assign_two_nearest`] under the `Dot` policy (same norm caches and
/// clamping as [`assign_accumulate_dot`]; same comparison sequence as
/// the exact two-nearest scan).
#[allow(clippy::too_many_arguments)]
pub fn assign_two_nearest_dot(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    x_norms: &[f32],
    c_norms: &[f32],
    assign_out: &mut [i32],
    d1_out: &mut [f32],
    d2_out: &mut [f32],
    tier: KernelTier,
) {
    assert_tier_supported(tier);
    let tier = dot_tier(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    let n = rows.len() / dim;
    assert_eq!(x_norms.len(), n);
    assert_eq!(c_norms.len(), k);
    assert_eq!(assign_out.len(), n);
    assert_eq!(d1_out.len(), n);
    assert_eq!(d2_out.len(), n);
    let mut tile = Tile::new(dim);
    let mut xn = [0.0f32; POINTS_BLOCK];
    let mut d1 = [f32::INFINITY; POINTS_BLOCK];
    let mut d2 = [f32::INFINITY; POINTS_BLOCK];
    let mut bi = [0i32; POINTS_BLOCK];

    let mut lo = 0usize;
    while lo < n {
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        xn[..bn].copy_from_slice(&x_norms[lo..lo + bn]);
        d1.fill(f32::INFINITY);
        d2.fill(f32::INFINITY);
        bi.fill(0);
        match tier {
            KernelTier::Scalar => dot_two_nearest_block_scalar(
                &tile.xt, dim, centroids, c_norms, k, &xn, &mut d1, &mut d2, &mut bi,
            ),
            #[cfg(target_arch = "x86_64")]
            // safety: dot_tier() confirmed avx2 + fma on this host
            KernelTier::Avx2 => unsafe {
                x86dot::two_nearest_block(
                    &tile.xt, dim, centroids, c_norms, k, &xn, &mut d1, &mut d2, &mut bi,
                )
            },
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => unsafe {
                armdot::two_nearest_block(
                    &tile.xt, dim, centroids, c_norms, k, &xn, &mut d1, &mut d2, &mut bi,
                )
            },
            #[allow(unreachable_patterns)]
            _ => dot_two_nearest_block_scalar(
                &tile.xt, dim, centroids, c_norms, k, &xn, &mut d1, &mut d2, &mut bi,
            ),
        }
        assign_out[lo..lo + bn].copy_from_slice(&bi[..bn]);
        d1_out[lo..lo + bn].copy_from_slice(&d1[..bn]);
        d2_out[lo..lo + bn].copy_from_slice(&d2[..bn]);
        lo += bn;
    }
}

/// [`sqdist_matrix`] under the `Dot` policy.
#[allow(clippy::too_many_arguments)]
pub fn sqdist_matrix_dot(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    x_norms: &[f32],
    c_norms: &[f32],
    out: &mut [f32],
    tier: KernelTier,
) {
    assert_tier_supported(tier);
    let tier = dot_tier(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    let n = rows.len() / dim;
    assert_eq!(x_norms.len(), n);
    assert_eq!(c_norms.len(), k);
    assert_eq!(out.len(), n * k);
    let mut tile = Tile::new(dim);
    let mut xn = [0.0f32; POINTS_BLOCK];
    let mut dist = [0.0f32; POINTS_BLOCK];

    let mut lo = 0usize;
    while lo < n {
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        xn[..bn].copy_from_slice(&x_norms[lo..lo + bn]);
        for c in 0..k {
            dot_dist_dispatch(&tile.xt, dim, centroids, c, c_norms[c], &xn, &mut dist, tier);
            for i in 0..bn {
                out[(lo + i) * k + c] = dist[i];
            }
        }
        lo += bn;
    }
}

/// [`sqdist_pruned`] under the `Dot` policy: same mask layout and
/// untouched-entry contract, same evaluated-pair count; a masked entry
/// is bit-identical to the [`sqdist_matrix_dot`] entry on the same
/// tier (not to the `exact` matrix — module docs).
#[allow(clippy::too_many_arguments)]
pub fn sqdist_pruned_dot(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    k: usize,
    x_norms: &[f32],
    c_norms: &[f32],
    mask: &[bool],
    out: &mut [f32],
    tier: KernelTier,
) -> u64 {
    assert_tier_supported(tier);
    let tier = dot_tier(tier);
    assert!(k >= 1 && dim >= 1);
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(centroids.len(), k * dim);
    let n = rows.len() / dim;
    assert_eq!(x_norms.len(), n);
    assert_eq!(c_norms.len(), k);
    let nblocks = n.div_ceil(POINTS_BLOCK);
    assert_eq!(mask.len(), nblocks * k);
    assert_eq!(out.len(), n * k);
    let mut tile = Tile::new(dim);
    let mut xn = [0.0f32; POINTS_BLOCK];
    let mut dist = [0.0f32; POINTS_BLOCK];
    let mut computed = 0u64;

    for b in 0..nblocks {
        let bmask = &mask[b * k..(b + 1) * k];
        if !bmask.iter().any(|&m| m) {
            continue;
        }
        let lo = b * POINTS_BLOCK;
        let bn = (n - lo).min(POINTS_BLOCK);
        tile.load(rows, lo, bn);
        xn[..bn].copy_from_slice(&x_norms[lo..lo + bn]);
        for c in 0..k {
            if !bmask[c] {
                continue;
            }
            dot_dist_dispatch(&tile.xt, dim, centroids, c, c_norms[c], &xn, &mut dist, tier);
            for i in 0..bn {
                out[(lo + i) * k + c] = dist[i];
            }
            computed += bn as u64;
        }
    }
    computed
}

/// Tier dispatch for one dot-policy centroid column (shared by the
/// matrix and pruned kernels). `tier` has already passed
/// [`assert_tier_supported`] and [`dot_tier`].
#[allow(clippy::too_many_arguments)]
fn dot_dist_dispatch(
    xt: &[f32],
    dim: usize,
    mu: &[f32],
    c: usize,
    cn: f32,
    xn: &[f32; POINTS_BLOCK],
    dist: &mut [f32; POINTS_BLOCK],
    tier: KernelTier,
) {
    match tier {
        KernelTier::Scalar => dot_dist_block_scalar(xt, dim, mu, c, cn, xn, dist),
        #[cfg(target_arch = "x86_64")]
        // safety: dot_tier() confirmed avx2 + fma on this host
        KernelTier::Avx2 => unsafe { x86dot::dist_block(xt, dim, mu, c, cn, xn, dist) },
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { armdot::dist_block(xt, dim, mu, c, cn, xn, dist) },
        #[allow(unreachable_patterns)]
        _ => dot_dist_block_scalar(xt, dim, mu, c, cn, xn, dist),
    }
}

// ---- scalar tier (reference semantics for every other tier) ------------

fn argmin_block_scalar(
    xt: &[f32],
    dim: usize,
    mu: &[f32],
    c0: usize,
    c1: usize,
    best_d: &mut [f32; POINTS_BLOCK],
    best_i: &mut [i32; POINTS_BLOCK],
) {
    for c in c0..c1 {
        let muc = &mu[c * dim..(c + 1) * dim];
        for i in 0..POINTS_BLOCK {
            let mut acc = 0.0f32;
            for (j, &m) in muc.iter().enumerate() {
                let diff = xt[j * POINTS_BLOCK + i] - m;
                acc += diff * diff;
            }
            if acc < best_d[i] {
                best_d[i] = acc;
                best_i[i] = c as i32;
            }
        }
    }
}

fn two_nearest_block_scalar(
    xt: &[f32],
    dim: usize,
    mu: &[f32],
    k: usize,
    d1: &mut [f32; POINTS_BLOCK],
    d2: &mut [f32; POINTS_BLOCK],
    bi: &mut [i32; POINTS_BLOCK],
) {
    for c in 0..k {
        let muc = &mu[c * dim..(c + 1) * dim];
        for i in 0..POINTS_BLOCK {
            let mut acc = 0.0f32;
            for (j, &m) in muc.iter().enumerate() {
                let diff = xt[j * POINTS_BLOCK + i] - m;
                acc += diff * diff;
            }
            if acc < d1[i] {
                d2[i] = d1[i];
                d1[i] = acc;
                bi[i] = c as i32;
            } else if acc < d2[i] {
                d2[i] = acc;
            }
        }
    }
}

fn dist_block_scalar(
    xt: &[f32],
    dim: usize,
    mu: &[f32],
    c: usize,
    dist: &mut [f32; POINTS_BLOCK],
) {
    let muc = &mu[c * dim..(c + 1) * dim];
    for i in 0..POINTS_BLOCK {
        let mut acc = 0.0f32;
        for (j, &m) in muc.iter().enumerate() {
            let diff = xt[j * POINTS_BLOCK + i] - m;
            acc += diff * diff;
        }
        dist[i] = acc;
    }
}

// ---- scalar dot-policy micro-kernels -----------------------------------
//
// Distance evaluation order mirrors the SIMD tiers' grouping —
// `(‖x‖² + ‖μ‖²) − 2·(x·μ)` clamped at 0 — but the dot product itself
// accumulates mul+add while the SIMD tiers fuse (FMA), so cross-tier
// bit-identity is intentionally NOT promised under `Dot` (module docs).

#[allow(clippy::too_many_arguments)]
fn dot_argmin_block_scalar(
    xt: &[f32],
    dim: usize,
    mu: &[f32],
    cn: &[f32],
    c0: usize,
    c1: usize,
    xn: &[f32; POINTS_BLOCK],
    best_d: &mut [f32; POINTS_BLOCK],
    best_i: &mut [i32; POINTS_BLOCK],
) {
    for c in c0..c1 {
        let muc = &mu[c * dim..(c + 1) * dim];
        let base_c = cn[c];
        for i in 0..POINTS_BLOCK {
            let mut acc = 0.0f32;
            for (j, &m) in muc.iter().enumerate() {
                acc += xt[j * POINTS_BLOCK + i] * m;
            }
            let dist = ((xn[i] + base_c) - 2.0 * acc).max(0.0);
            if dist < best_d[i] {
                best_d[i] = dist;
                best_i[i] = c as i32;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dot_two_nearest_block_scalar(
    xt: &[f32],
    dim: usize,
    mu: &[f32],
    cn: &[f32],
    k: usize,
    xn: &[f32; POINTS_BLOCK],
    d1: &mut [f32; POINTS_BLOCK],
    d2: &mut [f32; POINTS_BLOCK],
    bi: &mut [i32; POINTS_BLOCK],
) {
    for c in 0..k {
        let muc = &mu[c * dim..(c + 1) * dim];
        let base_c = cn[c];
        for i in 0..POINTS_BLOCK {
            let mut acc = 0.0f32;
            for (j, &m) in muc.iter().enumerate() {
                acc += xt[j * POINTS_BLOCK + i] * m;
            }
            let dist = ((xn[i] + base_c) - 2.0 * acc).max(0.0);
            if dist < d1[i] {
                d2[i] = d1[i];
                d1[i] = dist;
                bi[i] = c as i32;
            } else if dist < d2[i] {
                d2[i] = dist;
            }
        }
    }
}

fn dot_dist_block_scalar(
    xt: &[f32],
    dim: usize,
    mu: &[f32],
    c: usize,
    cn: f32,
    xn: &[f32; POINTS_BLOCK],
    dist: &mut [f32; POINTS_BLOCK],
) {
    let muc = &mu[c * dim..(c + 1) * dim];
    for i in 0..POINTS_BLOCK {
        let mut acc = 0.0f32;
        for (j, &m) in muc.iter().enumerate() {
            acc += xt[j * POINTS_BLOCK + i] * m;
        }
        dist[i] = ((xn[i] + cn) - 2.0 * acc).max(0.0);
    }
}

// ---- AVX2 tier (x86_64) ------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::POINTS_BLOCK;
    use std::arch::x86_64::*;

    const L: usize = 8;

    /// Distance of one 8-point sub-column to centroid `muc`, mul+add
    /// in ascending-`j` order — the scalar tier's exact f32 sequence.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn sqdist8(xt: &[f32], sub: usize, muc: *const f32, dim: usize) -> __m256 {
        let mut acc = _mm256_setzero_ps();
        for j in 0..dim {
            let xv = _mm256_loadu_ps(xt.as_ptr().add(j * POINTS_BLOCK + sub * L));
            let mv = _mm256_set1_ps(*muc.add(j));
            let diff = _mm256_sub_ps(xv, mv);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(diff, diff));
        }
        acc
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn argmin_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        c0: usize,
        c1: usize,
        best_d: &mut [f32; POINTS_BLOCK],
        best_i: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let mut bd = _mm256_loadu_ps(best_d.as_ptr().add(sub * L));
            let mut bi = _mm256_loadu_si256(best_i.as_ptr().add(sub * L) as *const __m256i);
            for c in c0..c1 {
                let acc = sqdist8(xt, sub, mu.as_ptr().add(c * dim), dim);
                let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(acc, bd);
                bd = _mm256_blendv_ps(bd, acc, lt);
                let ci = _mm256_set1_epi32(c as i32);
                bi = _mm256_blendv_epi8(bi, ci, _mm256_castps_si256(lt));
            }
            _mm256_storeu_ps(best_d.as_mut_ptr().add(sub * L), bd);
            _mm256_storeu_si256(best_i.as_mut_ptr().add(sub * L) as *mut __m256i, bi);
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn two_nearest_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        k: usize,
        d1: &mut [f32; POINTS_BLOCK],
        d2: &mut [f32; POINTS_BLOCK],
        bi: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let mut v1 = _mm256_loadu_ps(d1.as_ptr().add(sub * L));
            let mut v2 = _mm256_loadu_ps(d2.as_ptr().add(sub * L));
            let mut vi = _mm256_loadu_si256(bi.as_ptr().add(sub * L) as *const __m256i);
            for c in 0..k {
                let acc = sqdist8(xt, sub, mu.as_ptr().add(c * dim), dim);
                let lt1 = _mm256_cmp_ps::<_CMP_LT_OQ>(acc, v1);
                let lt2 = _mm256_cmp_ps::<_CMP_LT_OQ>(acc, v2);
                // d2' = acc<d1 ? d1 : (acc<d2 ? acc : d2)
                v2 = _mm256_blendv_ps(_mm256_blendv_ps(v2, acc, lt2), v1, lt1);
                v1 = _mm256_blendv_ps(v1, acc, lt1);
                let ci = _mm256_set1_epi32(c as i32);
                vi = _mm256_blendv_epi8(vi, ci, _mm256_castps_si256(lt1));
            }
            _mm256_storeu_ps(d1.as_mut_ptr().add(sub * L), v1);
            _mm256_storeu_ps(d2.as_mut_ptr().add(sub * L), v2);
            _mm256_storeu_si256(bi.as_mut_ptr().add(sub * L) as *mut __m256i, vi);
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dist_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        c: usize,
        dist: &mut [f32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let acc = sqdist8(xt, sub, mu.as_ptr().add(c * dim), dim);
            _mm256_storeu_ps(dist.as_mut_ptr().add(sub * L), acc);
        }
    }
}

// ---- AVX2+FMA dot-policy micro-kernels (x86_64) ------------------------

#[cfg(target_arch = "x86_64")]
mod x86dot {
    use super::POINTS_BLOCK;
    use std::arch::x86_64::*;

    const L: usize = 8;

    /// Dot product of one 8-point sub-column with centroid `muc`,
    /// FMA-accumulated in ascending-`j` order.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot8(xt: &[f32], sub: usize, muc: *const f32, dim: usize) -> __m256 {
        let mut acc = _mm256_setzero_ps();
        for j in 0..dim {
            let xv = _mm256_loadu_ps(xt.as_ptr().add(j * POINTS_BLOCK + sub * L));
            let mv = _mm256_set1_ps(*muc.add(j));
            acc = _mm256_fmadd_ps(xv, mv, acc);
        }
        acc
    }

    /// `max(0, (‖x‖² + ‖μ‖²) − 2·acc)` — one fused multiply-add, then
    /// the non-negativity clamp (Elkan/Hamerly take square roots).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dist_from(acc: __m256, xn: __m256, cn: f32) -> __m256 {
        let base = _mm256_add_ps(xn, _mm256_set1_ps(cn));
        let d = _mm256_fmadd_ps(_mm256_set1_ps(-2.0), acc, base);
        _mm256_max_ps(_mm256_setzero_ps(), d)
    }

    /// Register-blocked argmin sweep: two centroid accumulators live
    /// per FMA loop (hides the fmadd latency chain), argmin updates in
    /// ascending centroid order (first-lowest-index ties preserved).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn argmin_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        cn: &[f32],
        c0: usize,
        c1: usize,
        xnorm: &[f32; POINTS_BLOCK],
        best_d: &mut [f32; POINTS_BLOCK],
        best_i: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let xn = _mm256_loadu_ps(xnorm.as_ptr().add(sub * L));
            let mut bd = _mm256_loadu_ps(best_d.as_ptr().add(sub * L));
            let mut bi = _mm256_loadu_si256(best_i.as_ptr().add(sub * L) as *const __m256i);
            let mut c = c0;
            while c + 2 <= c1 {
                let mu0 = mu.as_ptr().add(c * dim);
                let mu1 = mu.as_ptr().add((c + 1) * dim);
                let mut a0 = _mm256_setzero_ps();
                let mut a1 = _mm256_setzero_ps();
                for j in 0..dim {
                    let xv = _mm256_loadu_ps(xt.as_ptr().add(j * POINTS_BLOCK + sub * L));
                    a0 = _mm256_fmadd_ps(xv, _mm256_set1_ps(*mu0.add(j)), a0);
                    a1 = _mm256_fmadd_ps(xv, _mm256_set1_ps(*mu1.add(j)), a1);
                }
                let d0 = dist_from(a0, xn, cn[c]);
                let d1 = dist_from(a1, xn, cn[c + 1]);
                let lt0 = _mm256_cmp_ps::<_CMP_LT_OQ>(d0, bd);
                bd = _mm256_blendv_ps(bd, d0, lt0);
                bi = _mm256_blendv_epi8(
                    bi,
                    _mm256_set1_epi32(c as i32),
                    _mm256_castps_si256(lt0),
                );
                let lt1 = _mm256_cmp_ps::<_CMP_LT_OQ>(d1, bd);
                bd = _mm256_blendv_ps(bd, d1, lt1);
                bi = _mm256_blendv_epi8(
                    bi,
                    _mm256_set1_epi32((c + 1) as i32),
                    _mm256_castps_si256(lt1),
                );
                c += 2;
            }
            if c < c1 {
                let acc = dot8(xt, sub, mu.as_ptr().add(c * dim), dim);
                let d = dist_from(acc, xn, cn[c]);
                let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(d, bd);
                bd = _mm256_blendv_ps(bd, d, lt);
                bi = _mm256_blendv_epi8(
                    bi,
                    _mm256_set1_epi32(c as i32),
                    _mm256_castps_si256(lt),
                );
            }
            _mm256_storeu_ps(best_d.as_mut_ptr().add(sub * L), bd);
            _mm256_storeu_si256(best_i.as_mut_ptr().add(sub * L) as *mut __m256i, bi);
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn two_nearest_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        cn: &[f32],
        k: usize,
        xnorm: &[f32; POINTS_BLOCK],
        d1: &mut [f32; POINTS_BLOCK],
        d2: &mut [f32; POINTS_BLOCK],
        bi: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let xn = _mm256_loadu_ps(xnorm.as_ptr().add(sub * L));
            let mut v1 = _mm256_loadu_ps(d1.as_ptr().add(sub * L));
            let mut v2 = _mm256_loadu_ps(d2.as_ptr().add(sub * L));
            let mut vi = _mm256_loadu_si256(bi.as_ptr().add(sub * L) as *const __m256i);
            for c in 0..k {
                let acc = dot8(xt, sub, mu.as_ptr().add(c * dim), dim);
                let d = dist_from(acc, xn, cn[c]);
                let lt1 = _mm256_cmp_ps::<_CMP_LT_OQ>(d, v1);
                let lt2 = _mm256_cmp_ps::<_CMP_LT_OQ>(d, v2);
                // d2' = d<d1 ? d1 : (d<d2 ? d : d2)
                v2 = _mm256_blendv_ps(_mm256_blendv_ps(v2, d, lt2), v1, lt1);
                v1 = _mm256_blendv_ps(v1, d, lt1);
                vi = _mm256_blendv_epi8(
                    vi,
                    _mm256_set1_epi32(c as i32),
                    _mm256_castps_si256(lt1),
                );
            }
            _mm256_storeu_ps(d1.as_mut_ptr().add(sub * L), v1);
            _mm256_storeu_ps(d2.as_mut_ptr().add(sub * L), v2);
            _mm256_storeu_si256(bi.as_mut_ptr().add(sub * L) as *mut __m256i, vi);
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dist_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        c: usize,
        cn: f32,
        xnorm: &[f32; POINTS_BLOCK],
        dist: &mut [f32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let xn = _mm256_loadu_ps(xnorm.as_ptr().add(sub * L));
            let acc = dot8(xt, sub, mu.as_ptr().add(c * dim), dim);
            _mm256_storeu_ps(dist.as_mut_ptr().add(sub * L), dist_from(acc, xn, cn));
        }
    }
}

// ---- NEON tier (aarch64) -----------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::POINTS_BLOCK;
    use std::arch::aarch64::*;

    const L: usize = 4;

    /// Scalar-identical mul+add chain (vmlaq would fuse; see module docs).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn sqdist4(xt: &[f32], sub: usize, muc: *const f32, dim: usize) -> float32x4_t {
        let mut acc = vdupq_n_f32(0.0);
        for j in 0..dim {
            let xv = vld1q_f32(xt.as_ptr().add(j * POINTS_BLOCK + sub * L));
            let mv = vdupq_n_f32(*muc.add(j));
            let diff = vsubq_f32(xv, mv);
            acc = vaddq_f32(acc, vmulq_f32(diff, diff));
        }
        acc
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn argmin_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        c0: usize,
        c1: usize,
        best_d: &mut [f32; POINTS_BLOCK],
        best_i: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let mut bd = vld1q_f32(best_d.as_ptr().add(sub * L));
            let mut bi = vld1q_s32(best_i.as_ptr().add(sub * L));
            for c in c0..c1 {
                let acc = sqdist4(xt, sub, mu.as_ptr().add(c * dim), dim);
                let lt = vcltq_f32(acc, bd);
                bd = vbslq_f32(lt, acc, bd);
                bi = vbslq_s32(lt, vdupq_n_s32(c as i32), bi);
            }
            vst1q_f32(best_d.as_mut_ptr().add(sub * L), bd);
            vst1q_s32(best_i.as_mut_ptr().add(sub * L), bi);
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn two_nearest_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        k: usize,
        d1: &mut [f32; POINTS_BLOCK],
        d2: &mut [f32; POINTS_BLOCK],
        bi: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let mut v1 = vld1q_f32(d1.as_ptr().add(sub * L));
            let mut v2 = vld1q_f32(d2.as_ptr().add(sub * L));
            let mut vi = vld1q_s32(bi.as_ptr().add(sub * L));
            for c in 0..k {
                let acc = sqdist4(xt, sub, mu.as_ptr().add(c * dim), dim);
                let lt1 = vcltq_f32(acc, v1);
                let lt2 = vcltq_f32(acc, v2);
                v2 = vbslq_f32(lt1, v1, vbslq_f32(lt2, acc, v2));
                v1 = vbslq_f32(lt1, acc, v1);
                vi = vbslq_s32(lt1, vdupq_n_s32(c as i32), vi);
            }
            vst1q_f32(d1.as_mut_ptr().add(sub * L), v1);
            vst1q_f32(d2.as_mut_ptr().add(sub * L), v2);
            vst1q_s32(bi.as_mut_ptr().add(sub * L), vi);
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dist_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        c: usize,
        dist: &mut [f32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let acc = sqdist4(xt, sub, mu.as_ptr().add(c * dim), dim);
            vst1q_f32(dist.as_mut_ptr().add(sub * L), acc);
        }
    }
}

// ---- NEON dot-policy micro-kernels (aarch64) ---------------------------

#[cfg(target_arch = "aarch64")]
mod armdot {
    use super::POINTS_BLOCK;
    use std::arch::aarch64::*;

    const L: usize = 4;

    /// FMA dot product (`vfmaq` fuses — the intended `Dot` semantics).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn dot4(xt: &[f32], sub: usize, muc: *const f32, dim: usize) -> float32x4_t {
        let mut acc = vdupq_n_f32(0.0);
        for j in 0..dim {
            let xv = vld1q_f32(xt.as_ptr().add(j * POINTS_BLOCK + sub * L));
            let mv = vdupq_n_f32(*muc.add(j));
            acc = vfmaq_f32(acc, xv, mv);
        }
        acc
    }

    /// `max(0, (‖x‖² + ‖μ‖²) − 2·acc)` — fused, then clamped.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn dist_from(acc: float32x4_t, xn: float32x4_t, cn: f32) -> float32x4_t {
        let base = vaddq_f32(xn, vdupq_n_f32(cn));
        let d = vfmaq_f32(base, vdupq_n_f32(-2.0), acc);
        vmaxq_f32(vdupq_n_f32(0.0), d)
    }

    /// Register-blocked argmin sweep: two centroid accumulators per FMA
    /// loop, argmin updates in ascending centroid order.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn argmin_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        cn: &[f32],
        c0: usize,
        c1: usize,
        xnorm: &[f32; POINTS_BLOCK],
        best_d: &mut [f32; POINTS_BLOCK],
        best_i: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let xn = vld1q_f32(xnorm.as_ptr().add(sub * L));
            let mut bd = vld1q_f32(best_d.as_ptr().add(sub * L));
            let mut bi = vld1q_s32(best_i.as_ptr().add(sub * L));
            let mut c = c0;
            while c + 2 <= c1 {
                let mu0 = mu.as_ptr().add(c * dim);
                let mu1 = mu.as_ptr().add((c + 1) * dim);
                let mut a0 = vdupq_n_f32(0.0);
                let mut a1 = vdupq_n_f32(0.0);
                for j in 0..dim {
                    let xv = vld1q_f32(xt.as_ptr().add(j * POINTS_BLOCK + sub * L));
                    a0 = vfmaq_f32(a0, xv, vdupq_n_f32(*mu0.add(j)));
                    a1 = vfmaq_f32(a1, xv, vdupq_n_f32(*mu1.add(j)));
                }
                let d0 = dist_from(a0, xn, cn[c]);
                let d1 = dist_from(a1, xn, cn[c + 1]);
                let lt0 = vcltq_f32(d0, bd);
                bd = vbslq_f32(lt0, d0, bd);
                bi = vbslq_s32(lt0, vdupq_n_s32(c as i32), bi);
                let lt1 = vcltq_f32(d1, bd);
                bd = vbslq_f32(lt1, d1, bd);
                bi = vbslq_s32(lt1, vdupq_n_s32((c + 1) as i32), bi);
                c += 2;
            }
            if c < c1 {
                let acc = dot4(xt, sub, mu.as_ptr().add(c * dim), dim);
                let d = dist_from(acc, xn, cn[c]);
                let lt = vcltq_f32(d, bd);
                bd = vbslq_f32(lt, d, bd);
                bi = vbslq_s32(lt, vdupq_n_s32(c as i32), bi);
            }
            vst1q_f32(best_d.as_mut_ptr().add(sub * L), bd);
            vst1q_s32(best_i.as_mut_ptr().add(sub * L), bi);
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn two_nearest_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        cn: &[f32],
        k: usize,
        xnorm: &[f32; POINTS_BLOCK],
        d1: &mut [f32; POINTS_BLOCK],
        d2: &mut [f32; POINTS_BLOCK],
        bi: &mut [i32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let xn = vld1q_f32(xnorm.as_ptr().add(sub * L));
            let mut v1 = vld1q_f32(d1.as_ptr().add(sub * L));
            let mut v2 = vld1q_f32(d2.as_ptr().add(sub * L));
            let mut vi = vld1q_s32(bi.as_ptr().add(sub * L));
            for c in 0..k {
                let acc = dot4(xt, sub, mu.as_ptr().add(c * dim), dim);
                let d = dist_from(acc, xn, cn[c]);
                let lt1 = vcltq_f32(d, v1);
                let lt2 = vcltq_f32(d, v2);
                v2 = vbslq_f32(lt1, v1, vbslq_f32(lt2, d, v2));
                v1 = vbslq_f32(lt1, d, v1);
                vi = vbslq_s32(lt1, vdupq_n_s32(c as i32), vi);
            }
            vst1q_f32(d1.as_mut_ptr().add(sub * L), v1);
            vst1q_f32(d2.as_mut_ptr().add(sub * L), v2);
            vst1q_s32(bi.as_mut_ptr().add(sub * L), vi);
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn dist_block(
        xt: &[f32],
        dim: usize,
        mu: &[f32],
        c: usize,
        cn: f32,
        xnorm: &[f32; POINTS_BLOCK],
        dist: &mut [f32; POINTS_BLOCK],
    ) {
        for sub in 0..POINTS_BLOCK / L {
            let xn = vld1q_f32(xnorm.as_ptr().add(sub * L));
            let acc = dot4(xt, sub, mu.as_ptr().add(c * dim), dim);
            vst1q_f32(dist.as_mut_ptr().add(sub * L), dist_from(acc, xn, cn));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    /// Every tier available on this host, scalar first.
    fn tiers() -> Vec<KernelTier> {
        let mut t = vec![KernelTier::Scalar];
        if detect() != KernelTier::Scalar {
            t.push(detect());
        }
        t
    }

    fn run_aa(
        rows: &[f32],
        dim: usize,
        mu: &[f32],
        k: usize,
        tier: KernelTier,
    ) -> (Vec<i32>, Vec<f64>, Vec<u64>, f64) {
        let n = rows.len() / dim;
        let mut assign = vec![-1i32; n];
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0u64; k];
        let mut sse = 0.0f64;
        assign_accumulate(rows, dim, mu, k, &mut assign, &mut sums, &mut counts, &mut sse, tier);
        (assign, sums, counts, sse)
    }

    fn ulp_close(a: f64, b: f64) -> bool {
        if a == b {
            return true;
        }
        let (ba, bb) = (a.to_bits() as i64, b.to_bits() as i64);
        (ba - bb).abs() <= 1
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for c in [KernelChoice::Auto, KernelChoice::Scalar, KernelChoice::Avx2, KernelChoice::Neon]
        {
            assert_eq!(c.to_string().parse::<KernelChoice>().unwrap(), c);
        }
        assert!("sse9".parse::<KernelChoice>().is_err());
        assert_eq!(resolve(KernelChoice::Scalar).unwrap(), KernelTier::Scalar);
        assert_eq!(resolve(KernelChoice::Auto).unwrap(), detect());
    }

    #[test]
    fn forcing_an_unsupported_tier_errors() {
        // at most one SIMD tier exists per host; the other must error
        let bad = match detect() {
            KernelTier::Avx2 => KernelChoice::Neon,
            _ => KernelChoice::Avx2,
        };
        if resolve(bad).is_ok() {
            // (only possible if detect() returned the requested tier)
            return;
        }
        assert!(resolve(bad).is_err());
    }

    #[test]
    fn assigns_nearest_basic() {
        let rows = vec![0.0, 0.0, 0.2, 0.0, 10.0, 0.0, 10.2, 0.0];
        let mu = vec![0.0, 0.0, 10.0, 0.0];
        for tier in tiers() {
            let (assign, sums, counts, sse) = run_aa(&rows, 2, &mu, 2, tier);
            assert_eq!(assign, vec![0, 0, 1, 1], "{tier}");
            assert_eq!(counts, vec![2, 2]);
            assert!((sums[0] - 0.2).abs() < 1e-6);
            assert!((sums[2] - 20.2).abs() < 1e-5);
            assert!((sse - 0.08).abs() < 1e-5);
        }
    }

    #[test]
    fn tiers_bit_identical_property() {
        // assignments identical; f64 sums within 1 ulp (in practice
        // bit-identical: the SIMD lanes replay the scalar op sequence)
        prop::check("simd == scalar", 24, |g| {
            let d = *g.choice(&[1usize, 2, 3, 5, 8, 16, 17, 32]);
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 40);
            let rows = g.points(n, d, 15.0);
            let mu = g.points(k, d, 15.0);
            let (a0, s0, c0, e0) = run_aa(&rows, d, &mu, k, KernelTier::Scalar);
            for tier in tiers() {
                let (a, s, c, e) = run_aa(&rows, d, &mu, k, tier);
                prop::ensure(a == a0, format!("{tier}: assignments differ"))?;
                prop::ensure(c == c0, format!("{tier}: counts differ"))?;
                let sums_ok = s.iter().zip(&s0).all(|(x, y)| ulp_close(*x, *y));
                prop::ensure(sums_ok, format!("{tier}: sums differ by > 1 ulp"))?;
                prop::ensure(ulp_close(e, e0), format!("{tier}: sse differs"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn d17_non_lane_multiple_edge_case() {
        // d = 17 exercises the any-d transposed-tile path (no lane
        // remainder handling exists along d by construction)
        let mut g = prop::Gen::new(0xD17);
        let (n, k, d) = (131, 7, 17);
        let rows = g.points(n, d, 8.0);
        let mu = g.points(k, d, 8.0);
        let (a0, s0, c0, e0) = run_aa(&rows, d, &mu, k, KernelTier::Scalar);
        // reference: plain per-point sqdist scan
        for i in 0..n {
            let p = &rows[i * d..(i + 1) * d];
            let mut best = 0;
            let mut best_d = f32::INFINITY;
            for c in 0..k {
                let dist = crate::linalg::sqdist(p, &mu[c * d..(c + 1) * d]);
                if dist < best_d {
                    best_d = dist;
                    best = c as i32;
                }
            }
            assert_eq!(a0[i], best, "point {i}");
        }
        for tier in tiers() {
            let (a, s, c, e) = run_aa(&rows, d, &mu, k, tier);
            assert_eq!(a, a0, "{tier}");
            assert_eq!(c, c0, "{tier}");
            assert!(s.iter().zip(&s0).all(|(x, y)| ulp_close(*x, *y)), "{tier}");
            assert!(ulp_close(e, e0), "{tier}");
        }
    }

    #[test]
    fn paper_datasets_bit_identical_across_tiers() {
        // acceptance: identical assignments on the paper's 2D/3D GMM
        // families, every available tier vs scalar
        for (dim, k) in [(2usize, 8usize), (3, 4)] {
            let spec = if dim == 2 {
                crate::data::MixtureSpec::paper_2d(k)
            } else {
                crate::data::MixtureSpec::paper_3d(k)
            };
            let ds = spec.generate(20_003, 42); // ragged tail block
            let mu: Vec<f32> = ds.rows(0, k).to_vec();
            let (a0, ..) = run_aa(ds.raw(), dim, &mu, k, KernelTier::Scalar);
            for tier in tiers() {
                let (a, ..) = run_aa(ds.raw(), dim, &mu, k, tier);
                assert_eq!(a, a0, "tier {tier} diverged on paper {dim}D");
            }
        }
    }

    #[test]
    fn two_nearest_matches_scalar_scan() {
        prop::check("two-nearest == reference", 16, |g| {
            let d = *g.choice(&[2usize, 3, 9, 17]);
            let n = g.usize_in(1, 200);
            let k = g.usize_in(2, 12);
            let rows = g.points(n, d, 10.0);
            let mu = g.points(k, d, 10.0);
            for tier in tiers() {
                let mut assign = vec![0i32; n];
                let mut d1 = vec![0.0f32; n];
                let mut d2 = vec![0.0f32; n];
                assign_two_nearest(&rows, d, &mu, k, &mut assign, &mut d1, &mut d2, tier);
                for i in 0..n {
                    let p = &rows[i * d..(i + 1) * d];
                    let (mut best, mut r1, mut r2) = (0i32, f32::INFINITY, f32::INFINITY);
                    for c in 0..k {
                        let dist = crate::linalg::sqdist(p, &mu[c * d..(c + 1) * d]);
                        if dist < r1 {
                            r2 = r1;
                            r1 = dist;
                            best = c as i32;
                        } else if dist < r2 {
                            r2 = dist;
                        }
                    }
                    prop::ensure(assign[i] == best, format!("{tier}: argmin point {i}"))?;
                    prop::ensure(d1[i] == r1, format!("{tier}: d1 point {i}"))?;
                    prop::ensure(d2[i] == r2, format!("{tier}: d2 point {i}"))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sqdist_pruned_all_true_mask_equals_sqdist_matrix_bitwise() {
        // the pruned kernel's contract: a masked entry is the dense
        // matrix entry, bit for bit, on every available tier
        prop::check("pruned(all-true) == matrix", 24, |g| {
            let d = *g.choice(&[1usize, 2, 3, 7, 17]);
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 12);
            let rows = g.points(n, d, 9.0);
            let mu = g.points(k, d, 9.0);
            let nblocks = n.div_ceil(POINTS_BLOCK);
            let mask = vec![true; nblocks * k];
            for tier in tiers() {
                let mut dense = vec![0.0f32; n * k];
                sqdist_matrix(&rows, d, &mu, k, &mut dense, tier);
                let mut pruned = vec![f32::NAN; n * k];
                let computed = sqdist_pruned(&rows, d, &mu, k, &mask, &mut pruned, tier);
                prop::ensure(
                    computed == (n * k) as u64,
                    format!("{tier}: computed {computed} != n*k {}", n * k),
                )?;
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                prop::ensure(bits(&pruned) == bits(&dense), format!("{tier}: bits differ"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn sqdist_pruned_partial_mask_touches_only_masked_entries() {
        prop::check("pruned partial mask", 16, |g| {
            let d = *g.choice(&[2usize, 3, 17]);
            let n = g.usize_in(1, 200);
            let k = g.usize_in(1, 9);
            let rows = g.points(n, d, 6.0);
            let mu = g.points(k, d, 6.0);
            let nblocks = n.div_ceil(POINTS_BLOCK);
            let mask: Vec<bool> = (0..nblocks * k).map(|_| g.bool()).collect();
            let want: u64 = (0..nblocks)
                .flat_map(|b| (0..k).map(move |c| (b, c)))
                .filter(|&(b, c)| mask[b * k + c])
                .map(|(b, _)| (n - b * POINTS_BLOCK).min(POINTS_BLOCK) as u64)
                .sum();
            for tier in tiers() {
                let sentinel = -1.0f32;
                let mut out = vec![sentinel; n * k];
                let computed = sqdist_pruned(&rows, d, &mu, k, &mask, &mut out, tier);
                prop::ensure(computed == want, format!("{tier}: count {computed} != {want}"))?;
                for i in 0..n {
                    for c in 0..k {
                        let m = mask[(i / POINTS_BLOCK) * k + c];
                        let got = out[i * k + c];
                        if m {
                            let r = crate::linalg::sqdist(
                                &rows[i * d..(i + 1) * d],
                                &mu[c * d..(c + 1) * d],
                            );
                            prop::ensure(got == r, format!("{tier}: ({i},{c}) wrong value"))?;
                        } else {
                            prop::ensure(
                                got == sentinel,
                                format!("{tier}: ({i},{c}) written but unmasked"),
                            )?;
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sqdist_matrix_matches_pointwise() {
        let mut g = prop::Gen::new(7);
        let (n, k, d) = (97, 6, 5);
        let rows = g.points(n, d, 4.0);
        let mu = g.points(k, d, 4.0);
        for tier in tiers() {
            let mut out = vec![0.0f32; n * k];
            sqdist_matrix(&rows, d, &mu, k, &mut out, tier);
            for i in 0..n {
                for c in 0..k {
                    let want =
                        crate::linalg::sqdist(&rows[i * d..(i + 1) * d], &mu[c * d..(c + 1) * d]);
                    assert_eq!(out[i * k + c], want, "{tier} ({i},{c})");
                }
            }
        }
    }

    // ---- dot-policy (norm-trick) kernels -------------------------------

    fn norms_of(rows: &[f32], d: usize) -> Vec<f32> {
        row_norms_vec(rows, d)
    }

    /// f64 reference squared distance (no norm trick, no f32 rounding).
    fn refdist(p: &[f32], c: &[f32]) -> f64 {
        crate::linalg::sqdist_f64(p, c)
    }

    #[test]
    fn distance_policy_parse_and_display() {
        for p in [DistancePolicy::Exact, DistancePolicy::Dot] {
            assert_eq!(p.to_string().parse::<DistancePolicy>().unwrap(), p);
        }
        assert!("cosine".parse::<DistancePolicy>().is_err());
        assert_eq!(DistancePolicy::default(), DistancePolicy::Exact);
    }

    #[test]
    fn row_norms_match_sqdist_to_origin() {
        prop::check("row norms == sqdist(x, 0)", 16, |g| {
            let d = *g.choice(&[1usize, 2, 3, 17]);
            let n = g.usize_in(1, 150);
            let rows = g.points(n, d, 7.0);
            let norms = norms_of(&rows, d);
            let zero = vec![0.0f32; d];
            for i in 0..n {
                let want = crate::linalg::sqdist(&rows[i * d..(i + 1) * d], &zero);
                prop::ensure(norms[i] == want, format!("row {i}: {} != {want}", norms[i]))?;
            }
            Ok(())
        });
    }

    #[test]
    fn sqdist_matrix_dot_within_tolerance_of_reference() {
        prop::check("dot matrix ~= f64 reference", 16, |g| {
            let d = *g.choice(&[1usize, 2, 3, 5, 17]);
            let n = g.usize_in(1, 200);
            let k = g.usize_in(1, 9);
            let rows = g.points(n, d, 8.0);
            let mu = g.points(k, d, 8.0);
            let xn = norms_of(&rows, d);
            let cn = norms_of(&mu, d);
            for tier in tiers() {
                let mut out = vec![0.0f32; n * k];
                sqdist_matrix_dot(&rows, d, &mu, k, &xn, &cn, &mut out, tier);
                for i in 0..n {
                    for c in 0..k {
                        let want = refdist(&rows[i * d..(i + 1) * d], &mu[c * d..(c + 1) * d]);
                        let got = out[i * k + c] as f64;
                        // cancellation scale: the norms the trick subtracts
                        let scale = (xn[i] + cn[c]) as f64;
                        prop::ensure(
                            got >= 0.0 && (got - want).abs() <= 1e-4 * scale.max(1.0),
                            format!("{tier}: ({i},{c}) got {got} want {want}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn assign_accumulate_dot_picks_near_optimal_centroids() {
        // dot argmin may legitimately differ from exact on razor-thin
        // ties; what it must never do is pick a centroid measurably
        // farther than the true nearest
        prop::check("dot argmin near-optimal", 16, |g| {
            let d = *g.choice(&[2usize, 3, 17]);
            let n = g.usize_in(1, 250);
            let k = g.usize_in(1, 12);
            let rows = g.points(n, d, 10.0);
            let mu = g.points(k, d, 10.0);
            let xn = norms_of(&rows, d);
            let cn = norms_of(&mu, d);
            for tier in tiers() {
                let mut assign = vec![-1i32; n];
                let mut sums = vec![0.0f64; k * d];
                let mut counts = vec![0u64; k];
                let mut sse = 0.0f64;
                assign_accumulate_dot(
                    &rows, d, &mu, k, &xn, &cn, &mut assign, &mut sums, &mut counts, &mut sse,
                    tier,
                );
                prop::ensure(counts.iter().sum::<u64>() == n as u64, "counts != n")?;
                for i in 0..n {
                    let p = &rows[i * d..(i + 1) * d];
                    let chosen = refdist(p, &mu[assign[i] as usize * d..]);
                    let best = (0..k)
                        .map(|c| refdist(p, &mu[c * d..(c + 1) * d]))
                        .fold(f64::INFINITY, f64::min);
                    let slack = 1e-4 * (xn[i] as f64 + 1.0);
                    prop::ensure(
                        chosen <= best + slack,
                        format!("{tier}: point {i} chose {chosen} vs best {best}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn exact_ties_break_to_first_lowest_index_both_policies() {
        // duplicate every centroid after the original block: identical
        // inputs produce identical per-tier distances, so the strict-<
        // ascending-index argmin must never select a later duplicate
        prop::check("tie-break first-lowest-index", 16, |g| {
            let d = *g.choice(&[1usize, 2, 3, 17]);
            let n = g.usize_in(1, 200);
            let kbase = g.usize_in(1, 9);
            let rows = g.points(n, d, 6.0);
            let base = g.points(kbase, d, 6.0);
            let mut mu = base.clone();
            mu.extend_from_slice(&base); // k = 2 × kbase, exact duplicates
            let k = 2 * kbase;
            let xn = norms_of(&rows, d);
            let cn = norms_of(&mu, d);
            for tier in tiers() {
                let mut sums = vec![0.0f64; k * d];
                let mut counts = vec![0u64; k];
                let mut sse = 0.0f64;

                let mut a_exact = vec![-1i32; n];
                assign_accumulate(
                    &rows, d, &mu, k, &mut a_exact, &mut sums, &mut counts, &mut sse, tier,
                );
                for (i, &a) in a_exact.iter().enumerate() {
                    prop::ensure(
                        (a as usize) < kbase,
                        format!("{tier} exact: point {i} picked duplicate {a}"),
                    )?;
                }

                sums.iter_mut().for_each(|v| *v = 0.0);
                counts.iter_mut().for_each(|v| *v = 0);
                sse = 0.0;
                let mut a_dot = vec![-1i32; n];
                assign_accumulate_dot(
                    &rows, d, &mu, k, &xn, &cn, &mut a_dot, &mut sums, &mut counts, &mut sse,
                    tier,
                );
                for (i, &a) in a_dot.iter().enumerate() {
                    prop::ensure(
                        (a as usize) < kbase,
                        format!("{tier} dot: point {i} picked duplicate {a}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dot_agrees_with_exact_on_paper_gmms() {
        // the cross-policy acceptance contract at the kernel level:
        // identical assignments on the paper's 2D/3D GMM families
        for (dim, k) in [(2usize, 8usize), (3, 4)] {
            let spec = if dim == 2 {
                crate::data::MixtureSpec::paper_2d(k)
            } else {
                crate::data::MixtureSpec::paper_3d(k)
            };
            let ds = spec.generate(20_003, 42); // ragged tail block
            let mu: Vec<f32> = ds.rows(0, k).to_vec();
            let xn = norms_of(ds.raw(), dim);
            let cn = norms_of(&mu, dim);
            let (a_exact, ..) = run_aa(ds.raw(), dim, &mu, k, KernelTier::Scalar);
            for tier in tiers() {
                let n = ds.len();
                let mut assign = vec![-1i32; n];
                let mut sums = vec![0.0f64; k * dim];
                let mut counts = vec![0u64; k];
                let mut sse = 0.0f64;
                assign_accumulate_dot(
                    ds.raw(), dim, &mu, k, &xn, &cn, &mut assign, &mut sums, &mut counts,
                    &mut sse, tier,
                );
                assert_eq!(assign, a_exact, "dot({tier}) diverged on paper {dim}D");
            }
        }
    }

    #[test]
    fn two_nearest_dot_ordering_and_tolerance() {
        prop::check("dot two-nearest ~= reference", 12, |g| {
            let d = *g.choice(&[2usize, 3, 9]);
            let n = g.usize_in(1, 150);
            let k = g.usize_in(2, 10);
            let rows = g.points(n, d, 8.0);
            let mu = g.points(k, d, 8.0);
            let xn = norms_of(&rows, d);
            let cn = norms_of(&mu, d);
            for tier in tiers() {
                let mut assign = vec![0i32; n];
                let mut d1 = vec![0.0f32; n];
                let mut d2 = vec![0.0f32; n];
                assign_two_nearest_dot(
                    &rows, d, &mu, k, &xn, &cn, &mut assign, &mut d1, &mut d2, tier,
                );
                for i in 0..n {
                    prop::ensure(
                        d1[i] >= 0.0 && d1[i] <= d2[i],
                        format!("{tier}: point {i} d1 {} > d2 {}", d1[i], d2[i]),
                    )?;
                    let p = &rows[i * d..(i + 1) * d];
                    let best = (0..k)
                        .map(|c| refdist(p, &mu[c * d..(c + 1) * d]))
                        .fold(f64::INFINITY, f64::min);
                    let slack = 1e-4 * (xn[i] as f64 + 1.0);
                    prop::ensure(
                        (d1[i] as f64 - best).abs() <= slack,
                        format!("{tier}: point {i} d1 {} vs best {best}", d1[i]),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pruned_mask_edges_both_policies() {
        // empty mask, full mask, and a single-row tail block — the mask
        // edge cases for both distance formulations
        let mut g = prop::Gen::new(0xED6E);
        let d = 3usize;
        let n = POINTS_BLOCK + 1; // second block holds exactly one row
        let k = 5usize;
        let rows = g.points(n, d, 5.0);
        let mu = g.points(k, d, 5.0);
        let xn = norms_of(&rows, d);
        let cn = norms_of(&mu, d);
        let nblocks = n.div_ceil(POINTS_BLOCK);
        assert_eq!(nblocks, 2);
        let sentinel = -7.0f32;

        for tier in tiers() {
            // empty mask: nothing computed, nothing touched
            let empty = vec![false; nblocks * k];
            for dot in [false, true] {
                let mut out = vec![sentinel; n * k];
                let computed = if dot {
                    sqdist_pruned_dot(&rows, d, &mu, k, &xn, &cn, &empty, &mut out, tier)
                } else {
                    sqdist_pruned(&rows, d, &mu, k, &empty, &mut out, tier)
                };
                assert_eq!(computed, 0, "{tier} dot={dot}: empty mask computed pairs");
                assert!(
                    out.iter().all(|&v| v == sentinel),
                    "{tier} dot={dot}: empty mask wrote entries"
                );
            }

            // full mask: bitwise the dense matrix of the same policy
            let full = vec![true; nblocks * k];
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            let mut dense = vec![0.0f32; n * k];
            let mut pruned = vec![sentinel; n * k];
            sqdist_matrix(&rows, d, &mu, k, &mut dense, tier);
            let computed = sqdist_pruned(&rows, d, &mu, k, &full, &mut pruned, tier);
            assert_eq!(computed, (n * k) as u64, "{tier}: full-mask count");
            assert_eq!(bits(&pruned), bits(&dense), "{tier}: exact full mask");
            let mut dense_dot = vec![0.0f32; n * k];
            let mut pruned_dot = vec![sentinel; n * k];
            sqdist_matrix_dot(&rows, d, &mu, k, &xn, &cn, &mut dense_dot, tier);
            let computed =
                sqdist_pruned_dot(&rows, d, &mu, k, &xn, &cn, &full, &mut pruned_dot, tier);
            assert_eq!(computed, (n * k) as u64, "{tier}: dot full-mask count");
            assert_eq!(bits(&pruned_dot), bits(&dense_dot), "{tier}: dot full mask");

            // single-row tail block: only the tail's masked column is
            // evaluated, and it counts exactly one pair
            let mut tail = vec![false; nblocks * k];
            tail[k + 2] = true; // block 1 (the 1-row tail), centroid 2
            for dot in [false, true] {
                let mut out = vec![sentinel; n * k];
                let computed = if dot {
                    sqdist_pruned_dot(&rows, d, &mu, k, &xn, &cn, &tail, &mut out, tier)
                } else {
                    sqdist_pruned(&rows, d, &mu, k, &tail, &mut out, tier)
                };
                assert_eq!(computed, 1, "{tier} dot={dot}: tail count");
                let touched: Vec<usize> =
                    (0..n * k).filter(|&i| out[i] != sentinel).collect();
                assert_eq!(
                    touched,
                    vec![POINTS_BLOCK * k + 2],
                    "{tier} dot={dot}: tail wrote the wrong entries"
                );
            }
        }
    }
}
