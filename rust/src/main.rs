//! `parakm` — the parakmeans CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   gen-data   generate a paper-family GMM dataset to a .pkd/.csv file
//!              (`--chunk` streams the write with O(chunk) memory)
//!   run        cluster a dataset with any engine, print a report
//!              (`--engine oocore` streams with `--memory-budget`;
//!              `--engine dist --workers a:p,b:p` runs the distributed
//!              leader; `--save-model` persists the trained model)
//!   worker     serve one data shard to a distributed leader
//!   eval       regenerate paper tables/figures (t1..t5, f*, a1..a3, all)
//!   serve      nearest-centroid assignment as a line-JSON TCP service
//!              (`--model model.pkm` loads instead of retraining)
//!   info       show AOT artifact manifest + runtime info
//!
//! Examples:
//!   parakm gen-data --dim 3 --n 100000 --out data/d3_100k.pkd
//!   parakm run --input data/d3_100k.pkd --engine shared --k 4 --threads 8
//!   parakm run --synthetic 3d:200000 --engine offload --k 4 --kernel scalar
//!   parakm run --input data/d3_100k.pkd --engine oocore --k 4 --memory-budget 1M
//!   parakm run --synthetic 3d:100000000 --engine oocore --k 4 --memory-budget 64M
//!   parakm worker --listen 127.0.0.1:7551 --input data/d3_100k.pkd --shard 0/2
//!   parakm worker --listen 127.0.0.1:7552 --input data/d3_100k.pkd --shard 1/2
//!   parakm run --engine dist --workers 127.0.0.1:7551,127.0.0.1:7552 --k 4
//!   parakm run --input data/d3_100k.pkd --engine serial --k 4 --save-model m.pkm
//!   parakm serve --model m.pkm --addr 127.0.0.1:7878
//!   parakm eval --exp t3 --scale smoke
//!   parakm info

use std::path::PathBuf;

use parakmeans::config::{parse_bytes, DistancePolicy, Engine, Init, RunConfig, SchedMode};
use parakmeans::coordinator::{offload, shared};
use parakmeans::data::source::{DataSource, FileSource, GmmSource};
use parakmeans::data::{gmm::MixtureSpec, io, Dataset};
use parakmeans::error::{Error, Result};
use parakmeans::eval::{self, Scale};
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::linalg::kernel::{self, KernelChoice};
use parakmeans::metrics;
use parakmeans::util::args::Args;
use parakmeans::util::chaos;
use parakmeans::util::trace;

/// `anyhow::Context` stand-in (no third-party crates offline).
trait OrConfig<T> {
    fn or_config(self, msg: &str) -> Result<T>;
}

impl<T> OrConfig<T> for Option<T> {
    fn or_config(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| Error::Config(msg.to_string()))
    }
}

impl<T, E: std::fmt::Display> OrConfig<T> for std::result::Result<T, E> {
    fn or_config(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error::Config(format!("{msg}: {e}")))
    }
}

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("gen-data") => cmd_gen_data(args),
        Some("run") => cmd_run(args),
        Some("worker") => cmd_worker(args),
        Some("eval") => cmd_eval(args),
        Some("serve") => cmd_serve(args),
        Some("info") => cmd_info(args),
        Some(other) => Err(Error::Config(format!(
            "unknown subcommand `{other}` (gen-data|run|worker|eval|serve|info)"
        ))),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "parakm — parallel K-Means (rust + JAX/Pallas AOT)\n\
         \n\
         usage: parakm <gen-data|run|eval|serve|info> [flags]\n\
         \n\
         gen-data  --dim <2|3> --n <N> --out <file.pkd|file.csv> [--components K] [--seed S]\n\
         \u{20}          [--chunk C]   (stream the write, O(C) memory)\n\
         run       --input <file> | --synthetic <2d|3d>:<N>\n\
         \u{20}          --engine serial|threads|shared|offload|elkan|hamerly|minibatch|streaming|oocore|dist\n\
         \u{20}          --k K [--threads P] [--tol T] [--max-iters M] [--seed S]\n\
         \u{20}          [--init random|kmeans++] [--chunk C] [--artifacts DIR] [--assign-out FILE]\n\
         \u{20}          [--kernel auto|scalar|avx2|neon] [--save-model FILE.pkm]\n\
         \u{20}          [--distance exact|dot]   (pure-rust engines; exact = bit-identity default)\n\
         \u{20}          [--sched static|steal]   (threads/elkan/hamerly chunk scheduler)\n\
         \u{20}          [--memory-budget BYTES[K|M|G]]   (oocore: bound resident chunk buffers)\n\
         \u{20}          [--workers a:p1,b:p2,...] [--net-timeout SECS]   (dist: shard workers)\n\
         \u{20}          [--dist-sched static|elastic] [--retry N]   (dist: elastic = chunk\n\
         \u{20}          re-dispatch + worker retry/rejoin; needs replicated full-view workers)\n\
         \u{20}          [--checkpoint DIR] [--checkpoint-every N] [--resume DIR]   (durable .pkc\n\
         \u{20}          snapshots, A/B rotated; resume continues bit-identically —\n\
         \u{20}          serial|threads|elkan|hamerly|oocore|dist)\n\
         \u{20}          [--trace FILE.jsonl | PARAKM_TRACE=FILE] [--stats-every N]   (per-iteration\n\
         \u{20}          phase spans to JSONL + live progress every N iterations; off = zero cost)\n\
         worker    --listen HOST:PORT  --input <file.pkd> | --synthetic <2d|3d>:<N>\n\
         \u{20}          [--shard I/S] [--chunk C] [--seed S (synthetic only)] [--once]\n\
         eval      --exp t1|..|t5|figs|speedup|scaling|a1|a2|a3|report|all [--scale full|smoke]\n\
         serve     --model <file.pkm> | (--input <file> | --synthetic <2d|3d>:<N>)  --k K\n\
         \u{20}          [--addr HOST:PORT] [--max-batch B] [--max-delay-ms T] [--max-conns C]\n\
         \u{20}          [--serve-loop poll|threads]   (poll = event-driven reactor, unix default)\n\
         \u{20}          [--max-line-bytes B] [--shed-soft-pct PCT] [--shed-heavy-points N]\n\
         \u{20}          [--stats-every SECS]   (periodic latency/shed summary on stderr)\n\
         \u{20}          [--artifacts DIR] [--distance exact|dot]\n\
         \u{20}          ({{\"stats\": true}} probes live counters + latency percentiles;\n\
         \u{20}          {{\"metrics\": true}} dumps the metrics registry, \"text\" = Prometheus;\n\
         \u{20}          {{\"health\": true}} = live/ready probe, {{\"reload\": \"m.pkm\"}} hot-swaps\n\
         \u{20}          the model; SIGTERM drains + exits 0, SIGHUP reloads --model)\n\
         info      [--artifacts DIR]\n\
         \n\
         any       [--chaos SEED[:SITES[:PERIOD]] | PARAKM_CHAOS=SPEC]   (deterministic fault\n\
         \u{20}          injection at the I/O choke points; sites: atomic-write, artifact-read,\n\
         \u{20}          wire-read, wire-write, serve-accept, serve-enqueue, batcher, or `all`)"
    );
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let dim: usize = args.require("dim")?;
    let n: usize = args.require("n")?;
    let out: PathBuf = PathBuf::from(args.get("out").or_config("missing --out")?.to_string());
    let seed: u64 = args.get_or("seed", 42)?;
    let components: usize = args.get_or("components", if dim == 2 { 8 } else { 4 })?;
    let chunk: usize = args.get_or("chunk", 0)?; // 0 = whole dataset in memory
    args.finish()?;

    let spec = match dim {
        2 => MixtureSpec::paper_2d(components),
        3 => MixtureSpec::paper_3d(components),
        d => MixtureSpec::random(d, components, 12.0, 1.5, 0x9e0 + d as u64),
    };
    let is_csv = out.extension().and_then(|e| e.to_str()) == Some("csv");
    if chunk > 0 {
        gen_data_streamed(&spec, n, seed, &out, chunk, dim, is_csv)?;
    } else {
        let ds = spec.generate(n, seed);
        if is_csv {
            io::write_csv(&out, &ds)?;
        } else {
            io::write_binary(&out, &ds)?;
        }
    }
    println!(
        "wrote {} points ({dim}D, {components} components, seed {seed}) to {}",
        n,
        out.display()
    );
    Ok(())
}

/// `gen-data --chunk`: stream the write with O(chunk) resident memory.
/// The sequential sampler draws the exact bytes `generate(n, seed)`
/// would, so output is byte-identical to the unstreamed path. For
/// `.pkd`, truth labels follow the payload on disk, so a second
/// sampler replay streams them too — label memory stays O(chunk) at
/// the cost of generating twice. CSV carries no labels (one pass).
fn gen_data_streamed(
    spec: &MixtureSpec,
    n: usize,
    seed: u64,
    out: &std::path::Path,
    chunk: usize,
    dim: usize,
    is_csv: bool,
) -> Result<()> {
    use std::io::Write as _;

    if is_csv {
        // CSV is row-at-a-time through the BufWriter — no chunk
        // staging needed, the flag only bounds the (absent) buffering
        if let Some(dir) = out.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(out)?);
        writeln!(w, "{}", io::csv_header(dim))?;
        let mut sampler = spec.sampler(seed);
        let mut row = vec![0.0f32; dim];
        for _ in 0..n {
            sampler.next_row(&mut row);
            writeln!(w, "{}", io::csv_row(&row))?;
        }
        w.flush()?;
        return Ok(());
    }

    let mut block = vec![0.0f32; chunk.min(n.max(1)) * dim];
    let mut w = io::BinWriter::create(out, dim, n, true)?;
    let mut sampler = spec.sampler(seed);
    let mut written = 0usize;
    while written < n {
        let rows = chunk.min(n - written);
        for row in block[..rows * dim].chunks_exact_mut(dim) {
            sampler.next_row(row);
        }
        w.write_rows(&block[..rows * dim])?;
        written += rows;
    }
    // second pass: replay the sampler for the trailing truth section
    let mut sampler = spec.sampler(seed);
    let mut labels = Vec::with_capacity(chunk.min(n.max(1)));
    let mut row = vec![0.0f32; dim];
    let mut written = 0usize;
    while written < n {
        let rows = chunk.min(n - written);
        labels.clear();
        for _ in 0..rows {
            labels.push(sampler.next_row(&mut row) as i32);
        }
        w.write_truth(&labels)?;
        written += rows;
    }
    w.finish(None)
}

fn load_input(args: &Args) -> Result<Dataset> {
    if let Some(path) = args.get("input") {
        let p = PathBuf::from(path);
        let ds = match p.extension().and_then(|e| e.to_str()) {
            Some("csv") => io::read_csv(&p)?,
            _ => io::read_binary(&p)?,
        };
        return Ok(ds);
    }
    if let Some(spec) = args.get("synthetic") {
        let (dim, n) = parse_synthetic(spec)?;
        return Ok(eval::paper_dataset(dim, n));
    }
    Err(Error::Config("provide --input <file> or --synthetic <2d|3d>:<N>".into()))
}

/// Resolve the distance policy: `--distance` wins, else the
/// `PARAKM_DISTANCE` env var, else `exact` (the bit-identity default).
fn distance_from(args: &Args) -> Result<DistancePolicy> {
    match args.get("distance") {
        Some(v) => v.parse(),
        None => DistancePolicy::from_env(),
    }
}

/// Parse a `--synthetic <2d|3d>:<N>` spec into `(dim, n)`.
fn parse_synthetic(spec: &str) -> Result<(usize, usize)> {
    let (dim_s, n_s) = spec
        .split_once(':')
        .or_config("--synthetic expects <2d|3d>:<N>")?;
    let dim = match dim_s {
        "2d" => 2,
        "3d" => 3,
        other => return Err(Error::Config(format!("--synthetic dim `{other}` (2d|3d)"))),
    };
    let n: usize = n_s.parse().or_config("--synthetic size")?;
    Ok((dim, n))
}

fn cmd_run(args: &Args) -> Result<()> {
    let engine: Engine = args.require("engine")?;
    if engine == Engine::OutOfCore {
        // the point of oocore is that the dataset is never resident —
        // it gets its own path that opens a source instead of loading
        return cmd_run_oocore(args);
    }
    if engine == Engine::Dist {
        // the data lives at the workers; the leader loads nothing
        return cmd_run_dist(args);
    }
    let ds = load_input(args)?;
    let k: usize = args.require("k")?;
    let threads: usize = args.get_or("threads", 4)?;
    let tol: f64 = args.get_or("tol", 1e-6)?;
    let max_iters: usize = args.get_or("max-iters", 300)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let init: Init = args.get_or("init", Init::Random)?;
    let chunk: usize = args.get_or("chunk", 0)?; // 0 = auto
    let batch: usize = args.get_or("batch", 8192)?;
    let sched_flag: Option<SchedMode> = args.get("sched").map(|v| v.parse()).transpose()?;
    // the knob only reaches the chunk-scheduled engines — reject it
    // elsewhere so an ablation script cannot silently no-op
    if sched_flag.is_some() && !matches!(engine, Engine::Threads | Engine::Elkan | Engine::Hamerly)
    {
        return Err(Error::Config(format!(
            "--sched applies to threads|elkan|hamerly, not `{engine}`"
        )));
    }
    // dense threads defaults to the static shards so the documented
    // `oocore --threads S` ≡ `threads --threads S` bit-identity
    // (DESIGN.md §4) holds out of the box; the pruned engines default
    // to stealing, where results are bit-identical either way
    let sched = sched_flag.unwrap_or(match engine {
        Engine::Threads => SchedMode::Static,
        _ => SchedMode::Steal,
    });
    let kernel_flag: Option<KernelChoice> =
        args.get("kernel").map(|v| v.parse()).transpose()?;
    let distance = distance_from(args)?;
    // the norm-trick path lives in the pure-rust kernels; the AOT
    // coordinator engines run their own executables — reject instead
    // of silently serving exact distances under a dot request
    if distance == DistancePolicy::Dot && !engine.supports_distance_policy() {
        return Err(Error::Config(format!(
            "--distance dot applies to the pure-rust engines, not `{engine}`"
        )));
    }
    let artifacts: PathBuf =
        PathBuf::from(args.get("artifacts").unwrap_or("artifacts").to_string());
    let assign_out = args.get("assign-out").map(PathBuf::from);
    let save_model = args.get("save-model").map(PathBuf::from);
    let ckpt_dir = args.get("checkpoint").map(PathBuf::from);
    let ckpt_every: usize = args.get_or("checkpoint-every", 1)?;
    let resume_dir = args.get("resume").map(PathBuf::from);
    install_trace_from(args)?;
    install_chaos_from(args)?;
    args.finish()?;

    if ckpt_every == 0 {
        return Err(Error::Config("--checkpoint-every must be >= 1".into()));
    }
    let ckpt_active = ckpt_dir.is_some() || resume_dir.is_some();
    // only the engines wired for iteration-boundary snapshots accept
    // the flags — rejecting elsewhere keeps "checkpointed" honest
    if ckpt_active && !matches!(engine, Engine::Serial | Engine::Threads | Engine::Elkan | Engine::Hamerly)
    {
        return Err(Error::Config(format!(
            "--checkpoint/--resume apply to serial|threads|elkan|hamerly|oocore|dist, not `{engine}`"
        )));
    }

    // fix the process-global hot-path tier before any engine runs: an
    // explicit --kernel wins; otherwise active_tier() honors the
    // PARAKM_KERNEL env var before falling back to detection
    let tier = match kernel_flag {
        Some(choice) => kernel::set_active(choice)?,
        None => kernel::active_tier(),
    };
    let kernel_choice = kernel_flag.unwrap_or(KernelChoice::Auto);

    let kc = KmeansConfig { k, tol, max_iters, seed, init, distance };
    // the fingerprint pins everything resumed state must agree on; a
    // serial run has no scheduler, recorded as "none"
    let (sink, resume_state) = if ckpt_active {
        let sched_str = match engine {
            Engine::Serial => "none".to_string(),
            _ => sched.to_string(),
        };
        let fp = kmeans::ckpt::fingerprint(&engine.to_string(), &sched_str, &kc, ds.len(), ds.dim());
        let sink = match &ckpt_dir {
            Some(dir) => Some(kmeans::ckpt::CkptSink::create(dir, ckpt_every, fp.clone())?),
            None => None,
        };
        let state = match &resume_dir {
            Some(dir) => Some(kmeans::ckpt::load_validated(dir, &fp)?),
            None => None,
        };
        (sink, state)
    } else {
        (None, None)
    };
    let resumed_iter = resume_state.as_ref().map(|s| s.iteration);
    let t0 = std::time::Instant::now();
    let (result, setup, engine_wall) = match engine {
        Engine::Serial => {
            (kmeans::serial::run_ckpt(&ds, &kc, sink.as_ref(), resume_state)?, 0.0, None)
        }
        Engine::Threads => (
            kmeans::parallel::run_sched_ckpt(
                &ds,
                &kc,
                threads,
                kmeans::parallel::MergeMode::Leader,
                sched,
                sink.as_ref(),
                resume_state,
            )?,
            0.0,
            None,
        ),
        Engine::Elkan => (
            kmeans::elkan::run_ckpt(&ds, &kc, threads, sched, sink.as_ref(), resume_state)?,
            0.0,
            None,
        ),
        Engine::Hamerly => (
            kmeans::hamerly::run_ckpt(&ds, &kc, threads, sched, sink.as_ref(), resume_state)?,
            0.0,
            None,
        ),
        Engine::MiniBatch => (kmeans::minibatch::run(&ds, &kc, batch), 0.0, None),
        Engine::Shared => {
            let cfg = RunConfig {
                engine, k, tol, max_iters, seed, init, threads, sched, chunk, batch,
                memory_budget: 0, artifacts_dir: artifacts, kernel: kernel_choice, distance,
                checkpoint: None, checkpoint_every: 1, resume: None,
            };
            let run = shared::run(&ds, &cfg, threads)?;
            (run.result.clone(), run.setup_secs, Some((run.wall_secs, run.table_secs())))
        }
        Engine::Offload => {
            let cfg = RunConfig {
                engine, k, tol, max_iters, seed, init, threads, sched, chunk, batch,
                memory_budget: 0, artifacts_dir: artifacts, kernel: kernel_choice, distance,
                checkpoint: None, checkpoint_every: 1, resume: None,
            };
            let run = offload::run(&ds, &cfg)?;
            (run.result.clone(), run.setup_secs, Some((run.wall_secs, run.table_secs())))
        }
        Engine::Streaming => {
            let path = args
                .get("input")
                .or_config("--engine streaming requires --input <file.pkd>")?;
            let cfg = RunConfig {
                engine, k, tol, max_iters, seed, init, threads, sched, chunk, batch,
                memory_budget: 0, artifacts_dir: artifacts, kernel: kernel_choice, distance,
                checkpoint: None, checkpoint_every: 1, resume: None,
            };
            let run =
                parakmeans::coordinator::streaming::run_file(std::path::Path::new(path), &cfg)?;
            (run.result.clone(), run.setup_secs, Some((run.wall_secs, run.table_secs())))
        }
        Engine::OutOfCore => unreachable!("dispatched to cmd_run_oocore above"),
        Engine::Dist => unreachable!("dispatched to cmd_run_dist above"),
    };
    let total = t0.elapsed().as_secs_f64();

    println!("engine      : {engine}");
    println!("kernel tier : {tier} (requested: {kernel_choice})");
    println!("distance    : {distance}");
    println!("dataset     : {} points, {}D", ds.len(), ds.dim());
    println!("k           : {k}   init: {init:?}   seed: {seed}");
    println!(
        "iterations  : {} (converged: {})",
        result.iterations, result.converged
    );
    if let Some(it) = resumed_iter {
        println!("resumed     : from iteration {it}");
    }
    if let Some(s) = &sink {
        println!("checkpoints : {} (every {ckpt_every} iterations)", s.dir().display());
    }
    println!("sse         : {:.6e}", result.sse);
    println!("final shift : {:.3e}", result.shift);
    match engine_wall {
        Some((wall, table)) => {
            println!("setup       : {setup:.3}s (client + AOT compile + upload)");
            println!("iter loop   : {wall:.4}s wall, {table:.4}s testbed-clock");
        }
        None => println!("time        : {total:.4}s"),
    }
    println!("cluster sizes: {:?}", result.cluster_sizes());
    print_empty_clusters(&result);
    if let Some(prune) = &result.pruning {
        println!(
            "pruning     : {:.1}% of dense distance work skipped ({} computed, {} skipped)",
            100.0 * prune.skip_rate(),
            prune.computed(),
            prune.skipped()
        );
    }
    if let Some(truth) = &ds.truth {
        println!(
            "ARI vs truth: {:.4}",
            metrics::adjusted_rand_index(&result.assign, truth)
        );
    }
    if let Some(path) = assign_out {
        write_assign_csv(&path, &result.assign)?;
    }
    if let Some(path) = save_model {
        save_model_file(&path, engine, seed, &result)?;
    }
    finish_trace()?;
    print_artifact_warnings();
    Ok(())
}

/// One summary line when any iteration hit the keep-centroid policy
/// (an empty cluster kept its previous centroid — DESIGN.md §2).
/// Silent in the common all-clusters-populated case.
fn print_empty_clusters(result: &parakmeans::kmeans::KmeansResult) {
    let empties = result.empty_total();
    if empties > 0 {
        println!(
            "empty clust.: {empties} keep-centroid events across {} of {} iterations",
            result.empty_events.iter().filter(|&&e| e > 0).count(),
            result.iterations
        );
    }
}

/// `--trace FILE` / `PARAKM_TRACE` + `--stats-every N`: consume the
/// observability flags (before `args.finish()` so they count as used)
/// and install the process-wide tracer when either asks for it. Left
/// uninstalled, every span/emit call in the engines stays a single
/// relaxed atomic load (DESIGN.md §15).
fn install_trace_from(args: &Args) -> Result<()> {
    let flag = args.get("trace").map(|s| s.to_string());
    let stats_every: u64 = args.get_or("stats-every", 0)?;
    let path = trace::trace_path_from(flag.as_deref());
    if path.is_some() || stats_every > 0 {
        trace::install(path, stats_every);
    }
    Ok(())
}

/// `--chaos SEED[:SITES[:PERIOD]]` / `PARAKM_CHAOS`: consume the
/// fault-injection flag (before `args.finish()` so it counts as used)
/// and arm the process-wide chaos plan. Left uninstalled, every
/// injection site stays a single relaxed atomic load (DESIGN.md §16).
fn install_chaos_from(args: &Args) -> Result<()> {
    let flag = args.get("chaos").map(|s| s.to_string());
    if let Some(spec) = chaos::spec_from(flag.as_deref()) {
        chaos::install_spec(&spec)?;
        eprintln!("chaos: plan `{spec}` armed");
    }
    Ok(())
}

/// Flush the JSONL run trace (atomic write) and name it in the run
/// report. No-op when tracing was never installed.
fn finish_trace() -> Result<()> {
    if let Some(p) = trace::finish()? {
        println!("trace       : {}", p.display());
    }
    Ok(())
}

/// One summary line when any artifact read this run lacked (or needed
/// leniency about) its CRC trailer — legacy files still load, but the
/// run says so instead of silently trusting unverified bytes.
fn print_artifact_warnings() {
    let warns = io::artifact_warnings();
    if warns > 0 {
        println!(
            "warnings    : {warns} artifact integrity warning(s) — legacy CRC-less file(s) \
             read unverified; rewrite them to add trailers"
        );
    }
}

/// `--assign-out`: write the assignment vector as an `index,cluster`
/// CSV — one streamed writer shared by every engine path, so
/// cross-engine byte-compares (the CI dist-smoke `cmp`) stay valid and
/// no path stages an O(n)-row table (dist and oocore exist precisely
/// for n too big to double-buffer).
fn write_assign_csv(path: &std::path::Path, assign: &[i32]) -> Result<()> {
    use std::io::Write as _;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "index,cluster")?;
    for (i, &a) in assign.iter().enumerate() {
        writeln!(w, "{i},{a}")?;
    }
    w.flush()?;
    println!("assignments : {}", path.display());
    Ok(())
}

/// `--save-model`: persist the trained centroids + provenance as a
/// `.pkm` the serve command loads instead of retraining.
fn save_model_file(
    path: &std::path::Path,
    engine: Engine,
    seed: u64,
    result: &parakmeans::kmeans::KmeansResult,
) -> Result<()> {
    io::write_model(
        path,
        &io::Model {
            k: result.k,
            dim: result.dim,
            seed,
            engine: engine.to_string(),
            iterations: result.iterations,
            sse: result.sse,
            centroids: result.centroids.clone(),
        },
    )?;
    println!("model       : {}", path.display());
    Ok(())
}

/// `run --engine oocore`: cluster through a [`DataSource`] with
/// bounded resident memory — `--input file.pkd` streams from disk,
/// `--synthetic` streams from the on-the-fly GMM generator (so `n` can
/// exceed both RAM and disk).
fn cmd_run_oocore(args: &Args) -> Result<()> {
    use parakmeans::kmeans::streaming::{self, StreamOpts};

    let k: usize = args.require("k")?;
    let threads: usize = args.get_or("threads", 4)?;
    let tol: f64 = args.get_or("tol", 1e-6)?;
    let max_iters: usize = args.get_or("max-iters", 300)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let init: Init = args.get_or("init", Init::Random)?;
    let chunk: usize = args.get_or("chunk", 0)?;
    let memory_budget: usize = match args.get("memory-budget") {
        Some(raw) => parse_bytes(raw)?,
        None => 0,
    };
    let kernel_flag: Option<KernelChoice> =
        args.get("kernel").map(|v| v.parse()).transpose()?;
    let distance = distance_from(args)?;
    let assign_out = args.get("assign-out").map(PathBuf::from);
    let save_model = args.get("save-model").map(PathBuf::from);
    let ckpt_dir = args.get("checkpoint").map(PathBuf::from);
    let ckpt_every: usize = args.get_or("checkpoint-every", 1)?;
    let resume_dir = args.get("resume").map(PathBuf::from);

    // build the source without materializing anything
    let source: Box<dyn DataSource> = if let Some(path) = args.get("input") {
        let p = PathBuf::from(path);
        match p.extension().and_then(|e| e.to_str()) {
            Some(e) if e.eq_ignore_ascii_case("csv") => {
                return Err(Error::Config(
                    "--engine oocore streams .pkd files, not csv; \
                     convert with gen-data or use an in-memory engine"
                        .into(),
                ))
            }
            // anything else: let the magic-number probe decide (same
            // policy as the in-memory loader)
            _ => Box::new(FileSource::open(&p)?),
        }
    } else if let Some(spec) = args.get("synthetic") {
        // NOTE: streams the per-row-seeded generator family — a
        // different (equally distributed) sample sequence than the
        // in-memory engines' --synthetic datasets, which no O(1)-seek
        // generator can reproduce. For cross-engine bit-identity
        // comparisons use a shared --input file.
        let (dim, n) = parse_synthetic(spec)?;
        Box::new(GmmSource::paper(dim, n, parakmeans::data::gmm::workloads::seed_for(dim, n))?)
    } else {
        return Err(Error::Config("provide --input <file.pkd> or --synthetic <2d|3d>:<N>".into()));
    };
    install_trace_from(args)?;
    install_chaos_from(args)?;
    args.finish()?;

    let tier = match kernel_flag {
        Some(choice) => kernel::set_active(choice)?,
        None => kernel::active_tier(),
    };
    let kernel_choice = kernel_flag.unwrap_or(KernelChoice::Auto);
    let cfg = RunConfig {
        engine: Engine::OutOfCore,
        k,
        tol,
        max_iters,
        seed,
        init,
        threads,
        sched: SchedMode::Static, // oocore shards contiguously by design
        chunk,
        memory_budget,
        batch: 8192,
        artifacts_dir: "artifacts".into(),
        kernel: kernel_choice,
        distance,
        checkpoint: ckpt_dir.clone(),
        checkpoint_every: ckpt_every,
        resume: resume_dir.clone(),
    };
    cfg.validate()?;
    let opts = StreamOpts::from_run_config(&cfg, source.dim())?;
    let kc = KmeansConfig { k, tol, max_iters, seed, init, distance };

    // oocore always shards contiguously — "static" is the recorded
    // scheduler, matching the documented threads-static bit-identity
    let (sink, resume_state) = if ckpt_dir.is_some() || resume_dir.is_some() {
        let fp =
            kmeans::ckpt::fingerprint("oocore", "static", &kc, source.len(), source.dim());
        let sink = match &ckpt_dir {
            Some(dir) => Some(kmeans::ckpt::CkptSink::create(dir, ckpt_every, fp.clone())?),
            None => None,
        };
        let state = match &resume_dir {
            Some(dir) => Some(kmeans::ckpt::load_validated(dir, &fp)?),
            None => None,
        };
        (sink, state)
    } else {
        (None, None)
    };
    let resumed_iter = resume_state.as_ref().map(|s| s.iteration);

    let t0 = std::time::Instant::now();
    let result = streaming::run_ckpt(source.as_ref(), &kc, &opts, sink.as_ref(), resume_state)?;
    let total = t0.elapsed().as_secs_f64();

    let payload_bytes = source.len() * source.dim() * 4;
    println!("engine      : oocore");
    println!("kernel tier : {tier} (requested: {kernel_choice})");
    println!("distance    : {distance}");
    println!("source      : {}", source.describe());
    println!(
        "residency   : {} chunk-buffer bytes ({} shards × {} rows) + {} assignment bytes; \
         payload {} bytes never resident",
        opts.buffer_bytes(source.dim()),
        opts.shards,
        opts.chunk_rows,
        source.len() * 4,
        payload_bytes
    );
    println!("k           : {k}   init: {init:?}   seed: {seed}");
    println!(
        "iterations  : {} (converged: {})",
        result.iterations, result.converged
    );
    if let Some(it) = resumed_iter {
        println!("resumed     : from iteration {it}");
    }
    if let Some(s) = &sink {
        println!("checkpoints : {} (every {ckpt_every} iterations)", s.dir().display());
    }
    println!("sse         : {:.6e}", result.sse);
    println!("final shift : {:.3e}", result.shift);
    println!("time        : {total:.4}s");
    println!("cluster sizes: {:?}", result.cluster_sizes());
    print_empty_clusters(&result);
    if source.has_truth() {
        // honor the budget: truth labels are another O(n·4) bytes on
        // top of the assignment vector
        let truth_bytes = source.len() * 4;
        if memory_budget > 0 && truth_bytes > memory_budget {
            println!(
                "ARI vs truth: skipped ({truth_bytes} label bytes exceed \
                 --memory-budget {memory_budget}; rerun without a budget to compute)"
            );
        } else if let Some(truth) = source.truth()? {
            println!(
                "ARI vs truth: {:.4}",
                metrics::adjusted_rand_index(&result.assign, &truth)
            );
        }
    }
    if let Some(path) = assign_out {
        write_assign_csv(&path, &result.assign)?;
    }
    if let Some(path) = save_model {
        save_model_file(&path, Engine::OutOfCore, seed, &result)?;
    }
    finish_trace()?;
    print_artifact_warnings();
    Ok(())
}

/// `run --engine dist`: the distributed leader. The dataset lives at
/// the workers (`parakm worker`); the leader connects, initializes
/// (seeded random — the same index stream as every other engine),
/// broadcasts centroids per iteration and folds the returned partials.
/// `--dist-sched elastic` swaps the per-shard leader for the
/// chunk-granular fault-tolerant one (DESIGN.md §12).
fn cmd_run_dist(args: &Args) -> Result<()> {
    use parakmeans::kmeans::dist::{self, DistOpts, DistSched};

    let workers_raw = args.get("workers").or_config(
        "--engine dist requires --workers host:port,host:port,... (one per shard, \
         ascending shard order)",
    )?;
    let addrs = parse_worker_list(workers_raw)?;
    let k: usize = args.require("k")?;
    let tol: f64 = args.get_or("tol", 1e-6)?;
    let max_iters: usize = args.get_or("max-iters", 300)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let init: Init = args.get_or("init", Init::Random)?;
    let net_timeout: f64 = args.get_or("net-timeout", 120.0)?;
    let sched: DistSched = args.get_or("dist-sched", DistSched::Static)?;
    let retry: u32 = args.get_or("retry", 2)?;
    let distance = distance_from(args)?;
    let assign_out = args.get("assign-out").map(PathBuf::from);
    let save_model = args.get("save-model").map(PathBuf::from);
    let ckpt_dir = args.get("checkpoint").map(PathBuf::from);
    let ckpt_every: usize = args.get_or("checkpoint-every", 1)?;
    let resume_dir = args.get("resume").map(PathBuf::from);
    install_trace_from(args)?;
    install_chaos_from(args)?;
    args.finish()?;

    if !net_timeout.is_finite() || net_timeout <= 0.0 || net_timeout > 86_400.0 {
        return Err(Error::Config("--net-timeout must be in (0, 86400] seconds".into()));
    }
    if retry > 1_000 {
        return Err(Error::Config("--retry must be <= 1000".into()));
    }
    if ckpt_every == 0 {
        return Err(Error::Config("--checkpoint-every must be >= 1".into()));
    }
    let kc = KmeansConfig { k, tol, max_iters, seed, init, distance };
    let opts = DistOpts {
        connect_timeout: std::time::Duration::from_secs_f64(net_timeout.min(10.0)),
        io_timeout: std::time::Duration::from_secs_f64(net_timeout),
        sched,
        retry,
    };
    let ckpt_active = ckpt_dir.is_some() || resume_dir.is_some();

    let t0 = std::time::Instant::now();
    // the leader learns (n, d) from the worker handshake, so fingerprint
    // construction — and with it sink creation and resume validation —
    // lives behind run_ckpt_spec rather than here
    let run = if ckpt_active {
        let spec = dist::CkptSpec {
            checkpoint: ckpt_dir.clone(),
            every: ckpt_every,
            resume: resume_dir.clone(),
        };
        dist::run_ckpt_spec(&addrs, &kc, &opts, &spec)?
    } else {
        dist::run(&addrs, &kc, &opts)?
    };
    let total = t0.elapsed().as_secs_f64();
    let result = &run.result;
    let net = &run.net;
    let (n, dim) = (result.assign.len(), result.dim);

    println!("engine      : dist ({sched})");
    println!("distance    : {distance}");
    println!("workers     : {} ({})", net.workers, addrs.join(", "));
    match sched {
        DistSched::Static => {
            println!("dataset     : {n} points, {dim}D (sharded across workers)")
        }
        DistSched::Elastic => {
            println!("dataset     : {n} points, {dim}D (replicated at every worker)")
        }
    }
    println!("k           : {k}   init: {init:?}   seed: {seed}");
    println!(
        "iterations  : {} (converged: {})",
        result.iterations, result.converged
    );
    if let Some(dir) = &resume_dir {
        println!("resumed     : from {}", dir.display());
    }
    if let Some(dir) = &ckpt_dir {
        println!("checkpoints : {} (every {ckpt_every} iterations)", dir.display());
    }
    println!("sse         : {:.6e}", result.sse);
    println!("final shift : {:.3e}", result.shift);
    println!("time        : {total:.4}s");
    println!(
        "wire        : {} B total ({:.0} B/iter, handshake {} B, init {} B, collect {} B)",
        net.total_bytes(),
        net.bytes_per_iter(),
        net.handshake_bytes,
        net.gather_bytes,
        net.collect_bytes
    );
    println!(
        "round trip  : {:.2} ms avg broadcast-to-last-partial",
        1e3 * net.avg_round_trip_secs()
    );
    if sched == DistSched::Elastic {
        println!(
            "recovery    : failures={} rejoins={} redispatched={} speculative={} (wins {}) \
             recovery={:.3}s",
            net.worker_failures,
            net.worker_rejoins,
            net.redispatched_chunks,
            net.speculative_chunks,
            net.speculative_wins,
            net.recovery_secs
        );
    }
    println!("cluster sizes: {:?}", result.cluster_sizes());
    print_empty_clusters(result);
    if let Some(path) = assign_out {
        write_assign_csv(&path, &result.assign)?;
    }
    if let Some(path) = save_model {
        save_model_file(&path, Engine::Dist, seed, result)?;
    }
    finish_trace()?;
    print_artifact_warnings();
    Ok(())
}

/// Parse `--workers a:p1,b:p2,...` into addresses, rejecting obviously
/// malformed entries up front (connect errors name the rest).
fn parse_worker_list(raw: &str) -> Result<Vec<String>> {
    let addrs: Vec<String> = raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if addrs.is_empty() {
        return Err(Error::Config("--workers lists no addresses".into()));
    }
    for a in &addrs {
        if !a.contains(':') {
            return Err(Error::Config(format!("--workers entry `{a}` is not host:port")));
        }
    }
    Ok(addrs)
}

/// `parakm worker`: own one data shard and serve distributed leaders.
fn cmd_worker(args: &Args) -> Result<()> {
    use parakmeans::cluster::ShardWorker;
    use parakmeans::kmeans::streaming::StreamOpts;

    let listen = args.get("listen").or_config("missing --listen HOST:PORT")?.to_string();
    let chunk: usize = args.get_or("chunk", StreamOpts::DEFAULT_CHUNK_ROWS)?;
    let once = args.has("once");
    let shard_spec = args.get("shard").map(str::to_string);
    let kernel_flag: Option<KernelChoice> =
        args.get("kernel").map(|v| v.parse()).transpose()?;

    // the shard's source: a .pkd file or the on-the-fly GMM generator
    let source: Box<dyn DataSource + Send + Sync> = if let Some(path) = args.get("input") {
        // --seed shapes synthetic sources only; rejecting it here keeps
        // the typo guard honest (a file shard's bytes are fixed)
        if args.get("seed").is_some() {
            return Err(Error::Config(
                "--seed applies to --synthetic worker sources; file shards carry their own bytes"
                    .into(),
            ));
        }
        let p = PathBuf::from(path);
        match p.extension().and_then(|e| e.to_str()) {
            Some(e) if e.eq_ignore_ascii_case("csv") => {
                return Err(Error::Config(
                    "worker streams .pkd files, not csv; convert with gen-data".into(),
                ))
            }
            _ => Box::new(FileSource::open(&p)?),
        }
    } else if let Some(spec) = args.get("synthetic") {
        let (dim, n) = parse_synthetic(spec)?;
        let seed: u64 = args.get_or("seed", parakmeans::data::gmm::workloads::seed_for(dim, n))?;
        Box::new(GmmSource::paper(dim, n, seed)?)
    } else {
        return Err(Error::Config("provide --input <file.pkd> or --synthetic <2d|3d>:<N>".into()));
    };
    install_chaos_from(args)?;
    args.finish()?;

    let tier = match kernel_flag {
        Some(choice) => kernel::set_active(choice)?,
        None => kernel::active_tier(),
    };

    // --shard I/S: this worker owns slice I of the S-way contiguous
    // decomposition — every worker points at the same file/spec
    let (lo, hi) = match shard_spec.as_deref() {
        Some(spec) => {
            let (i_s, s_s) = spec.split_once('/').or_config("--shard expects I/S, e.g. 0/2")?;
            let i: usize = i_s.trim().parse().or_config("--shard index")?;
            let s: usize = s_s.trim().parse().or_config("--shard count")?;
            ShardWorker::shard_slice(source.len(), i, s)?
        }
        None => (0, source.len()),
    };
    let worker = ShardWorker::with_range(source, lo, hi, chunk)?;

    let listener = std::net::TcpListener::bind(&listen)?;
    println!("worker listening on {} — {}", listener.local_addr()?, worker.describe());
    println!("kernel tier : {tier}");
    worker.serve_listener(&listener, once)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let exp = args.get("exp").unwrap_or("all").to_string();
    let scale = match args.get("scale") {
        Some("full") => Scale::Full,
        Some("smoke") | None => Scale::Smoke,
        Some(other) => return Err(Error::Config(format!("--scale `{other}` (full|smoke)"))),
    };
    args.finish()?;
    run_eval(&exp, scale)
}

fn run_eval(exp: &str, scale: Scale) -> Result<()> {
    use parakmeans::eval::{ablations, figures, tables};
    match exp {
        "t1" => drop(tables::table1(scale)?),
        "t2" => drop(tables::table2(scale)?),
        "t3" => drop(tables::table3(scale)?),
        "t4" => drop(tables::table4(scale)?),
        "t5" => drop(tables::table5(scale)?),
        "figs" => drop(figures::cluster_figures(scale)?),
        "speedup" => {
            figures::speedup_efficiency(3, scale)?;
            figures::speedup_efficiency(2, scale)?;
        }
        "scaling" => {
            figures::time_vs_scaling(3, scale)?;
            figures::time_vs_scaling(2, scale)?;
        }
        "a1" => drop(ablations::chunk_size(scale)?),
        "a2" => drop(ablations::merge_policy(scale)?),
        "a3" => drop(ablations::algorithms(scale)?),
        "report" => {
            let text = parakmeans::eval::report::generate(&parakmeans::eval::results_dir())?;
            println!("{text}");
        }
        "all" => {
            for e in [
                "t1", "t2", "t3", "t4", "t5", "figs", "speedup", "scaling", "a1", "a2", "a3",
                "report",
            ] {
                println!("==== eval {e} ====");
                run_eval(e, scale)?;
            }
        }
        other => return Err(Error::Config(format!("unknown --exp `{other}`"))),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts").to_string());
    args.finish()?;
    match parakmeans::runtime::Manifest::load(&dir) {
        Ok(manifest) => {
            println!("artifacts dir : {}", dir.display());
            println!("default chunk : {}", manifest.default_chunk);
            println!("executables   : {}", manifest.executables.len());
            for e in &manifest.executables {
                println!(
                    "  {:<36} kind={:<14?} d={} k={:<2} chunk={:<6} tile={}",
                    e.name, e.kind, e.d, e.k, e.chunk, e.tile_n
                );
            }
        }
        // a manifest that exists but fails to load would fail `run`
        // the same way — report the error instead of claiming fallback
        Err(e) if dir.join("manifest.json").exists() => return Err(e),
        Err(_) => {
            println!("artifacts dir : {} (no manifest)", dir.display());
            println!("engines fall back to the native backend:");
            for (key, val) in parakmeans::runtime::native::synthetic_summary() {
                println!("  {key:<12}: {val}");
            }
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use parakmeans::serve::{serve, BatcherConfig, ServeConfig, ServeLoop, ShedConfig};
    let model_path = args.get("model").map(PathBuf::from);
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878").to_string();
    let max_batch: usize = args.get_or("max-batch", 4096)?;
    let max_delay_ms: u64 = args.get_or("max-delay-ms", 2)?;
    let max_conns: usize = args.get_or("max-conns", 64)?;
    let loop_mode = match args.get("serve-loop") {
        Some(s) => s.parse::<ServeLoop>()?,
        None => ServeLoop::default_for_host(),
    };
    let max_line_bytes: usize = args.get_or("max-line-bytes", 1 << 20)?;
    let shed_soft_pct: u32 = args.get_or("shed-soft-pct", 75)?;
    let shed_heavy_points: usize = args.get_or("shed-heavy-points", 1024)?;
    let stats_every: u64 = args.get_or("stats-every", 0)?;
    let distance = distance_from(args)?;
    let artifacts: PathBuf =
        PathBuf::from(args.get("artifacts").unwrap_or("artifacts").to_string());
    install_chaos_from(args)?;
    // SIGHUP re-reads the model file the server started from
    let reload_path = model_path.clone();

    // a persisted model serves immediately; otherwise train first (a
    // restart re-pays full training cost — prefer run --save-model)
    let (centroids, dim, k) = if let Some(path) = model_path {
        let model = io::read_model(&path)?;
        if let Some(k_flag) = args.get("k") {
            let k_flag: usize = k_flag.parse().or_config("--k")?;
            if k_flag != model.k {
                return Err(Error::Config(format!(
                    "--k {k_flag} contradicts the model's k = {} ({})",
                    model.k,
                    path.display()
                )));
            }
        }
        args.finish()?;
        eprintln!(
            "loaded model {} — k={} dim={} (engine {}, {} iters, sse {:.4e}, seed {})",
            path.display(),
            model.k,
            model.dim,
            model.engine,
            model.iterations,
            model.sse,
            model.seed
        );
        (model.centroids, model.dim, model.k)
    } else {
        let ds = load_input(args)?;
        let k: usize = args.require("k")?;
        let seed: u64 = args.get_or("seed", 42)?;
        args.finish()?;
        // train with the offload engine, then serve assignments
        let cfg = RunConfig { k, seed, artifacts_dir: artifacts.clone(), ..Default::default() };
        eprintln!("training on {} points ({}D, K={k})...", ds.len(), ds.dim());
        let run = offload::run(&ds, &cfg)?;
        eprintln!(
            "trained: {} iters (converged: {}), sse {:.4e}",
            run.result.iterations, run.result.converged, run.result.sse
        );
        (run.result.centroids, ds.dim(), k)
    };

    let scfg = ServeConfig {
        addr,
        artifacts_dir: artifacts,
        batcher: BatcherConfig {
            max_batch,
            max_delay: std::time::Duration::from_millis(max_delay_ms),
            distance,
        },
        queue_depth: 256,
        max_conns,
        loop_mode,
        max_line_bytes,
        shed: ShedConfig { soft_pct: shed_soft_pct, heavy_points: shed_heavy_points },
    };
    let handle = serve(scfg, centroids, dim, k)?;
    println!(
        "serving on {} (--serve-loop {loop_mode}) — line-JSON: {{\"id\": N, \"points\": [[..], ..]}}",
        handle.local_addr
    );
    #[cfg(unix)]
    sig::install();
    #[cfg(not(unix))]
    let _ = &reload_path; // signals are unix-only; ctrl-c still kills
    // lifecycle wait loop: poll the signal flags (SIGTERM/SIGINT →
    // graceful drain + exit 0, SIGHUP → model hot-reload), optionally
    // printing a periodic latency/shed summary from the shared counters
    let mut last_stats = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(250));
        #[cfg(unix)]
        {
            use std::sync::atomic::Ordering;
            if sig::TERM.swap(false, Ordering::AcqRel) {
                eprintln!("sigterm: draining — no new connections, flushing in-flight replies");
                let s = handle.drain(std::time::Duration::from_secs(30));
                eprintln!(
                    "drained: requests={} errors={} batcher_restarts={} model_generation={}",
                    s.batcher.requests, s.batcher.errors, s.batcher_restarts, s.model_generation
                );
                return Ok(()); // exit code 0: the drain was clean
            }
            if sig::HUP.swap(false, Ordering::AcqRel) {
                match reload_path {
                    Some(ref p) => match handle.reload_from(p) {
                        Ok(generation) => eprintln!(
                            "sighup: reloaded {} — now serving generation {generation}",
                            p.display()
                        ),
                        Err(e) => {
                            eprintln!("sighup: reload failed, keeping current model: {e}")
                        }
                    },
                    None => eprintln!("sighup: no --model path to reload from"),
                }
            }
        }
        if stats_every > 0 && last_stats.elapsed().as_secs() >= stats_every {
            last_stats = std::time::Instant::now();
            let s = handle.stats();
            eprintln!(
                "stats: requests={} errors={} saturated={} shed_heavy={} shed_load={} \
                 oversized={} | latency n={} p50={:.1}us p90={:.1}us p99={:.1}us",
                s.batcher.requests,
                s.batcher.errors,
                s.saturated,
                s.shed_heavy,
                s.shed_load,
                s.oversized,
                s.latency.count,
                s.latency.p50_us,
                s.latency.p90_us,
                s.latency.p99_us
            );
        }
    }
}

/// Hand-rolled `signal(2)` hookup (no libc crate): the handlers only
/// flip atomics the serve wait loop polls, which keeps them trivially
/// async-signal-safe.
#[cfg(unix)]
mod sig {
    use std::os::raw::c_int;
    use std::sync::atomic::AtomicBool;
    use std::sync::atomic::Ordering;

    pub static TERM: AtomicBool = AtomicBool::new(false);
    pub static HUP: AtomicBool = AtomicBool::new(false);

    const SIGHUP: c_int = 1;
    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;

    extern "C" {
        fn signal(signum: c_int, handler: usize) -> usize;
    }

    extern "C" fn on_term(_sig: c_int) {
        TERM.store(true, Ordering::Release);
    }

    extern "C" fn on_hup(_sig: c_int) {
        HUP.store(true, Ordering::Release);
    }

    /// Install the serve-lifecycle handlers: SIGTERM/SIGINT request a
    /// graceful drain, SIGHUP a model hot-reload.
    pub fn install() {
        unsafe {
            signal(SIGTERM, on_term as extern "C" fn(c_int) as usize);
            signal(SIGINT, on_term as extern "C" fn(c_int) as usize);
            signal(SIGHUP, on_hup as extern "C" fn(c_int) as usize);
        }
    }
}
