//! # parakmeans — parallel K-Means for big-data clustering
//!
//! A three-layer reproduction of *"Parallelization of the K-Means
//! Algorithm with Applications to Big Data Clustering"* (CS.DC 2024):
//!
//! - **Layer 3 (this crate)** — the coordination contribution: a
//!   shared-memory leader/worker engine ([`coordinator::shared`],
//!   the paper's OpenMP model) and a device-offload engine
//!   ([`coordinator::offload`], the paper's OpenACC model), plus
//!   pure-rust baselines ([`kmeans`]), dataset generation ([`data`]),
//!   metrics ([`metrics`]) and the paper-table/figure harness ([`eval`]).
//! - **Layer 2** — the Lloyd iteration as jax programs
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! - **Layer 1** — the fused assign+accumulate Pallas kernel
//!   (`python/compile/kernels/lloyd.py`).
//!
//! ## The one hot path
//!
//! Every engine's per-iteration cost is the fused assign+accumulate
//! loop. [`linalg::kernel`] implements it once — blocked (points-tile
//! × centroid-tile) and SIMD-vectorized with runtime dispatch (AVX2 /
//! NEON via `std::arch`, portable scalar fallback) — and everything
//! routes through it:
//!
//! - the pure-rust engines via the [`kmeans::step`] facade;
//! - the coordinator engines and the serving batcher via the
//!   [`runtime`] executor, which implements the AOT executable
//!   contract (`stats_partial` / `assign` / `fused_stats` /
//!   `finalize`, `n_valid` padding semantics) natively on the same
//!   kernels. With compiled artifacts on disk the manifest is honored
//!   verbatim; without, a synthetic shape matrix is used so every
//!   engine runs artifact-free.
//!
//! The tier is selected once per process ([`linalg::kernel::active_tier`]),
//! recorded in [`config::RunConfig`], forceable via `--kernel` /
//! `PARAKM_KERNEL`, and surfaced by `eval::report`. All tiers produce
//! bit-identical assignments (property-tested): dispatch changes speed,
//! never results.
//!
//! The kernel offers two distance formulations
//! ([`config::DistancePolicy`], `--distance` / `PARAKM_DISTANCE`,
//! DESIGN.md §11): `exact` — the subtract-square reference every
//! bit-identity contract above is stated against, and the default —
//! and `dot`, which expands `‖x−μ‖² = ‖x‖² − 2·x·μ + ‖μ‖²` into a
//! register-blocked FMA micro-kernel over cached norms ([`data::Dataset::norms`],
//! per-chunk in the out-of-core readers, per-shard in the distributed
//! worker). On the paper suites `dot` reproduces `exact` assignments
//! and iteration counts with SSE inside 1e-5 relative, while relaxing
//! last-ulp value identity across policies and tiers.
//!
//! ## Out of core: clustering past RAM
//!
//! [`data::source::DataSource`] streams rows in fixed-size chunks —
//! from memory (zero-copy), a `.pkd` file, or an on-the-fly seeded GMM
//! generator — and [`kmeans::streaming`] runs sharded Lloyd over any
//! of them with `shards × chunk × dim × 4` bytes of row buffers.
//! The **chunked-accumulation contract** (DESIGN.md §4; details in
//! `rust/src/linalg/README.md`) makes this exact, not approximate:
//! the kernel folds f64 statistics in ascending row order and resumes
//! from the caller's accumulators, so per-shard partials are
//! bit-identical for every chunk size; partials merge in the fixed
//! [`kmeans::step::merge_ordered`] fold, so results depend only on
//! the shard count — one shard reproduces [`kmeans::serial`] bit-for-bit,
//! `S` shards reproduce [`kmeans::parallel`] at `p = S` bit-for-bit.
//!
//! ## Distributed: crossing the process boundary
//!
//! [`cluster`] takes the same decomposition across machines: `parakm
//! worker` processes each own one shard (any `DataSource`) and answer
//! length-prefixed binary frames; the [`kmeans::dist`] leader
//! broadcasts centroids, folds per-shard partials with the same
//! [`kmeans::step::merge_ordered`] contract, and fetches assignments
//! once at the end. Floats cross the wire as IEEE bits, so `dist(S)`
//! is bit-identical to `oocore(shards = S)` and `threads(p = S)` — for
//! any reply timing and any mix of kernel tiers across the cluster.
//! Trained models persist via [`data::io::write_model`] and serve
//! without retraining (`parakm serve --model`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use parakmeans::data::gmm::MixtureSpec;
//! use parakmeans::kmeans::{self, KmeansConfig};
//!
//! let ds = MixtureSpec::paper_2d(4).generate(10_000, 42);
//! let cfg = KmeansConfig::new(4).with_seed(7);
//! let result = kmeans::serial::run(&ds, &cfg);
//! println!("converged in {} iters, sse={}", result.iterations, result.sse);
//! ```
//!
//! Out of core, streaming from a generator source (no resident data):
//!
//! ```
//! use parakmeans::data::gmm::MixtureSpec;
//! use parakmeans::data::source::GmmSource;
//! use parakmeans::kmeans::{streaming, KmeansConfig};
//!
//! let src = GmmSource::new(MixtureSpec::paper_3d(4), 5_000, 42);
//! let opts = streaming::StreamOpts { shards: 2, chunk_rows: 512 };
//! let result = streaming::run(&src, &KmeansConfig::new(4), &opts).unwrap();
//! assert_eq!(result.assign.len(), 5_000);
//! ```

// Lint policy: numeric hot-path code indexes flat row-major buffers by
// design; these pedantic lints fight that idiom and are allowed
// crate-wide so CI can hold `clippy -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::comparison_chain,
    clippy::manual_memcpy
)]

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod kmeans;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod testutil;
pub mod util;

pub use error::{ClusterError, Error, Result};
