//! # parakmeans — parallel K-Means for big-data clustering
//!
//! A three-layer reproduction of *"Parallelization of the K-Means
//! Algorithm with Applications to Big Data Clustering"* (CS.DC 2024):
//!
//! - **Layer 3 (this crate)** — the coordination contribution: a
//!   shared-memory leader/worker engine ([`coordinator::shared`],
//!   the paper's OpenMP model) and a device-offload engine
//!   ([`coordinator::offload`], the paper's OpenACC model), plus
//!   pure-rust baselines ([`kmeans`]), dataset generation ([`data`]),
//!   metrics ([`metrics`]) and the paper-table/figure harness ([`eval`]).
//! - **Layer 2** — the Lloyd iteration as jax programs
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! - **Layer 1** — the fused assign+accumulate Pallas kernel
//!   (`python/compile/kernels/lloyd.py`).
//!
//! Python never runs at request time: [`runtime`] loads the AOT
//! artifacts through the PJRT C API (`xla` crate) and the rust engines
//! drive them directly.
//!
//! ## Quickstart
//!
//! ```no_run
//! use parakmeans::data::gmm::MixtureSpec;
//! use parakmeans::kmeans::{self, KmeansConfig};
//!
//! let ds = MixtureSpec::paper_2d(4).generate(10_000, 42);
//! let cfg = KmeansConfig::new(4).with_seed(7);
//! let result = kmeans::serial::run(&ds, &cfg);
//! println!("converged in {} iters, sse={}", result.iterations, result.sse);
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod kmeans;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod testutil;
pub mod util;

pub use error::{Error, Result};
